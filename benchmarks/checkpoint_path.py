"""Checkpoint/recovery-plane benchmark: the cold-backup spine of the
fault-tolerance plane (paper §4.2), measured stage by stage.

Legs:
  * save_stage — the acceptance leg: full vs delta checkpoint at swept
    dirty-row fractions. A delta captures only rows written since the
    previous checkpoint (``SparseTable`` mutation clock) + evicted ids,
    so its payload should shrink ~linearly with the dirty fraction
    (>= 5x smaller at <= 10% dirty). Reports bytes and save rows/sec.
  * restore_stage — recover_all from a full checkpoint vs from a
    full+deltas chain (``ColdBackup.materialize`` folds the chain), plus
    the bit-equality check between the two restored clusters.
  * reshard_stage — N->M recovery routing: the seed's per-(dest shard,
    snapshot) lambda ``ids_filter`` (kept here verbatim) vs the argsort
    ownership router (ONE ``owner_of`` + argsort pass per group).
  * compress — raw vs int8 checkpoint payloads through the
    ``kernels/delta_codec.py`` row codec (numpy mirror): bytes ratio,
    save throughput, worst-case quantization error.

Timing uses best-of-``--reps`` (the ``timeit`` convention).

Run:  PYTHONPATH=src python benchmarks/checkpoint_path.py
      [--rows 262144 --dim 16 --shards 4 --dst-shards 6 --smoke]
Emits BENCH_checkpoint_path.json (or --out PATH).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def best_of(fn, reps: int) -> float:
    fn()                                              # warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Baseline: the pre-refactor recover_all resharding path, verbatim — one
# load_snapshot per (source snapshot, destination shard), each re-running
# owner_of over the snapshot's full id set, filtering with boolean masks,
# and upserting through SparseTable.scatter (ensure probe + write; touch
# stats dropped — the seed bug the refactor also fixes).
# ---------------------------------------------------------------------------
def seed_load_snapshot(shard, snap, *, ids_filter=None):
    shard.step = snap["step"]
    for g, tsnap in snap["tables"].items():
        t = shard.tables[g]
        ids, w, slots = tsnap["ids"], tsnap["w"], tsnap["slots"]
        if ids_filter is not None:
            keep = ids_filter(ids)
            ids, w = ids[keep], w[keep]
            slots = {k: v[keep] for k, v in slots.items()}
        t.scatter(ids, w, slots)


def seed_lambda_recover_all(ckpt, shards, owner_of):
    for s in shards:
        s.clear()
        s.alive = True
    for snap in ckpt.shard_snaps.values():
        for s in shards:
            sid = s.shard_id
            seed_load_snapshot(
                s, snap, ids_filter=lambda ids, sid=sid:
                owner_of(ids) == sid)


def _sorted_state(shard, group="w"):
    snap = shard.tables[group].snapshot()
    order = np.argsort(snap["ids"])
    return (snap["ids"][order], snap["w"][order],
            {k: v[order] for k, v in snap["slots"].items()},
            snap["last_touch"][order], snap["touch_count"][order])


def states_bit_equal(a_shards, b_shards, group="w") -> bool:
    for a, b in zip(a_shards, b_shards):
        sa, sb = _sorted_state(a, group), _sorted_state(b, group)
        if not (np.array_equal(sa[0], sb[0]) and np.array_equal(sa[1], sb[1])
                and np.array_equal(sa[3], sb[3])
                and np.array_equal(sa[4], sb[4])
                and all(np.array_equal(sa[2][k], sb[2][k])
                        for k in sa[2])):
            return False
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=262_144)
    ap.add_argument("--dim", type=int, default=16)
    # defaults follow the paper's §4.2.1d migration example: "migrate a
    # model from cluster A with 10 shards to cluster B with 20 shards"
    ap.add_argument("--shards", type=int, default=10)
    ap.add_argument("--dst-shards", type=int, default=20)
    ap.add_argument("--deltas", type=int, default=3,
                    help="chain length (full + N deltas) for restore leg")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI")
    ap.add_argument("--out", default="BENCH_checkpoint_path.json")
    args = ap.parse_args()
    if args.smoke:
        args.rows = min(args.rows, 16_384)
        args.reps = 2

    from repro.core.fault_tolerance import (BackupPolicy, CheckpointStore,
                                            ColdBackup, checkpoint_nbytes)
    from repro.core.ps import MasterShard
    from repro.core.routing import RoutingPlan
    from repro.optim import get_optimizer

    rng = np.random.default_rng(0)
    opt = get_optimizer("ftrl")
    groups = {"w": args.dim}
    plan = RoutingPlan(args.shards, 1, 1)
    ids = np.sort(rng.choice(1 << 40, size=args.rows,
                             replace=False).astype(np.int64))

    def make_shards(n):
        return [MasterShard(i, groups, opt) for i in range(n)]

    def populate(shards, step=0, subset=None):
        sel = ids if subset is None else subset
        grads = rng.normal(size=(4096, args.dim)).astype(np.float32)
        for sid, sids in plan.split_by_master(sel).items():
            for i in range(0, len(sids), 4096):
                b = sids[i:i + 4096]
                shards[sid].push_grad("w", b, grads[:len(b)], step=step)

    def dirty_some(shards, frac, step):
        k = max(1, int(args.rows * frac))
        sel = np.sort(rng.choice(ids, size=k, replace=False))
        populate(shards, step=step, subset=sel)
        return k

    shards = make_shards(args.shards)
    populate(shards)
    results: dict[str, dict] = {}

    # -- save stage: full vs delta at swept dirty fractions ----------------
    results["save_stage"] = {"rows": args.rows, "by_dirty_frac": {}}

    def run_full():
        cb = ColdBackup(shards, CheckpointStore(keep=2),
                        BackupPolicy(incremental=False))
        return cb.checkpoint(0.0, tier="local")

    t_full = best_of(run_full, args.reps)
    store_f = CheckpointStore(keep=2)
    cb_f = ColdBackup(shards, store_f, BackupPolicy(incremental=False))
    full_bytes = checkpoint_nbytes(store_f.load(cb_f.checkpoint(0.0)))
    results["save_stage"]["full_seconds"] = t_full
    results["save_stage"]["full_rows_per_sec"] = args.rows / t_full
    results["save_stage"]["full_bytes"] = full_bytes

    for frac in (0.01, 0.10):
        store = CheckpointStore(keep=1024)
        cb = ColdBackup(shards, store, BackupPolicy(incremental=True))
        base_v = cb.checkpoint(0.0, tier="remote")
        marks = {sid: dict(m) for sid, m in cb._marks.items()}
        dmarks = {sid: dict(m) for sid, m in cb._dense_marks.items()}
        k = dirty_some(shards, frac, step=1)

        def run_delta():
            # re-base onto the full checkpoint so every rep captures the
            # same dirty set (checkpointing advances the marks)
            cb._marks = {sid: dict(m) for sid, m in marks.items()}
            cb._dense_marks = {sid: dict(m) for sid, m in dmarks.items()}
            cb._last_version = base_v
            cb._force_full = False
            return cb.checkpoint(1.0, tier="local")

        t_delta = best_of(run_delta, args.reps)
        delta_bytes = checkpoint_nbytes(store.load(run_delta()))
        results["save_stage"]["by_dirty_frac"][f"{frac:.2f}"] = {
            "dirty_rows": k,
            "delta_seconds": t_delta,
            "delta_dirty_rows_per_sec": k / t_delta,
            "delta_bytes": delta_bytes,
            "full_over_delta_bytes": full_bytes / delta_bytes,
            "full_over_delta_seconds": t_full / t_delta,
        }
    results["save_stage"]["full_over_delta_bytes_at_10pct"] = \
        results["save_stage"]["by_dirty_frac"]["0.10"][
            "full_over_delta_bytes"]

    # -- restore stage: full vs full+deltas chain --------------------------
    store = CheckpointStore(keep=1024)
    cb = ColdBackup(shards, store, BackupPolicy(incremental=True))
    cb.checkpoint(0.0, tier="remote")                   # full base
    for i in range(args.deltas):
        dirty_some(shards, 0.05, step=2 + i)
        v_chain = cb.checkpoint(1.0 + i, tier="local")
    v_full = cb.checkpoint(10.0, tier="remote")         # same state, full

    def run_restore_full():
        cb.recover_all(make_shards(args.shards), version=v_full)

    def run_restore_chain():
        cb.recover_all(make_shards(args.shards), version=v_chain)

    t_rf = best_of(run_restore_full, args.reps)
    t_rc = best_of(run_restore_chain, args.reps)
    a, b = make_shards(args.shards), make_shards(args.shards)
    cb.recover_all(a, version=v_chain)
    cb.recover_all(b, version=v_full)
    results["restore_stage"] = {
        "chain_links": 1 + args.deltas,
        "restore_full_rows_per_sec": args.rows / t_rf,
        "restore_chain_rows_per_sec": args.rows / t_rc,
        "chain_over_full_seconds": t_rc / t_rf,
        "chain_bit_equals_full": states_bit_equal(a, b),
    }

    # -- reshard stage: seed lambda filter vs argsort ownership routing ----
    plan_dst = RoutingPlan(args.dst_shards, 1, 1)
    ckpt_full = store.load(v_full)

    def run_seed_reshard():
        seed_lambda_recover_all(ckpt_full, make_shards(args.dst_shards),
                                plan_dst.master_shard)

    def run_vec_reshard():
        cb.recover_all(make_shards(args.dst_shards), version=v_full,
                       owner_of=plan_dst.master_shard)

    t_seed = best_of(run_seed_reshard, max(1, args.reps // 2))
    t_vec = best_of(run_vec_reshard, args.reps)
    sa, sb = make_shards(args.dst_shards), make_shards(args.dst_shards)
    seed_lambda_recover_all(ckpt_full, sa, plan_dst.master_shard)
    cb.recover_all(sb, version=v_full, owner_of=plan_dst.master_shard)
    # seed drops touch stats, so compare ids/values only
    values_equal = all(
        np.array_equal(_sorted_state(a)[0], _sorted_state(b)[0])
        and np.array_equal(_sorted_state(a)[1], _sorted_state(b)[1])
        and all(np.array_equal(_sorted_state(a)[2][k],
                               _sorted_state(b)[2][k])
                for k in _sorted_state(a)[2])
        for a, b in zip(sa, sb))

    # routing stage alone (no table loads): the O(dst x snaps) lambda
    # sweep vs ONE owner_of + argsort + take over the merged row set
    from repro.core.fault_tolerance import (iter_owner_rows,
                                            merge_shard_tables)
    state = cb.materialize(v_full)

    def route_seed():
        for snap in ckpt_full.shard_snaps.values():
            for sid in range(args.dst_shards):
                for tsnap in snap["tables"].values():
                    keep = plan_dst.master_shard(tsnap["ids"]) == sid
                    (tsnap["ids"][keep], tsnap["w"][keep],
                     {k: v[keep] for k, v in tsnap["slots"].items()})

    def route_vec():
        for rows in merge_shard_tables(state["shard_snaps"]).values():
            owner = plan_dst.master_shard(rows["ids"])
            for _dst, _part in iter_owner_rows(rows, owner):
                pass

    t_rseed = best_of(route_seed, max(1, args.reps // 2))
    t_rvec = best_of(route_vec, args.reps)
    results["reshard_stage"] = {
        "src_shards": args.shards, "dst_shards": args.dst_shards,
        "seed_lambda_rows_per_sec": args.rows / t_seed,
        "argsort_rows_per_sec": args.rows / t_vec,
        "speedup": t_seed / t_vec,
        "routing_only_speedup": t_rseed / t_rvec,
        "matches_seed_values": values_equal,
    }

    # -- compression: raw vs int8 checkpoint payloads ----------------------
    def run_int8():
        cb8 = ColdBackup(shards, CheckpointStore(keep=2),
                         BackupPolicy(incremental=False, compress="int8"))
        return cb8.checkpoint(0.0, tier="local")

    t_int8 = best_of(run_int8, max(1, args.reps // 2))
    store8 = CheckpointStore(keep=2)
    cb8 = ColdBackup(shards, store8, BackupPolicy(incremental=False,
                                                  compress="int8"))
    v8 = cb8.checkpoint(0.0)
    int8_bytes = checkpoint_nbytes(store8.load(v8))
    rec = make_shards(args.shards)
    cb8.recover_all(rec, version=v8)
    err = 0.0
    for s_src, s_rec in zip(shards, rec):
        for name in ("z", "n"):
            a_sl = _sorted_state(s_src)[2][name]
            b_sl = _sorted_state(s_rec)[2][name]
            bound = np.abs(a_sl).max(axis=1, keepdims=True) / 127.0 + 1e-7
            err = max(err, float((np.abs(a_sl - b_sl) / bound).max()))
    results["compress"] = {
        "raw_bytes": full_bytes,
        "int8_bytes": int8_bytes,
        "compression": full_bytes / int8_bytes,
        "int8_rows_per_sec": args.rows / t_int8,
        "max_quant_error_in_row_bounds": err,   # <= 1.0 == within absmax/127
    }

    out = {
        "config": {"rows": args.rows, "dim": args.dim,
                   "shards": args.shards, "dst_shards": args.dst_shards,
                   "deltas": args.deltas, "reps": args.reps,
                   "optimizer": "ftrl", "smoke": args.smoke},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"\nfull/delta bytes at 10% dirty: "
          f"{results['save_stage']['full_over_delta_bytes_at_10pct']:.1f}x; "
          f"chain bit-equals full: "
          f"{results['restore_stage']['chain_bit_equals_full']}; "
          f"reshard argsort speedup: "
          f"{results['reshard_stage']['speedup']:.1f}x; int8 compression: "
          f"{results['compress']['compression']:.2f}x")


if __name__ == "__main__":
    main()
