"""End-to-end SLO benchmark: concurrent serve+train load through one
shared PS (the ROADMAP "production-shape SLO" harness, built on
``repro.launch.slo``).

Legs:
  * overload_sweep — the acceptance leg: seeded Zipf predict traffic +
    feedback-joined training batches drive two scenarios (FM store + LR
    head) concurrently; offered load sweeps 0.5x/1x/2x/4x of the serve
    budget with admission control ON. Reports p50/p99 predict latency,
    event→deployed staleness (push→scatter→cache-visible), throughput,
    and shed counters — graceful degradation means p99 stays bounded
    while sheds absorb the overload.
  * no_admission_2x — the same 2x overload with admission OFF: the queue
    grows without bound tick over tick, so tail latency scales with run
    length instead of the depth bound. The p99 ratio vs the admitted run
    is the benefit number.
  * procs (optional, ``--procs``) — the multi-process leg: the PR 7
    process-per-shard runtime driven for ``--proc-steps`` steps,
    reporting per-worker applied counts and the new scatter staleness
    percentiles from worker metrics (simulated seconds: now == step).

``--trace [PATH]`` turns the span tracer on for the whole run and
exports a Chrome/Perfetto JSON (default ``trace_e2e.json``) covering
the in-process sweep; with ``--procs`` the multi-process leg exports
its own cross-process trace next to it (``<PATH minus .json>_procs.json``).
Inspect either with ``python -m repro.obs.trace <path>``.

Run:  PYTHONPATH=src python benchmarks/e2e_slo.py [--smoke] [--procs]
Emits BENCH_e2e.json (or --out PATH).
"""

from __future__ import annotations

import argparse
import json
import tempfile
from dataclasses import asdict


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1 << 20,
                    help="pre-seeded serve-table id space (>=1M full run)")
    ap.add_argument("--budget", type=int, default=2048,
                    help="serve budget (examples) per scenario per tick")
    ap.add_argument("--req-batch", type=int, default=128)
    ap.add_argument("--train-events", type=int, default=512)
    ap.add_argument("--ticks", type=int, default=16,
                    help="measured ticks per sweep point")
    ap.add_argument("--warmup", type=int, default=4)
    ap.add_argument("--procs", action="store_true",
                    help="also run the multi-process runtime leg")
    ap.add_argument("--proc-steps", type=int, default=12)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", nargs="?", const="trace_e2e.json",
                    default=None, metavar="PATH",
                    help="enable span tracing; export Perfetto JSON here")
    ap.add_argument("--out", default="BENCH_e2e.json")
    args = ap.parse_args()
    multipliers = (0.5, 1.0, 2.0, 4.0)
    if args.smoke:
        args.rows = min(args.rows, 1 << 16)
        args.budget = min(args.budget, 512)
        args.req_batch = min(args.req_batch, 64)
        args.train_events = min(args.train_events, 128)
        args.ticks = min(args.ticks, 6)
        args.warmup = min(args.warmup, 2)
        multipliers = (0.5, 2.0)

    from repro.launch.slo import SLOConfig, SLOHarness

    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.configure(enabled=True, process="slo",
                            capacity=1 << 16)

    def make_cfg(**kw) -> SLOConfig:
        return SLOConfig(rows=args.rows, budget=args.budget,
                         req_batch=args.req_batch,
                         train_events=args.train_events,
                         warmup_ticks=args.warmup,
                         measure_ticks=args.ticks, **kw)

    results: dict[str, dict] = {}

    # -- overload sweep, admission ON (acceptance leg) ----------------------
    # depth bound = one tick's budget of queueing per scenario; overload
    # beyond it must shed, not queue
    admitted = SLOHarness(make_cfg(max_pending=2 * args.budget))
    results["overload_sweep"] = {
        f"load_{m}x": admitted.run_point(m) for m in multipliers}
    results["train_side"] = {
        "train_batches": admitted.train_batches,
        "train_examples": admitted.metrics()["train_examples"],
    }

    # -- same overload, admission OFF (the collapse this PR prevents) ------
    raw = SLOHarness(make_cfg(max_pending=None))
    results["no_admission_2x"] = raw.run_point(2.0)

    adm_2x = results["overload_sweep"]["load_2.0x"]
    results["admission_benefit"] = {
        "p99_with_admission_s": adm_2x["latency_s"]["p99"],
        "p99_without_admission_s":
            results["no_admission_2x"]["latency_s"]["p99"],
        "p99_ratio": results["no_admission_2x"]["latency_s"]["p99"]
        / max(adm_2x["latency_s"]["p99"], 1e-9),
        "queue_depth_with": adm_2x["pending_examples"],
        "queue_depth_without":
            results["no_admission_2x"]["pending_examples"],
    }

    if args.trace:
        n = admitted.export_trace(args.trace)
        results["trace"] = {"path": args.trace, "events": n}
        print(f"trace: {n} events -> {args.trace}")

    # -- optional multi-process leg -----------------------------------------
    if args.procs:
        from repro.launch.runtime import ClusterRuntime, RuntimeConfig
        with tempfile.TemporaryDirectory() as root:
            rcfg = RuntimeConfig(root=root, num_master=2, num_slave=2,
                                 num_replicas=1, vocab=1 << 12,
                                 batch_size=64, trace=bool(args.trace))
            with ClusterRuntime(rcfg) as rt:
                rt.run_to(args.proc_steps)
                results["procs"] = {
                    "steps": args.proc_steps,
                    "slaves": {n: rt.clients[n].call("metrics")
                               for n in rt.slave_names()},
                }
                if args.trace:
                    ppath = args.trace.removesuffix(".json") + "_procs.json"
                    n = rt.export_trace(ppath)
                    results["procs"]["trace"] = {"path": ppath,
                                                 "events": n}
                    print(f"procs trace: {n} events -> {ppath}")

    out = {
        "config": {**{k: getattr(args, k) for k in
                      ("rows", "budget", "req_batch", "train_events",
                       "ticks", "warmup", "smoke")},
                   "multipliers": list(multipliers),
                   "harness": asdict(make_cfg(
                       max_pending=2 * args.budget))},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    lo = results["overload_sweep"][f"load_{multipliers[0]}x"]
    hi = results["overload_sweep"][f"load_{multipliers[-1]}x"]
    ben = results["admission_benefit"]
    print(f"\nSLO: p50 {lo['latency_s']['p50']*1e3:.2f}ms / "
          f"p99 {lo['latency_s']['p99']*1e3:.2f}ms at "
          f"{multipliers[0]}x; p99 {hi['latency_s']['p99']*1e3:.2f}ms at "
          f"{multipliers[-1]}x overload "
          f"(shed {hi['admission']['shed_examples']} ex); "
          f"staleness p99 {lo['staleness_s']['p99']*1e3:.2f}ms; "
          f"no-admission 2x p99 is {ben['p99_ratio']:.1f}x worse")


if __name__ == "__main__":
    main()
