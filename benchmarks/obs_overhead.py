"""Tracer-overhead benchmark — the gate that keeps ``repro.obs`` honest
about "low-overhead": the serve and push hot paths are timed with the
span tracer disabled and enabled, and the enabled fractional overhead
must stay under the gate (3 % full run, 10 % in ``--smoke`` where the
tiny workloads amplify timer noise). Disabled must be ~free: the only
cost a disabled tracer may add is one attribute check per instrumented
site, micro-measured here in ns/span.

Legs:
  * serve — ``WeiPSCluster.predict`` over a rotating warm request set
    (the ``serve.predict``/``serve.bucket`` spans + cache instrumentation
    in the loop).
  * push  — ``Pusher.push`` at a 16k-id flush (the ``sync.push`` span +
    per-record trace-meta stamping).
  * guard — raw ns/span of ``begin``/``end`` with the tracer disabled
    (the no-op ``_NULL_SPAN`` path) and enabled (ring write).

Timing is best-of-``--reps`` with the disabled leg measured BEFORE and
AFTER the enabled leg (min of the two) so clock drift can't masquerade
as tracer cost.

Run:  PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke]
Emits BENCH_obs.json (or --out PATH). Exits non-zero if the gate fails.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def best_of(fn, reps: int) -> float:
    fn()                                              # warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=131_072)
    ap.add_argument("--batch", type=int, default=1024,
                    help="examples per predict request")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--push-ids", type=int, default=16_384)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--gate", type=float, default=None,
                    help="max enabled overhead fraction "
                         "(default 0.03, 0.10 with --smoke)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()
    if args.smoke:
        args.rows = min(args.rows, 1 << 14)
        args.batch = min(args.batch, 256)
        args.requests = 4
        args.push_ids = min(args.push_ids, 4096)
        args.reps = 3
    gate = args.gate if args.gate is not None else \
        (0.10 if args.smoke else 0.03)

    from repro.configs.weips_ctr import FM_FTRL
    from repro.core import ClusterConfig, WeiPSCluster
    from repro.core.ps import MasterShard
    from repro.core.queue import PartitionedQueue
    from repro.core.routing import RoutingPlan
    from repro.core.streaming import Pusher
    from repro.core.transform import make_transform
    from repro.obs import trace as obs_trace
    from repro.optim import get_optimizer

    rng = np.random.default_rng(0)
    results: dict[str, dict] = {}

    def enable():
        obs_trace.configure(enabled=True, capacity=1 << 15,
                            process="bench")

    def measure_pair(fn) -> dict:
        """Interleaved best-of: each round times the fn disabled then
        enabled back to back, and each leg keeps its minimum — clock
        drift, frequency scaling, and allocator state hit both legs
        equally instead of masquerading as tracer cost."""
        for en in (False, True):                      # warm both modes
            enable() if en else obs_trace.disable()
            fn()
        off = on = float("inf")
        for _ in range(max(3, args.reps)):
            obs_trace.disable()
            t0 = time.perf_counter()
            fn()
            off = min(off, time.perf_counter() - t0)
            enable()
            t0 = time.perf_counter()
            fn()
            on = min(on, time.perf_counter() - t0)
        obs_trace.disable()
        return {"disabled_s": off, "enabled_s": on,
                "overhead_frac": (on - off) / off}

    # -- serve hot path -----------------------------------------------------
    import dataclasses
    cfg = dataclasses.replace(FM_FTRL, fields=8, feature_space=args.rows)
    cl = WeiPSCluster(cfg, ClusterConfig(
        num_master=1, num_slave=2, num_replicas=1, num_partitions=4))
    pool = np.arange(args.rows, dtype=np.int64)
    for i in range(0, args.rows, 65_536):
        chunk = pool[i:i + 65_536]
        for g, dim in cl.groups.items():
            cl.masters[0].apply_batch(
                g, chunk,
                rng.normal(size=(len(chunk), dim)).astype(np.float32))
    cl.sync_tick(0.0)
    reqs = [pool[rng.integers(0, args.rows, size=(args.batch, 8))]
            for _ in range(args.requests)]

    def serve_cycle():
        for q in reqs:
            cl.predict(q)

    cl.predict(reqs[0])                       # compile the bucket shape
    results["serve"] = {
        "request_ids": args.batch * 8, "requests": args.requests,
        **measure_pair(serve_cycle)}

    # -- push hot path ------------------------------------------------------
    plan = RoutingPlan(1, 2, 4)
    opt = get_optimizer("ftrl")
    master = MasterShard(0, {"w": args.dim}, opt)
    push_ids = np.sort(rng.choice(1 << 40, size=args.push_ids,
                                  replace=False).astype(np.int64))
    for i in range(0, args.push_ids, 4096):
        chunk = push_ids[i:i + 4096]
        master.apply_batch(
            "w", chunk,
            rng.normal(size=(len(chunk), args.dim)).astype(np.float32))
    gathered = {("w", "upsert"): push_ids}
    transform = make_transform("identity", opt)

    def push_flush():
        Pusher(master, PartitionedQueue(4), plan,
               transform).push(gathered, now=0.0)

    results["push"] = {
        "push_ids": args.push_ids, "dim": args.dim,
        **measure_pair(push_flush)}

    # -- guard micro-measure: ns per instrumented site ----------------------
    n = 100_000

    def span_loop():
        tr = obs_trace.get_tracer()
        for _ in range(n):
            if tr.enabled:
                with tr.span("bench.noop"):
                    pass

    obs_trace.disable()
    t_off = best_of(span_loop, 3)
    enable()
    t_on = best_of(span_loop, 3)
    obs_trace.disable()
    results["guard"] = {
        "disabled_ns_per_site": t_off / n * 1e9,
        "enabled_ns_per_span": t_on / n * 1e9,
    }

    worst = max(results["serve"]["overhead_frac"],
                results["push"]["overhead_frac"])
    results["gate"] = {
        "threshold_frac": gate,
        "worst_overhead_frac": worst,
        "pass": bool(worst < gate),
    }

    out = {
        "config": {k: getattr(args, k) for k in
                   ("rows", "batch", "requests", "push_ids", "dim",
                    "reps", "smoke")},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"\ntracer overhead: serve "
          f"{results['serve']['overhead_frac']*100:+.2f}%, push "
          f"{results['push']['overhead_frac']*100:+.2f}% (gate "
          f"<{gate*100:.0f}%); disabled site cost "
          f"{results['guard']['disabled_ns_per_site']:.0f}ns, enabled "
          f"span {results['guard']['enabled_ns_per_span']:.0f}ns")
    if not results["gate"]["pass"]:
        print("GATE FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
