"""PS hot-path benchmark: dict-loop baseline vs vectorized open-addressing
vs Pallas-interpret gather, measured as rows/sec through batched
``_ensure`` + gather (the per-minibatch PS resolution path) and through
the full FTRL push (gather → update → scatter).

The dict-loop baseline is the seed implementation this PR replaced
(per-row ``dict.get`` in Python, fancy-indexed row copies); it is kept
here verbatim as the reference point for the recorded speedup. The seed's
full push path additionally ran the FTRL update through per-call eager
JAX dispatch — ``seed_push`` reproduces that too.

Timing uses best-of-``--reps`` over a fixed batch set (the ``timeit``
convention: the minimum measures the code, not scheduler/VM noise).

Run:  PYTHONPATH=src python benchmarks/ps_hot_path.py
      [--rows 1000000 --batch 4096 --dim 16 --reps 9 --quick]
Emits BENCH_ps_hot_path.json (or --out PATH).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


# ---------------------------------------------------------------------------
# Baseline: the seed's dict-based SparseTable row resolution (verbatim
# semantics: per-id Python loop over a dict + free list, fancy+copy gather).
# ---------------------------------------------------------------------------
class DictLoopTable:
    def __init__(self, dim: int, slot_names: tuple = (),
                 init_capacity: int = 1024):
        self.dim = dim
        self._slot_of: dict[int, int] = {}
        self._id_of: list[int] = []
        self._free: list[int] = []
        self._w = np.zeros((init_capacity, dim), dtype=np.float32)
        self._slots = {n: np.zeros((init_capacity, dim), np.float32)
                       for n in slot_names}

    def _grow(self, need: int) -> None:
        cap = self._w.shape[0]
        new_cap = max(need, cap * 2)
        def grow(a):
            out = np.zeros((new_cap,) + a.shape[1:], dtype=a.dtype)
            out[:cap] = a
            return out
        self._w = grow(self._w)
        self._slots = {n: grow(a) for n, a in self._slots.items()}

    def _ensure(self, ids: np.ndarray) -> np.ndarray:
        slots = np.empty(len(ids), dtype=np.int64)
        for i, rid in enumerate(ids.tolist()):
            s = self._slot_of.get(rid)
            if s is None:
                if self._free:
                    s = self._free.pop()
                else:
                    s = len(self._id_of)
                    self._id_of.append(-1)
                    if s >= self._w.shape[0]:
                        self._grow(s + 1)
                self._slot_of[rid] = s
                self._id_of[s] = rid
                self._w[s] = 0.0
                for a in self._slots.values():
                    a[s] = 0.0
            slots[i] = s
        return slots

    def gather(self, ids: np.ndarray):
        sl = self._ensure(ids)
        return self._w[sl].copy(), {n: a[sl].copy()
                                    for n, a in self._slots.items()}

    def scatter(self, ids: np.ndarray, w: np.ndarray, slots: dict) -> None:
        sl = self._ensure(ids)
        self._w[sl] = w
        for n, v in slots.items():
            self._slots[n][sl] = v


def best_of(fn, batches, reps: int) -> float:
    """Minimum per-batch seconds over ``reps`` sweeps (timeit convention)."""
    fn(batches[0])                                    # warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for b in batches:
            fn(b)
        best = min(best, (time.perf_counter() - t0) / len(batches))
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=1,
                    help="row dim; default 1 = the paper's flagship "
                         "LR-on-FTRL CTR config (weips_ctr.LR_FTRL, "
                         "groups {'w': 1}); use 8/16 for FM/DNN embeddings")
    ap.add_argument("--reps", type=int, default=11)
    ap.add_argument("--hot-batches", type=int, default=10)
    ap.add_argument("--pallas-rows", type=int, default=4096,
                    help="table size for the Pallas-interpret leg "
                         "(interpret mode executes grid steps in Python; "
                         "full 1M-row scale is a TPU measurement)")
    ap.add_argument("--pallas-batch", type=int, default=256)
    ap.add_argument("--sweep-slots", type=int, nargs="*", default=None,
                    help="map capacities for the HBM/windowed-DMA sweep "
                         "(default 1M..16M; --quick defaults 1M,4M)")
    ap.add_argument("--sweep-batch", type=int, default=512)
    ap.add_argument("--sweep-live", type=int, default=65536,
                    help="live rows per sweep table (map capacity is the "
                         "swept quantity; load stays far below growth)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_ps_hot_path.json")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.reps = min(args.rows, 100_000), 3
    if args.sweep_slots is None:
        args.sweep_slots = [1 << 20, 1 << 22] if args.quick else \
            [1 << 20, 1 << 21, 1 << 22, 1 << 23, 1 << 24]

    from repro.core.ps import MasterShard, SparseTable
    from repro.optim import get_optimizer

    rng = np.random.default_rng(0)
    # unique random int64 ids over a huge space (realistic hashed features)
    ids = rng.choice(1 << 40, size=args.rows, replace=False).astype(np.int64)
    hot = [rng.choice(ids, size=args.batch).astype(np.int64)
           for _ in range(args.hot_batches)]

    results: dict[str, dict] = {}

    # -- populate (cold insert) --------------------------------------------
    dt = DictLoopTable(args.dim, init_capacity=args.rows)
    t0 = time.perf_counter()
    for i in range(0, args.rows, args.batch):
        dt._ensure(ids[i:i + args.batch])
    dict_pop = time.perf_counter() - t0
    vt = SparseTable(args.dim, init_capacity=args.rows)
    t0 = time.perf_counter()
    for i in range(0, args.rows, args.batch):
        vt.ensure(ids[i:i + args.batch])
    vec_pop = time.perf_counter() - t0

    # -- hot ensure + gather (the acceptance leg) --------------------------
    d_s = best_of(dt.gather, hot, args.reps)
    v_s = best_of(lambda b: vt.gather(b, create=True), hot, args.reps)
    results["dict_loop"] = {
        "populate_rows_per_sec": args.rows / dict_pop,
        "ensure_gather_rows_per_sec": args.batch / d_s,
        "us_per_batch": d_s * 1e6}
    results["vectorized"] = {
        "populate_rows_per_sec": args.rows / vec_pop,
        "ensure_gather_rows_per_sec": args.batch / v_s,
        "us_per_batch": v_s * 1e6}

    # -- full FTRL push: seed path (dict + eager-JAX) vs apply_batch -------
    opt = get_optimizer("ftrl")
    sdt = DictLoopTable(args.dim, ("n", "z"), init_capacity=args.rows)
    for i in range(0, args.rows, args.batch):
        sdt._ensure(ids[i:i + args.batch])
    grads = np.ones((args.batch, args.dim), np.float32)

    import jax.numpy as jnp

    def seed_push(b):                 # the seed MasterShard.push_grad body
        w, slots = sdt.gather(b)
        new_w, new_slots = opt.update(
            jnp.asarray(w), {k: jnp.asarray(v) for k, v in slots.items()},
            jnp.asarray(grads[:len(b)]), 0)
        sdt.scatter(b, np.asarray(new_w),
                    {k: np.asarray(v) for k, v in new_slots.items()})

    m = MasterShard(0, {"w": args.dim}, opt)
    for i in range(0, args.rows, args.batch):
        m.tables["w"].ensure(ids[i:i + args.batch])
    s_push = best_of(seed_push, hot, max(1, args.reps // 3))
    v_push = best_of(lambda b: m.apply_batch("w", b, grads[:len(b)]),
                     hot, args.reps)
    results["ftrl_push"] = {
        "seed_rows_per_sec": args.batch / s_push,
        "apply_batch_rows_per_sec": args.batch / v_push,
        "speedup": s_push / v_push}

    # -- Pallas-interpret gather through the PS layer ----------------------
    pt = SparseTable(args.dim, init_capacity=args.pallas_rows,
                     backend="pallas")
    pt.ensure(ids[:args.pallas_rows])
    p_hot = [rng.choice(ids[:args.pallas_rows],
                        size=args.pallas_batch).astype(np.int64)
             for _ in range(2)]
    p_s = best_of(lambda b: pt.gather(b, create=True), p_hot, 2)
    results["pallas_interpret"] = {
        "rows": args.pallas_rows, "batch": args.pallas_batch,
        "ensure_gather_rows_per_sec": args.pallas_batch / p_s,
        "us_per_batch": p_s * 1e6,
        "note": "interpret mode runs grid steps in Python; on TPU the same "
                "call compiles to a Mosaic scalar-prefetch DMA pipeline"}

    # -- map-size sweep: fused lookup + FTRL apply vs map capacity ---------
    # The point: past VMEM_SLOT_BOUND (~2M slots) the probe's key table
    # cannot stream into VMEM — the windowed-DMA HBM kernel takes over
    # (placement flips to "hbm") and the fused paths keep running, with
    # bit-equality gates against the host-authoritative arrays at every
    # size. Interpret mode on CPU; the Mosaic path is exercised by the
    # `tpu`-marked smoke test on real hardware.
    from repro.kernels.hashmap_probe import VMEM_SLOT_BOUND
    from repro.optim.optimizers import FTRL

    sweep: dict[str, dict] = {}
    sw_reps = max(2, args.reps // 3)
    for slots in args.sweep_slots:
        st = SparseTable(args.dim, ("n", "z"), init_capacity=slots,
                         backend="pallas")
        n_live = min(args.sweep_live, slots // 8)   # stay below 25% growth
        live = np.unique(rng.integers(
            1, 1 << 62, size=n_live + 1024).astype(np.int64))[:n_live]
        st.ensure(live)
        assert st._map.capacity == slots, (st._map.capacity, slots)
        q_live = rng.choice(live, size=args.sweep_batch, replace=False)
        q_mixed = np.concatenate([
            q_live[:args.sweep_batch // 2],
            rng.integers(1 << 62, (1 << 62) + (1 << 40),
                         args.sweep_batch // 2).astype(np.int64)])
        grads = rng.normal(size=(args.sweep_batch, args.dim)) \
            .astype(np.float32)

        # bit-equality gates BEFORE timing (timing mutates rows)
        dev = np.asarray(st._gather_device(q_mixed))
        sl_h = st.lookup(q_mixed)
        ok = sl_h >= 0
        host = np.where(ok[:, None],
                        st._w[np.where(ok, sl_h, 0)].astype(np.float32),
                        np.float32(0.0))
        lookup_equal = bool((dev == host).all())

        # FTRL gate: the fused chain (probe→gather→FTRL→scatter over the
        # HBM/VMEM mirror) must be BIT-EQUAL to the same FTRL kernel run
        # standalone on host-gathered rows — anything the probe placement
        # or scatter got wrong shows up here. The numpy oracle differs in
        # float op order (~1 ulp on w), so it gates at allclose with the
        # max deviation recorded.
        opt = FTRL()
        sl = st.lookup(q_live)
        w0, slots0 = st.read_rows(sl)
        ref_w, ref_slots = opt.update_rows(w0, slots0, grads, 0,
                                           backend="pallas")
        np_w, np_slots = opt.update_rows(w0, slots0, grads, 0,
                                         backend="numpy")
        st.fused_ftrl_update(q_live, sl, grads, alpha=opt.alpha,
                             beta=opt.beta, l1=opt.l1, l2=opt.l2)
        w1, slots1 = st.read_rows(sl)
        ftrl_equal = bool(
            (w1.astype(np.float32) == ref_w.astype(np.float32)).all()
            and all((slots1[k] == ref_slots[k]).all() for k in slots1))
        ftrl_np_dev = float(max(
            np.abs(w1.astype(np.float32) - np_w.astype(np.float32)).max(),
            max(np.abs(slots1[k] - np_slots[k]).max() for k in slots1)))
        ftrl_np_close = bool(np.allclose(w1, np_w, rtol=1e-5, atol=1e-6))

        lk_batches = [q_mixed, np.roll(q_mixed, 7)]
        lk_s = best_of(st._gather_device, lk_batches, sw_reps)
        up_s = best_of(
            lambda b: st.fused_ftrl_update(
                q_live, sl, grads, alpha=opt.alpha, beta=opt.beta,
                l1=opt.l1, l2=opt.l2),
            [q_live], sw_reps)
        sweep[str(slots)] = {
            "slots": slots,
            "live_rows": n_live,
            "placement": st._dev.placement,
            "past_vmem_bound": slots > VMEM_SLOT_BOUND,
            "lookup_us_per_batch": lk_s * 1e6,
            "lookup_rows_per_sec": args.sweep_batch / lk_s,
            "ftrl_us_per_batch": up_s * 1e6,
            "ftrl_rows_per_sec": args.sweep_batch / up_s,
            "lookup_bit_equal_host": lookup_equal,
            "ftrl_bit_equal_kernel": ftrl_equal,
            "ftrl_allclose_numpy": ftrl_np_close,
            "ftrl_numpy_max_abs_dev": ftrl_np_dev,
        }
        del st
    results["map_size_sweep"] = {
        "batch": args.sweep_batch, "live_rows": args.sweep_live,
        "vmem_slot_bound": VMEM_SLOT_BOUND,
        "sizes": sweep,
        "note": "interpret mode on CPU; placement flips vmem->hbm past "
                "the bound — the windowed-DMA kernel is what keeps "
                ">2M-slot maps device-resident at all"}

    speedup = d_s / v_s
    out = {
        "config": {"rows": args.rows, "batch": args.batch, "dim": args.dim,
                   "reps": args.reps},
        "results": results,
        "speedup_vectorized_over_dict": speedup,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"\nvectorized ensure+gather speedup over dict loop: "
          f"{speedup:.1f}x; full FTRL push speedup: "
          f"{results['ftrl_push']['speedup']:.1f}x")


if __name__ == "__main__":
    main()
