"""Builds the EXPERIMENTS.md roofline tables from the dry-run artifacts.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--artifacts DIR]
Prints markdown; the EXPERIMENTS.md sections are generated from this.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "mamba2-1.3b", "llama-3.2-vision-90b", "qwen1.5-4b", "dbrx-132b",
    "qwen2-7b", "granite-moe-3b-a800m", "qwen2-1.5b", "whisper-medium",
    "jamba-1.5-large-398b", "gemma3-4b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(art_dir: str) -> dict:
    out = {}
    for f in glob.glob(os.path.join(art_dir, "*.json")):
        d = json.load(open(f))
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(arts: dict, mesh: str = "pod1") -> str:
    lines = [
        "| arch | shape | compute | memory(est) | collective | dominant | "
        "MODEL_FLOPS | useful | state/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = arts.get((arch, shape, mesh))
            if d is None:
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                             "- | missing |")
                continue
            if d["status"] == "skip":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — |"
                             f" — | SKIP: {d['reason'][:60]} |")
                continue
            if d["status"] == "error":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — |"
                             f" — | ERROR: {d['error'][:50]} |")
                continue
            r = d["roofline"]
            mem = d["memory"]
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"{r['dominant'].replace('_s','')} | "
                f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} | "
                f"{fmt_b(mem['argument_bytes_per_device'])} | |")
    return "\n".join(lines)


def memory_table(arts: dict, mesh: str = "pod1") -> str:
    lines = [
        "| arch | shape | args/dev | out/dev | XLA temp (no-reuse UB) | "
        "act est | collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = arts.get((arch, shape, mesh))
            if d is None or d["status"] != "ok":
                continue
            mem = d["memory"]
            cc = d["collectives"].get("counts", {})
            cstr = " ".join(f"{k.split('-')[1] if '-' in k else k}:{v}"
                            for k, v in sorted(cc.items()))
            act = mem.get("activation_estimate", {})
            act_tot = act.get("total", sum(
                v for k, v in act.items() if isinstance(v, (int, float))))
            lines.append(
                f"| {arch} | {shape} | "
                f"{fmt_b(mem['argument_bytes_per_device'])} | "
                f"{fmt_b(mem['output_bytes_per_device'])} | "
                f"{fmt_b(mem['temp_bytes_upper_bound'])} | "
                f"{fmt_b(act_tot)} | {cstr} |")
    return "\n".join(lines)


def multipod_delta_table(arts: dict) -> str:
    lines = [
        "| arch | shape | collective pod1 | collective pod2 | pod-axis "
        "overhead |",
        "|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            a = arts.get((arch, shape, "pod1"))
            b = arts.get((arch, shape, "pod2"))
            if not a or not b or a["status"] != "ok" or b["status"] != "ok":
                continue
            ca = a["roofline"]["collective_s"]
            cb = b["roofline"]["collective_s"]
            ratio = cb / ca if ca else float("inf")
            lines.append(f"| {arch} | {shape} | {fmt_s(ca)} | {fmt_s(cb)} | "
                         f"{ratio:.2f}x |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="benchmarks/artifacts/baseline")
    args = ap.parse_args()
    arts = load(args.artifacts)
    n_ok = sum(1 for d in arts.values() if d["status"] == "ok")
    n_skip = sum(1 for d in arts.values() if d["status"] == "skip")
    print(f"# Roofline report ({n_ok} ok, {n_skip} documented skips)\n")
    print("## Single-pod (16x16 = 256 chips) roofline\n")
    print(roofline_table(arts, "pod1"))
    print("\n## Memory / collectives detail (single-pod)\n")
    print(memory_table(arts, "pod1"))
    print("\n## Multi-pod (2x16x16 = 512 chips) collective delta\n")
    print(multipod_delta_table(arts))


if __name__ == "__main__":
    main()
