"""Benchmark harness — one benchmark per paper claim/figure (WeiPS has no
numbered result tables; its quantitative claims are §1.2 second-level
deployment, §4.1.2a >=90 % update repetition within 10 s, §4.1.3 serialize+
compress bandwidth, §4.2 multi-level fault tolerance, §4.3 domino
downgrade). Prints ``name,us_per_call,derived`` CSV rows.

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# 1. Second-level deployment: sync lag vs deployment mechanism (paper §1.2,
#    §4.1 — streaming update vs checkpoint-reload deployment)
# ---------------------------------------------------------------------------


def bench_deploy_latency(quick: bool) -> None:
    from repro.configs.weips_ctr import LR_FTRL
    from repro.core import ClusterConfig, WeiPSCluster
    from repro.data import ClickStream

    steps = 30 if quick else 80
    for mode, period in (("realtime", 0.0), ("period", 1.0), ("period", 10.0)):
        cl = WeiPSCluster(LR_FTRL, ClusterConfig(
            num_master=4, num_slave=2, num_replicas=2, num_partitions=8,
            gather_mode=mode, gather_period=period))
        stream = ClickStream(feature_space=1 << 14, fields=LR_FTRL.fields)
        t0 = time.perf_counter()
        now, lags = 0.0, []
        for i in range(steps):
            ids, y = stream.batch(128)
            cl.train_on_batch(ids, y, now=now)
            cl.sync_tick(now)
            lags.append(cl.sync_metrics(now)["sync_lag_seconds"])
            now += 0.2
        wall = (time.perf_counter() - t0) / steps * 1e6
        tag = f"{mode}{'' if mode == 'realtime' else f'_{period}s'}"
        _row(f"deploy_lag/{tag}", wall,
             f"p50_lag={np.median(lags):.2f}s max_lag={max(lags):.2f}s")
    # checkpoint-reload deployment baseline (what the paper replaces):
    # lag = checkpoint interval + reload; with a 60 s interval the mean
    # staleness is >=30 s vs sub-second streaming.
    _row("deploy_lag/checkpoint_reload_baseline", 0.0,
         "p50_lag=30.00s max_lag=60.00s (60s ckpt interval; paper's "
         "motivation)")


# ---------------------------------------------------------------------------
# 2. Update repetition / dedup within the gather window (paper §4.1.2a:
#    ">=90 % repetition within 10 seconds")
# ---------------------------------------------------------------------------


def bench_dedup_ratio(quick: bool) -> None:
    from repro.core.streaming import Gatherer
    from repro.data import ClickStream

    qps_batches = 20 if quick else 50          # batches per second
    for window in (1.0, 5.0, 10.0):
        stream = ClickStream(feature_space=1 << 20, fields=32, zipf_a=1.2,
                             seed=0)
        g = Gatherer("period", period=window)
        t0 = time.perf_counter()
        now = 0.0
        n_batches = int(window * qps_batches)
        for _ in range(n_batches):
            ids, _ = stream.batch(256)
            g.offer([("w", ids.reshape(-1), "upsert")])
            now += 1.0 / qps_batches
        g.flush(now)
        us = (time.perf_counter() - t0) / n_batches * 1e6
        _row(f"gather_dedup/window_{window:.0f}s", us,
             f"dedup_ratio={g.stats.dedup_ratio:.3f} "
             f"raw={g.stats.raw_ids} pushed={g.stats.pushed_ids}")


# ---------------------------------------------------------------------------
# 3. Push bandwidth per codec (paper §4.1.3 serialize + compress)
# ---------------------------------------------------------------------------


def bench_codec_bandwidth(quick: bool) -> None:
    from repro.core.transform import make_transform

    rows = np.random.default_rng(0).normal(
        size=(4096 if quick else 16384, 16)).astype(np.float32)
    for codec in ("identity", "cast16", "int8"):
        t = make_transform(codec)
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            payload = t.encode(rows, {})
        us = (time.perf_counter() - t0) / reps * 1e6
        nbytes = t.payload_bytes(payload)
        _row(f"codec_bandwidth/{codec}", us,
             f"bytes_per_row={nbytes/len(rows):.1f} "
             f"ratio_vs_f32={nbytes/(rows.nbytes):.3f}")


# ---------------------------------------------------------------------------
# 4. Fault tolerance: hot failover vs cold recovery (paper §4.2)
# ---------------------------------------------------------------------------


def bench_fault_tolerance(quick: bool) -> None:
    from repro.configs.weips_ctr import LR_FTRL
    from repro.core import ClusterConfig, WeiPSCluster
    from repro.data import ClickStream

    cl = WeiPSCluster(LR_FTRL, ClusterConfig(
        num_master=4, num_slave=2, num_replicas=2, num_partitions=8))
    stream = ClickStream(feature_space=1 << 14, fields=LR_FTRL.fields)
    now = 0.0
    for i in range(20 if quick else 60):
        ids, y = stream.batch(256)
        cl.train_on_batch(ids, y, now=now)
        cl.sync_tick(now)
        now += 0.2
    cl.checkpoint(now)

    # hot failover: kill a replica mid-serving; count failed requests
    ids_eval, _ = stream.batch(64)
    cl.kill_slave_replica(0, 0)
    t0 = time.perf_counter()
    failed = 0
    for _ in range(20):
        try:
            cl.predict(ids_eval)
        except RuntimeError:
            failed += 1
    us = (time.perf_counter() - t0) / 20 * 1e6
    _row("fault/hot_failover", us,
         f"failed_requests={failed} failovers={cl.replica_sets[0].failovers}")

    # cold recovery: kill a master shard, restore from checkpoint + replay
    rows_before = len(cl.masters[1].tables["w"])
    t0 = time.perf_counter()
    cl.kill_master(1)
    cl.recover_master(1)
    cl.sync_tick(now + 1)
    us = (time.perf_counter() - t0) * 1e6
    _row("fault/cold_partial_recovery", us,
         f"rows_restored={len(cl.masters[1].tables['w'])} "
         f"rows_before={rows_before} cluster_restart=False")


# ---------------------------------------------------------------------------
# 5. Domino downgrade: detection latency + serving restoration (paper §4.3)
# ---------------------------------------------------------------------------


def bench_downgrade(quick: bool) -> None:
    import dataclasses

    from repro.configs.weips_ctr import LR_FTRL
    from repro.core import ClusterConfig, WeiPSCluster
    from repro.data import ClickStream

    for window in (3, 10):
        cfg = dataclasses.replace(LR_FTRL, ftrl_l1=0.01, ftrl_alpha=0.3)
        cl = WeiPSCluster(cfg, ClusterConfig(
            num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
            downgrade_metric="logloss", downgrade_threshold=0.72,
            downgrade_window=window))
        stream = ClickStream(feature_space=1 << 8, fields=cfg.fields,
                             signal_scale=1.0)
        now = 0.0
        for i in range(30):
            ids, y = stream.batch(128)
            cl.train_on_batch(ids, y, now=now)
            cl.sync_tick(now)
            now += 0.5
        cl.checkpoint(now)
        false_alarms = 1 if cl.downgrade_check(now) else 0
        stream.corrupt(scale=2.0)
        detect_batches = None
        t0 = time.perf_counter()
        for i in range(30):
            ids, y = stream.batch(128)
            cl.train_on_batch(ids, y, now=now)
            now += 0.5
            if cl.downgrade_check(now) is not None:
                detect_batches = i + 1
                break
        us = (time.perf_counter() - t0) * 1e6
        _row(f"downgrade/window_{window}", us,
             f"detect_batches={detect_batches} false_alarm={false_alarms} "
             f"rollbacks={len(cl.downgrader.downgrades)}")


# ---------------------------------------------------------------------------
# 6. PS operation throughput (pull / push paths)
# ---------------------------------------------------------------------------


def bench_ps_throughput(quick: bool) -> None:
    from repro.core.ps import MasterShard
    from repro.optim import get_optimizer

    shard = MasterShard(0, {"w": 16}, get_optimizer("ftrl"))
    rng = np.random.default_rng(0)
    ids = rng.choice(1 << 22, size=4096, replace=False).astype(np.int64)
    grads = rng.normal(size=(4096, 16)).astype(np.float32)
    shard.push_grad("w", ids, grads)          # warm-up/row creation
    reps = 10 if quick else 30
    t0 = time.perf_counter()
    for _ in range(reps):
        shard.pull("w", ids)
    pull_us = (time.perf_counter() - t0) / reps * 1e6
    _row("ps/pull_4096x16", pull_us,
         f"rows_per_s={4096/(pull_us/1e6):.0f}")
    t0 = time.perf_counter()
    for _ in range(reps):
        shard.push_grad("w", ids, grads)
    push_us = (time.perf_counter() - t0) / reps * 1e6
    _row("ps/push_grad_4096x16", push_us,
         f"rows_per_s={4096/(push_us/1e6):.0f}")


# ---------------------------------------------------------------------------
# 7. Kernel microbenches (interpret-mode correctness path on CPU; the
#    derived column carries the oracle-vs-kernel max error)
# ---------------------------------------------------------------------------


def bench_kernels(quick: bool) -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (1 << 14, 128))
    ids = jax.random.randint(key, (1024,), 0, 1 << 14)

    def timed(fn, *args, reps=3):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
            jax.block_until_ready(out)
        return out, (time.perf_counter() - t0) / reps * 1e6

    got, us = timed(ops.embedding_lookup, table, ids)
    err = float(jnp.abs(got - ref.embedding_lookup(table, ids)).max())
    _row("kernel/embedding_lookup_1024x128", us, f"max_err={err:.1e}")

    z = jax.random.normal(key, (1024, 128))
    n = jax.random.uniform(key, (1024, 128)) * 4
    g = jax.random.normal(key, (1024, 128))
    got, us = timed(ops.ftrl_row_update, z, n, g)
    want = ref.ftrl_row_update(z, n, g, alpha=0.05, beta=1.0, l1=1.0, l2=1.0)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(got, want))
    _row("kernel/ftrl_row_update_1024x128", us, f"max_err={err:.1e}")

    x = jax.random.normal(key, (1024, 128))
    (q, s), us = timed(lambda v: ops.quantize_rows(v), x)
    _row("kernel/quantize_rows_1024x128", us,
         f"compression=4x wire_bytes={q.nbytes + s.nbytes}")

    if not quick:
        qq = jax.random.normal(key, (1, 8, 256, 128))
        kk = jax.random.normal(key, (1, 2, 256, 128))
        vv = jax.random.normal(key, (1, 2, 256, 128))
        got, us = timed(ops.flash_attention, qq, kk, vv, reps=1)
        err = float(jnp.abs(got - ref.flash_attention(qq, kk, vv)).max())
        _row("kernel/flash_attention_256", us, f"max_err={err:.1e}")

        qd = jax.random.normal(key, (2, 8, 128))
        kd = jax.random.normal(key, (2, 1024, 2, 128))
        vd = jax.random.normal(key, (2, 1024, 2, 128))
        lens = jnp.array([800, 1024], jnp.int32)
        got, us = timed(ops.decode_attention, qd, kd, vd, lens, reps=1)
        err = float(jnp.abs(got - ref.decode_attention(qd, kd, vd,
                                                       lens)).max())
        _row("kernel/decode_attention_1024", us, f"max_err={err:.1e}")


# ---------------------------------------------------------------------------
# 8. Full-model sync engine bandwidth (the LM-zoo application of the
#    paper's mechanism): bytes/flush per codec + expert granularity
# ---------------------------------------------------------------------------


def bench_model_sync(quick: bool) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.core.sync_engine import ModelSyncEngine, SyncConfig
    from repro.training import init_train_state, make_train_step

    cfg = reduced(get_config("granite-moe-3b-a800m"))
    step = make_train_step(cfg)
    rng = np.random.default_rng(0)
    for codec in ("cast16", "int8"):
        st = init_train_state(cfg, jax.random.PRNGKey(0))
        engine = ModelSyncEngine(cfg, st.params, SyncConfig(
            gather_mode="period", period=1.0, codec=codec))
        t0 = time.perf_counter()
        steps = 4 if quick else 8
        for t in range(steps):
            tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                 jnp.int32)
            st, metrics = step(st, {"tokens": tokens})
            engine.collect_step(np.asarray(tokens), {
                "expert_counts_per_layer": jax.tree.map(
                    np.asarray, metrics["expert_counts_per_layer"])})
            engine.tick(st.params, now=float(t))
        engine.tick(st.params, now=1e9)
        us = (time.perf_counter() - t0) / steps * 1e6
        m = engine.metrics()
        stale = engine.replicas[0].staleness(st.params)
        _row(f"model_sync/{codec}", us,
             f"bytes={m['pushed_bytes']} dedup={m['dedup_ratio']:.2f} "
             f"staleness={stale:.1e}")


BENCHES = [
    ("deploy_latency", bench_deploy_latency),
    ("dedup_ratio", bench_dedup_ratio),
    ("codec_bandwidth", bench_codec_bandwidth),
    ("fault_tolerance", bench_fault_tolerance),
    ("downgrade", bench_downgrade),
    ("ps_throughput", bench_ps_throughput),
    ("kernels", bench_kernels),
    ("model_sync", bench_model_sync),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if args.only and args.only != name:
            continue
        fn(args.quick)


if __name__ == "__main__":
    main()
