"""Serving-plane benchmark: the predict path (paper's symmetric serving
side), measured against the seed's per-request, per-group, per-shard
masked loop (kept here verbatim as ``SeedServePath``).

Legs:
  * pull_stage    — ``serve_rows`` only at the 65k-id request size: seed
    masked loop vs the vectorized path cold (cache cleared) and warm
    (serve-cache hits skip the shard pull entirely).
  * predict_stage — the acceptance leg: end-to-end predict QPS and
    p50/p99 latency over a rotating steady-state request set at 65k ids
    per request (B=2048 × F=32), seed vs serving subsystem; also a
    Zipfian variant (heavy within-request duplication — the regime most
    favourable to the seed's unique-space loop) for honesty.
  * cache_sweep   — hit-rate sweep: requests mix a cache-resident hot
    pool with always-cold ids at several hot fractions; reports the
    measured hit rate and ms/request at each point.
  * bucket_sweep  — micro-batching scheduler: mixed request sizes
    through different bucket ladders; latency, padding fraction, and
    the number of compiled bucket shapes.
  * dense_stage   — DNN: the seed re-pulled + re-reshaped every dense
    tensor per predict; the serving plane memoizes by sync version
    (``DenseCache``) — ms/request and refresh counts.
  * bit_equal     — consistency gate: on a live training cluster, after
    EVERY sync_tick the cached serve reads must equal direct replica
    reads bit-for-bit (stream-driven invalidation).

Timing uses best-of-``--reps`` (the ``timeit`` convention).

Run:  PYTHONPATH=src python benchmarks/serve_path.py [--smoke]
Emits BENCH_serve_path.json (or --out PATH).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np


# ---------------------------------------------------------------------------
# Baseline: the seed serving plane, verbatim (WeiPSCluster.serve_rows /
# _serve_dense / predict before the serving subsystem existed).
# ---------------------------------------------------------------------------
class SeedServePath:
    """Per-group × per-shard masked lookups, per-request jit dispatch,
    dense re-pull + re-reshape on every predict."""

    def __init__(self, cl):
        from repro.models import ctr as ctr_model
        self.cl = cl
        self.ctr = ctr_model
        self._predict = ctr_model.predict_fn(cl.cfg)
        self.dense_pulls = 0

    def serve_rows(self, ids):
        cl = self.cl
        b, f = ids.shape
        flat = ids.reshape(-1)
        uniq, inverse = np.unique(flat, return_inverse=True)
        owner = cl.plan.slave_shard(uniq)
        rows = {}
        for group, dim in cl.groups.items():
            vals = np.zeros((len(uniq), dim), np.float32)
            for sid in range(cl.ccfg.num_slave):
                mask = owner == sid
                if mask.any():
                    vals[mask] = cl.replica_sets[sid].lookup(
                        group, uniq[mask])
            rows[group] = vals[inverse].reshape(b, f, dim)
        return rows

    def _serve_dense(self):
        if not self.cl.dense:
            return {}
        out = {}
        rep = self.cl.replica_sets[0].healthy()[0]
        for name, shape in self.ctr.dense_shapes(self.cl.cfg).items():
            v = rep.dense.get(name)
            out[name] = (v.reshape(shape) if v is not None
                         else np.zeros(shape, np.float32))
            self.dense_pulls += 1
        return out

    def predict(self, ids):
        import jax.numpy as jnp
        rows = self.serve_rows(ids)
        dense = self._serve_dense()
        return np.asarray(self._predict(
            {k: jnp.asarray(v) for k, v in rows.items()},
            {k: jnp.asarray(v) for k, v in dense.items()}))


def best_of(fn, reps: int) -> float:
    fn()                                              # warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def populate(cl, pool: np.ndarray, rng) -> None:
    """Install FTRL-trained-looking rows for every pool id on the masters
    and stream them to the slaves (one sync tick)."""
    for mid, mids in cl.plan.split_by_master(pool).items():
        for i in range(0, len(mids), 65536):
            chunk = mids[i:i + 65536]
            for g, dim in cl.groups.items():
                cl.masters[mid].apply_batch(
                    g, chunk,
                    rng.normal(size=(len(chunk), dim)).astype(np.float32))
    cl.sync_tick(0.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=262_144,
                    help="populated PS rows (the request pool)")
    ap.add_argument("--batch", type=int, default=2048,
                    help="examples per request (batch × fields = the "
                         "65k-id request size of the acceptance criterion)")
    ap.add_argument("--requests", type=int, default=16,
                    help="distinct requests in the rotating steady-state "
                         "set of the predict leg")
    ap.add_argument("--slaves", type=int, default=4)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_serve_path.json")
    args = ap.parse_args()
    if args.smoke:
        args.rows = min(args.rows, 65_536)
        args.batch = min(args.batch, 512)
        args.requests = 4
        args.reps = 2

    from repro.configs.weips_ctr import DNN_ADAM, FM_FTRL
    from repro.core import ClusterConfig, WeiPSCluster
    from repro.data import ClickStream

    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(FM_FTRL, ftrl_l1=0.01, ftrl_alpha=0.2)
    cl = WeiPSCluster(cfg, ClusterConfig(
        num_master=2, num_slave=args.slaves, num_replicas=2,
        num_partitions=2 * args.slaves))
    pool = rng.choice(1 << 40, size=args.rows,
                      replace=False).astype(np.int64)
    populate(cl, pool, rng)
    seed = SeedServePath(cl)
    scn = cl.serving.scenario()
    B, F = args.batch, cfg.fields
    req_ids = B * F

    results: dict[str, dict] = {}

    # -- pull stage: serve_rows only ---------------------------------------
    r = pool[rng.integers(0, args.rows, size=(B, F))]

    def vec_cold():
        scn.cache.clear()
        cl.serve_rows(r)

    cl.serve_rows(r)                          # warm the cache
    t_seed = best_of(lambda: seed.serve_rows(r), args.reps)
    t_warm = best_of(lambda: cl.serve_rows(r), args.reps)
    t_cold = best_of(vec_cold, max(1, args.reps // 2))
    results["pull_stage"] = {
        "request_ids": req_ids,
        "seed_loop_rows_per_sec": req_ids / t_seed,
        "vectorized_cold_rows_per_sec": req_ids / t_cold,
        "cached_warm_rows_per_sec": req_ids / t_warm,
        "warm_speedup_vs_seed": t_seed / t_warm,
        "cold_speedup_vs_seed": t_seed / t_cold,
    }

    # -- predict stage (acceptance leg) ------------------------------------
    def predict_leg(reqs, path):
        lat, cycles = [], []
        for _ in range(max(2, args.reps)):
            t0 = time.perf_counter()
            for q in reqs:
                t1 = time.perf_counter()
                path(q)
                lat.append(time.perf_counter() - t1)
            cycles.append(time.perf_counter() - t0)
        lat = np.array(lat[len(reqs):])       # drop the first (cold) cycle
        # QPS from the best full cycle (the timeit convention — this VM's
        # timings are very noisy); percentiles over the whole steady run
        return {
            "qps": len(reqs) * B / min(cycles[1:]),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
        }

    def concurrent_leg(reqs):
        """The serving plane under concurrent load: requests are admitted
        together and coalesced by the micro-batching scheduler — the
        seed path has no admission step and can only serve one request
        at a time, which is exactly the gap this leg measures."""
        def cycle():
            for q in reqs:
                cl.serving.submit(q)
            cl.serving.flush()
        t = best_of(cycle, max(2, args.reps))
        return {"qps": len(reqs) * B / t,
                "ms_per_cycle": t * 1e3}

    reqs = [pool[rng.integers(0, args.rows, size=(B, F))]
            for _ in range(args.requests)]
    scn.cache.clear()
    s = predict_leg(reqs, seed.predict)
    v = predict_leg(reqs, cl.predict)
    c = concurrent_leg(reqs)
    results["predict_stage"] = {
        "request_ids": req_ids, "requests": args.requests,
        "seed": s, "serving_plane_sequential": v,
        "serving_plane_concurrent": c,
        "throughput_speedup": c["qps"] / s["qps"],
        "sequential_speedup": v["qps"] / s["qps"],
        "cache_hit_rate": scn.cache.hit_rate,
    }

    # Zipfian variant: heavy within-request duplication (unique ≈ 13 % of
    # the request) — the regime most favourable to the seed's
    # unique-space loop; reported for honesty, not the headline
    zreqs = [pool[np.minimum(rng.zipf(1.2, size=(B, F)) - 1,
                             args.rows - 1)]
             for _ in range(args.requests)]
    scn.cache.clear()
    sz = predict_leg(zreqs, seed.predict)
    vz = predict_leg(zreqs, cl.predict)
    results["predict_stage_zipf"] = {
        "seed": sz, "serving_plane": vz,
        "throughput_speedup": vz["qps"] / sz["qps"],
    }

    # -- cache-hit sweep ----------------------------------------------------
    hot_pool = pool[:min(args.rows, 65_536)]
    results["cache_sweep"] = {}
    for i, hot_frac in enumerate((0.0, 0.5, 0.9, 1.0)):
        sweep_scn = cl.add_scenario(cfg, name=f"sweep-{i}")
        cl.serve_rows(hot_pool.reshape(-1, F)[:B], scenario=sweep_scn.name)
        sweep_scn.cache.hits = sweep_scn.cache.misses = 0

        def one_request():
            hot = rng.random(size=(B, F)) < hot_frac
            ids = np.where(hot, hot_pool[rng.integers(
                0, len(hot_pool), size=(B, F))],
                rng.integers(1 << 41, 1 << 42, size=(B, F)))
            cl.serve_rows(ids, scenario=sweep_scn.name)

        t = best_of(one_request, max(1, args.reps // 2))
        results["cache_sweep"][f"hot_{hot_frac}"] = {
            "ms_per_request": t * 1e3,
            "rows_per_sec": req_ids / t,
            "hit_rate": sweep_scn.cache.hit_rate,
        }

    # -- bucket sweep -------------------------------------------------------
    sizes = [37, 173, 700, min(1500, B)]
    results["bucket_sweep"] = {}
    for ladder in ((4096,), (256, 2048), (64, 128, 256, 512, 1024,
                                          2048, 4096)):
        from repro.serving import PredictScheduler
        sched = PredictScheduler(
            lambda ids, bucket: cl.serving._run_bucket(scn, ids, bucket),
            buckets=ladder)
        mixed = [pool[rng.integers(0, args.rows, size=(n, F))]
                 for n in sizes]

        def run_mixed():
            for q in mixed:
                sched.run_one(q)

        t = best_of(run_mixed, max(1, args.reps // 2))
        results["bucket_sweep"][str(list(ladder))] = {
            "ms_per_mixed_cycle": t * 1e3,
            "padding_fraction": sched.stats.padding_fraction,
            "compiled_bucket_shapes": len(sched.stats.bucket_counts),
        }

    # -- dense stage (DNN: version-memoized dense vs per-predict re-pull) --
    dnn = dataclasses.replace(DNN_ADAM, fields=8, embed_dim=8,
                              dnn_hidden=(32,))
    cld = WeiPSCluster(dnn, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=1, num_partitions=4))
    stream = ClickStream(feature_space=1 << 14, fields=dnn.fields, seed=1)
    for i in range(5):
        ids, y = stream.batch(256)
        cld.train_on_batch(ids, y, now=float(i))
        cld.sync_tick(float(i))
    seed_d = SeedServePath(cld)
    rd = stream.batch(512)[0]
    t_sd = best_of(lambda: seed_d.predict(rd), args.reps)
    t_vd = best_of(lambda: cld.predict(rd), args.reps)
    dc = cld.serving.scenario().dense_cache
    results["dense_stage"] = {
        "seed_ms_per_predict": t_sd * 1e3,
        "serving_plane_ms_per_predict": t_vd * 1e3,
        "speedup": t_sd / t_vd,
        "seed_dense_pulls": seed_d.dense_pulls,
        "dense_cache_refreshes": dc.refreshes,
        "dense_cache_hits": dc.hits,
    }

    # -- device-resident predict (pallas backend) ---------------------------
    # The fused serving path end to end: ``pull_request`` answers warm
    # requests with the cache's combined-group arena block as a DEVICE
    # array, ``_run_bucket`` pads it on device, and the jitted predict
    # consumes it — no host-numpy materialization between pull and
    # predict (``device_blocks`` counts exactly those pulls). Interpret
    # mode on CPU is slow per call, so this leg runs at a reduced request
    # size; the gates are parity with the numpy path (bit-equal cold and
    # warm) and ``device_blocks`` > 0, with the warm ms/predict recorded
    # for the trajectory.
    Bd = 16 if args.smoke else 48
    dpool = np.unique(rng.choice(1 << 40, size=2048).astype(np.int64))
    dreq = dpool[rng.integers(0, len(dpool), size=(Bd, F))]
    dev_predict: dict[str, np.ndarray] = {}
    dev_leg: dict = {}
    for backend in ("numpy", "pallas"):
        clb2 = WeiPSCluster(cfg, ClusterConfig(
            num_master=1, num_slave=2, num_replicas=1, num_partitions=2,
            ps_backend=backend))
        populate(clb2, dpool, np.random.default_rng(7))
        cold = np.asarray(clb2.predict(dreq))         # fills the cache
        warm = np.asarray(clb2.predict(dreq))
        dev_predict[backend] = np.stack([cold, warm])
        if backend == "pallas":
            t_dev = best_of(lambda: clb2.predict(dreq),
                            max(2, args.reps // 2))
            mm = clb2.sync_metrics(0.0)["device_mirror"]
            dev_leg = {
                "request_ids": Bd * F,
                "warm_ms_per_predict": t_dev * 1e3,
                "device_blocks": clb2.serving.device_blocks,
                "cache_hit_rate": clb2.serving.scenario().cache.hit_rate,
                "mirror_key_bytes_uploaded": mm["key_bytes_uploaded"],
                "mirror_incremental_uploads":
                    mm["key_incremental_uploads"],
                "note": "interpret mode on CPU — the leg demonstrates the "
                        "device-resident block path (pull→pad→predict "
                        "with no host numpy hop), gated on bit-equality "
                        "with the numpy backend",
            }
    dev_leg["predict_bit_equal_numpy"] = bool(
        np.array_equal(dev_predict["numpy"], dev_predict["pallas"]))
    results["device_predict"] = dev_leg

    # -- bit-equality gate: cached reads == direct replica reads ------------
    clb = WeiPSCluster(cfg, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=2, num_partitions=4))
    stream = ClickStream(feature_space=1 << 10, fields=cfg.fields, seed=2)
    eval_ids, _ = stream.batch(64)
    ok = True
    for i in range(5):
        ids, y = stream.batch(64)
        clb.train_on_batch(ids, y, now=float(i))
        clb.sync_tick(float(i))
        got = clb.serve_rows(eval_ids)
        flat = eval_ids.reshape(-1)
        owner = clb.plan.slave_shard(flat)
        for g, dim in clb.groups.items():
            direct = np.zeros((len(flat), dim), np.float32)
            for sid in range(2):
                m = owner == sid
                direct[m] = clb.replica_sets[sid].replicas[0].lookup(
                    g, flat[m])
            ok = ok and bool(np.array_equal(
                got[g].reshape(-1, dim), direct))
    results["cache_bit_equal_after_sync"] = ok

    out = {
        "config": {"rows": args.rows, "batch": args.batch,
                   "fields": F, "request_ids": req_ids,
                   "requests": args.requests, "slaves": args.slaves,
                   "reps": args.reps, "smoke": args.smoke},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"\npredict-path throughput vs seed loop: "
          f"{results['predict_stage']['throughput_speedup']:.2f}x "
          f"(hit rate {results['predict_stage']['cache_hit_rate']:.2f}); "
          f"cold pull: {results['pull_stage']['cold_speedup_vs_seed']:.2f}x; "
          f"warm pull: {results['pull_stage']['warm_speedup_vs_seed']:.1f}x; "
          f"bit-equal after sync: {results['cache_bit_equal_after_sync']}; "
          f"device predict blocks: "
          f"{results['device_predict']['device_blocks']} "
          f"(bit-equal: "
          f"{results['device_predict']['predict_bit_equal_numpy']})")


if __name__ == "__main__":
    main()
