"""Streaming-sync-plane benchmark: the collect→gather→push→scatter spine
(paper §4.1), measured stage by stage.

Legs:
  * push_stage — the acceptance leg: rows/sec through ``Pusher.push`` at
    the 65k-id record size, vectorized (ONE gather + ONE encode + argsort
    partition routing) vs the pre-refactor per-partition/per-chunk loop,
    which is kept here verbatim (``SeedLoopPusher``) as the reference
    point for the recorded speedup.
  * scatter_stage — batched ``Scatter.poll`` (one ownership filter + one
    coalesced table scatter per group) vs the per-record apply loop.
  * codecs — identity / cast16 / int8 wire bytes and push throughput at
    the same record size (int8 is the delta-codec path: ~4x payload
    reduction vs identity fp32).
  * backends — numpy vs pallas(interpret) int8 codec through the
    ``kernels/delta_codec.py`` kernel (small block: interpret mode runs
    grid steps in Python; TPU is the real measurement) + bit-equivalence.
  * gather_modes — realtime / threshold / period trigger sweep over a
    Zipfian update stream through ``SyncPipeline``: dedup ratio (the
    paper's ≥90 % repetition effect), sync lag, pushed bytes.

Timing uses best-of-``--reps`` (the ``timeit`` convention: the minimum
measures the code, not scheduler/VM noise).

Run:  PYTHONPATH=src python benchmarks/sync_path.py
      [--rows 262144 --push-ids 65536 --dim 64 --parts 32 --quick]
Emits BENCH_sync_path.json (or --out PATH).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


# ---------------------------------------------------------------------------
# Baseline: the pre-refactor Pusher.push body (verbatim semantics: python
# loop over split_by_partition's boolean masks, per-chunk gather + encode)
# and the per-record Scatter.poll apply loop.
# ---------------------------------------------------------------------------
class SeedTransform:
    """The pre-refactor identity transform, verbatim: eager-jnp
    ``serve_values`` on every encode call (no numpy fast path, no
    cache blocking, no backend switch) — what the pre-refactor loop
    actually ran per partition chunk."""

    name = "identity"

    def __init__(self, optimizer):
        self.optimizer = optimizer

    def serve_values(self, w, slots):
        if self.optimizer is not None:
            import jax.numpy as jnp
            return np.asarray(self.optimizer.serve_weights(
                jnp.asarray(w),
                {k: jnp.asarray(v) for k, v in slots.items()}))
        return w

    def encode(self, w, slots):
        return {"values": self.serve_values(w, slots).astype(np.float32)}


def _seed_gather(table, ids):
    """Pre-refactor ``SparseTable.gather`` (create=False), verbatim:
    unconditional missing-row masking — one np.where allocation+pass per
    fetched column even when every id exists."""
    sl = table.lookup(ids)
    ok = sl >= 0
    safe = np.where(ok, sl, 0)
    w = table._fetch(table._w, safe)
    w = np.where(ok[:, None], w, np.zeros((), dtype=table.dtype))
    slots = {}
    for n in table.slot_names:
        v = table._fetch(table._slots[n], safe)
        slots[n] = np.where(ok[:, None], v, np.float32(0.0))
    return w, slots


def _seed_nbytes(rec) -> int:
    """Pre-refactor ``Record.nbytes``: a fresh pickle of the payload on
    every call (it was called twice per record — pusher accounting and
    queue accounting)."""
    import pickle
    try:
        pay = len(pickle.dumps(rec.payload, protocol=4))
    except Exception:
        pay = 0
    return int(rec.ids.nbytes + pay + 64)


class SeedLoopPusher:
    def __init__(self, shard, queue, plan, transform,
                 max_ids_per_record: int = 65536):
        self.shard = shard
        self.queue = queue
        self.plan = plan
        self.transform = transform
        self.max_ids_per_record = max_ids_per_record
        self._seq: dict[str, int] = {}
        self.pushed_bytes = 0

    def _next_seq(self, group):
        s = self._seq.get(group, -1) + 1
        self._seq[group] = s
        return s

    def push(self, gathered, now=0.0):
        from repro.core.queue import Record
        n_rec = 0
        for (group, op), ids in gathered.items():
            table = self.shard.tables[group]
            seq = self._next_seq(group)
            by_part = self.plan.split_by_partition(ids)
            for part, part_ids in by_part.items():
                for i in range(0, len(part_ids), self.max_ids_per_record):
                    chunk = part_ids[i:i + self.max_ids_per_record]
                    if op == "delete":
                        payload = {}
                    else:
                        w, slots = _seed_gather(table, chunk)
                        payload = self.transform.encode(w, slots)
                    rec = Record(group=group, op=op, ids=chunk,
                                 payload=payload, seq=seq,
                                 producer=self.shard.shard_id,
                                 meta={"codec": self.transform.name,
                                       "t": now})
                    self.queue.produce(int(part), rec)
                    _seed_nbytes(rec)            # queue-side pickle
                    self.pushed_bytes += _seed_nbytes(rec)
                    n_rec += 1
        return n_rec


def seed_loop_poll(shard, consumer, plan):
    """Pre-refactor scatter: per-record ownership filter + apply."""
    from repro.core.queue import Record
    from repro.core.streaming import _filter_payload
    n = 0
    for rec in consumer.poll():
        if not rec.group.startswith("dense/"):
            owner = plan.slave_shard(rec.ids)
            keep = owner == shard.shard_id
            if not keep.all():
                rec = Record(group=rec.group, op=rec.op, ids=rec.ids[keep],
                             payload=_filter_payload(rec.payload, keep),
                             seq=rec.seq, producer=rec.producer,
                             meta=rec.meta)
        if shard.apply(rec):
            n += 1
    return n


def best_of(fn, reps: int) -> float:
    fn()                                              # warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=262_144)
    ap.add_argument("--push-ids", type=int, default=65_536,
                    help="unique ids per push flush (the 65k-id record "
                         "size of the acceptance criterion)")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--parts", type=int, default=32)
    ap.add_argument("--slaves", type=int, default=4)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--pallas-rows", type=int, default=4096,
                    help="row count for the pallas-interpret codec leg")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_sync_path.json")
    args = ap.parse_args()
    if args.quick:
        args.rows = min(args.rows, 65_536)
        args.push_ids = min(args.push_ids, 16_384)
        args.reps = 2

    from repro.core.ps import MasterShard, SlaveShard
    from repro.core.queue import Consumer, PartitionedQueue
    from repro.core.routing import RoutingPlan
    from repro.core.streaming import Pusher, Scatter, SyncPipeline
    from repro.core.transform import make_transform
    from repro.optim import get_optimizer

    rng = np.random.default_rng(0)
    plan = RoutingPlan(1, args.slaves, args.parts)
    opt = get_optimizer("ftrl")
    ids = rng.choice(1 << 40, size=args.rows, replace=False).astype(np.int64)

    def populate(dim):
        """Master with FTRL training state (w + z,n rows) for every id."""
        m = MasterShard(0, {"w": dim}, opt)
        g = rng.normal(size=(4096, dim)).astype(np.float32)
        for i in range(0, args.rows, 4096):
            b = ids[i:i + 4096]
            m.apply_batch("w", b, g[:len(b)])
        return m

    push_ids = np.sort(rng.choice(ids, size=args.push_ids, replace=False))
    gathered = {("w", "upsert"): push_ids}

    results: dict[str, dict] = {}

    # -- push stage: seed loop vs vectorized (the acceptance leg) ----------
    # Swept over the paper's model zoo row dims (§4.1.2): LR dim 1 (the
    # flagship CTR config), FM dim 16, DNN dim 64. The seed loop's
    # per-chunk eager-JAX dispatch is size-independent, so the win is
    # largest on the skinny rows online CTR actually serves.
    transform = make_transform("identity", opt)
    seed_transform = SeedTransform(opt)
    results["push_stage"] = {
        "push_ids": args.push_ids, "partitions": args.parts, "by_dim": {}}
    for dim in (1, 16, args.dim):
        master = populate(dim)

        def run_seed():
            SeedLoopPusher(master, PartitionedQueue(args.parts), plan,
                           seed_transform).push(gathered, now=0.0)

        def run_vec():
            Pusher(master, PartitionedQueue(args.parts), plan,
                   transform).push(gathered, now=0.0)

        t_seed = best_of(run_seed, max(1, args.reps // 2))
        t_vec = best_of(run_vec, args.reps)
        results["push_stage"]["by_dim"][str(dim)] = {
            "seed_loop_rows_per_sec": args.push_ids / t_seed,
            "vectorized_rows_per_sec": args.push_ids / t_vec,
            "speedup": t_seed / t_vec,
        }
    results["push_stage"]["speedup"] = \
        results["push_stage"]["by_dim"]["16"]["speedup"]    # FM default

    # master for the remaining legs (DNN-width rows)
    master = populate(args.dim)

    # -- scatter stage: per-record apply loop vs batched apply_batch -------
    q = PartitionedQueue(args.parts)
    Pusher(master, q, plan, transform).push(gathered, now=0.0)

    def run_seed_scatter():
        shard = SlaveShard(0, {"w": args.dim})
        seed_loop_poll(shard, Consumer(q, plan.partitions_for_slave(0)),
                       plan)

    def run_vec_scatter():
        shard = SlaveShard(0, {"w": args.dim})
        Scatter(shard, q, plan).poll()

    t_sseed = best_of(run_seed_scatter, max(1, args.reps // 2))
    t_svec = best_of(run_vec_scatter, args.reps)
    slave_rows = int(np.sum(plan.slave_shard(push_ids) == 0))
    results["scatter_stage"] = {
        "rows": slave_rows,
        "seed_loop_rows_per_sec": slave_rows / t_sseed,
        "batched_rows_per_sec": slave_rows / t_svec,
        "speedup": t_sseed / t_svec,
    }

    # -- codec sweep: wire bytes + throughput at the same record size ------
    results["codecs"] = {}
    for codec in ("identity", "cast16", "int8"):
        tr = make_transform(codec, opt)
        qq = PartitionedQueue(args.parts)
        pusher = Pusher(master, qq, plan, tr)
        t = best_of(lambda p=pusher: p.push(gathered, now=0.0),
                    max(1, args.reps // 2))
        w, slots = master.tables["w"].gather(push_ids)
        payload = tr.payload_bytes(tr.encode(w, slots))
        results["codecs"][codec] = {
            "rows_per_sec": args.push_ids / t,
            "pushed_bytes_per_flush": pusher.pushed_bytes
            // (1 + max(1, args.reps // 2)),       # warm-up + reps pushes
            "payload_bytes_per_row": payload / args.push_ids,
        }
    ident = results["codecs"]["identity"]["payload_bytes_per_row"]
    int8 = results["codecs"]["int8"]["payload_bytes_per_row"]
    results["codecs"]["int8_payload_compression_vs_identity"] = ident / int8
    results["codecs"]["int8_wire_compression_vs_identity"] = (
        results["codecs"]["identity"]["pushed_bytes_per_flush"]
        / results["codecs"]["int8"]["pushed_bytes_per_flush"])

    # -- backend sweep: numpy vs pallas(interpret) int8 codec --------------
    blk = push_ids[:args.pallas_rows]
    w, slots = master.tables["w"].gather(blk)
    results["backends"] = {}
    for backend in ("numpy", "pallas"):
        tr = make_transform("int8", opt, backend=backend)
        t = best_of(lambda tr=tr: tr.encode(w, slots), 2)
        results["backends"][backend] = {
            "rows": len(blk),
            "encode_rows_per_sec": len(blk) / t,
        }
    enc_np = make_transform("int8", opt, backend="numpy").encode(w, slots)
    enc_pl = make_transform("int8", opt, backend="pallas").encode(w, slots)
    results["backends"]["bit_equivalent"] = bool(
        np.array_equal(enc_np["q"], enc_pl["q"])
        and np.allclose(enc_np["scale"], enc_pl["scale"], rtol=1e-7))
    results["backends"]["note"] = (
        "interpret mode runs grid steps in Python; on TPU the same call "
        "compiles to a Mosaic VMEM-resident quantize pass")

    # -- gather-mode sweep: Zipfian stream, dedup + lag --------------------
    results["gather_modes"] = {}
    grads = rng.normal(size=(4096, args.dim)).astype(np.float32)
    zipf_ids = ids[np.minimum(rng.zipf(1.3, size=(50, 4096)) - 1,
                              args.rows - 1)]
    for mode in ("realtime", "threshold", "period"):
        m = MasterShard(0, {"w": args.dim}, opt)
        pipe = SyncPipeline(
            m, [SlaveShard(i, {"w": args.dim}) for i in range(args.slaves)],
            PartitionedQueue(args.parts), plan,
            make_transform("int8", opt), gather_mode=mode,
            threshold=16_384, period=1.0)
        t0 = time.perf_counter()
        for step in range(zipf_ids.shape[0]):
            b = zipf_ids[step]
            m.apply_batch("w", b, grads[:len(b)])
            pipe.tick(now=step * 0.1)
        pipe.tick(now=zipf_ids.shape[0] * 0.1)         # drain
        wall = time.perf_counter() - t0
        met = pipe.metrics(now=zipf_ids.shape[0] * 0.1)
        results["gather_modes"][mode] = {
            "dedup_ratio": met.dedup_ratio,
            "sync_lag_seconds": met.sync_lag_seconds,
            "pushed_bytes": met.pushed_bytes,
            "records": pipe.pusher.pushed_records,
            "wall_seconds": wall,
        }

    out = {
        "config": {"rows": args.rows, "push_ids": args.push_ids,
                   "dim": args.dim, "partitions": args.parts,
                   "slaves": args.slaves, "reps": args.reps,
                   "optimizer": "ftrl", "quick": args.quick},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"\npush-stage speedup over pre-refactor loop: "
          f"{results['push_stage']['speedup']:.1f}x; scatter-stage: "
          f"{results['scatter_stage']['speedup']:.1f}x; int8 payload "
          f"compression: "
          f"{results['codecs']['int8_payload_compression_vs_identity']:.2f}x")


if __name__ == "__main__":
    main()
