"""Training-plane benchmark: the online ingest→update path (the paper's
§1.1–1.2 pipeline), measured against the seed's per-event dict+heap
joiner (kept here verbatim as ``SeedSampleJoiner``) and per-batch Python
event loop.

Legs:
  * joiner_stage  — the acceptance leg: events/s through the sample
    joiner at 65k-event batches (exposures + delayed feedback + drain),
    seed per-event loop vs the vectorized columnar joiner, plus a
    sample-equivalence gate (same ids/labels/order on identical input).
  * dedup_sweep   — per-batch id dedup/coalesce across Zipf skews: the
    paper's ≥90 % update-repetition claim measured as the ratio of raw
    to unique ids per train batch, with the train-step latency it saves.
  * bucket_ladder — ingest→update latency through the TrainPipeline for
    mixed drain sizes under different pow2 bucket ladders: compiled
    shape count, padding fraction, ms per flush.
  * window_sweep  — the timeliness vs model-effect trade-off: join
    window length vs captured-positive fraction, join-delay p50/p99 and
    late feedback, including the emit-on-feedback fast path.

Timing uses best-of-``--reps`` (the ``timeit`` convention).

Run:  PYTHONPATH=src python benchmarks/train_path.py [--smoke]
Emits BENCH_train_path.json (or --out PATH).
"""

from __future__ import annotations

import argparse
import heapq
import json
import time

import numpy as np


# ---------------------------------------------------------------------------
# Baseline: the seed per-event joiner, verbatim (data/joiner.py before the
# vectorized rewrite) — including its event-object interface: tuple
# feature ids in, per-sample ndarray conversion out.
# ---------------------------------------------------------------------------
class SeedSampleJoiner:
    """Event-time window join over exposure + feedback streams."""

    def __init__(self, window: float = 30.0):
        self.window = window
        self._pending: dict[int, tuple] = {}       # vid -> (t, feature tuple)
        self._labels: dict[int, float] = {}
        self._expiry: list[tuple[float, int]] = []    # heap (deadline, view)
        self.late_feedback = 0
        self.emitted = 0

    def offer_exposure(self, t: float, view_id: int,
                       feature_ids: tuple) -> None:
        self._pending[view_id] = (t, feature_ids)
        heapq.heappush(self._expiry, (t + self.window, view_id))

    def offer_feedback(self, t: float, view_id: int,
                       label: float = 1.0) -> None:
        if view_id in self._pending:
            self._labels[view_id] = label
        else:
            self.late_feedback += 1

    def drain(self, now: float) -> list[tuple]:
        out = []
        while self._expiry and self._expiry[0][0] <= now:
            deadline, vid = heapq.heappop(self._expiry)
            ev = self._pending.pop(vid, None)
            if ev is None:
                continue
            label = self._labels.pop(vid, 0.0)
            out.append((vid, np.asarray(ev[1], dtype=np.int64), label,
                        now - ev[0]))
            self.emitted += 1
        return out


def best_of(fn, reps: int) -> float:
    fn()                                              # warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=65_536,
                    help="events per joiner batch (the acceptance size)")
    ap.add_argument("--steps", type=int, default=40,
                    help="pipeline steps for the sweep legs")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_train_path.json")
    args = ap.parse_args()
    if args.smoke:
        args.events = min(args.events, 8192)
        args.steps = 8
        args.reps = 2

    from repro.configs.weips_ctr import FM_FTRL
    from repro.core import ClusterConfig, WeiPSCluster
    from repro.data import ClickStream, SampleJoiner

    rng = np.random.default_rng(0)
    results: dict[str, dict] = {}
    N = args.events
    F = 16

    # -- joiner stage (acceptance leg) --------------------------------------
    stream = ClickStream(feature_space=1 << 18, fields=F, zipf_a=1.2,
                         seed=0)
    feats, y = stream.batch(N)
    vids = np.arange(N, dtype=np.int64)
    pos = np.flatnonzero(y > 0)
    fb_t = 1.0 + rng.exponential(3.0, size=len(pos))
    # the seed's native input: per-event tuples (what ClickStream.events
    # produced) — built OUTSIDE the timed cycle, as the vectorized arrays
    # are for the other side
    feat_tuples = [tuple(row) for row in feats.tolist()]

    # steady-state streaming: ONE long-lived joiner per side (the
    # production regime — arena/map growth amortized away), each timed
    # cycle pushes N exposures + feedback at an advancing clock and
    # drains the previous cycle's expired window
    seed_j = SeedSampleJoiner(window=10.0)
    vec_j = SampleJoiner(window=10.0)
    clock = {"seed": 0.0, "vec": 0.0}

    def seed_cycle():
        t = clock["seed"]
        base = int(t) * N                    # fresh vids per cycle
        for i in range(N):
            j = seed_j
            j.offer_exposure(t, base + int(vids[i]), feat_tuples[i])
        for k, i in enumerate(pos):
            seed_j.offer_feedback(t + float(fb_t[k]), base + int(vids[i]))
        clock["seed"] = t + 20.0
        return seed_j.drain(clock["seed"])

    def vec_cycle():
        t = clock["vec"]
        base = int(t) * N
        vec_j.offer_exposures(t, base + vids, feats)
        vec_j.offer_feedbacks(t + fb_t, base + vids[pos])
        clock["vec"] = t + 20.0
        return vec_j.drain_batch(clock["vec"])

    t_seed = best_of(seed_cycle, max(2, args.reps // 2))
    t_vec = best_of(vec_cycle, args.reps)

    # sample-equivalence gate: fresh joiners, identical input, same
    # vids/labels/features in the same emission order
    gate_seed = SeedSampleJoiner(window=10.0)
    for i in range(N):
        gate_seed.offer_exposure(0.0, int(vids[i]), feat_tuples[i])
    for k, i in enumerate(pos):
        gate_seed.offer_feedback(float(fb_t[k]), int(vids[i]))
    want = gate_seed.drain(20.0)
    gate_vec = SampleJoiner(window=10.0)
    gate_vec.offer_exposures(0.0, vids, feats)
    gate_vec.offer_feedbacks(fb_t, vids[pos])
    got = gate_vec.drain_batch(20.0)
    equal = len(want) == len(got) and all(
        w[0] == int(got.view_ids[k]) and w[2] == float(got.labels[k])
        and np.array_equal(w[1], got.feature_ids[k])
        for k, w in enumerate(want))

    results["joiner_stage"] = {
        "events": N,
        "seed_events_per_sec": N / t_seed,
        "vectorized_events_per_sec": N / t_vec,
        "speedup": t_seed / t_vec,
        "sample_equivalent": bool(equal),
    }

    # -- dedup/coalesce sweep ----------------------------------------------
    results["dedup_sweep"] = {}
    for zipf_a in (1.05, 1.2, 1.4):
        cl = WeiPSCluster(FM_FTRL, ClusterConfig(
            num_master=2, num_slave=2, num_replicas=1, num_partitions=4))
        s = ClickStream(feature_space=1 << 18, fields=FM_FTRL.fields,
                        zipf_a=zipf_a, seed=1)
        scn = cl.training.scenario()
        batch = min(2048, max(256, N // 32))
        ids, yy = s.batch(batch)
        cl.train_on_batch(ids, yy, now=0.0)      # compile outside timing

        def step():
            ids, yy = s.batch(batch)
            cl.train_on_batch(ids, yy, now=0.0)

        t = best_of(step, max(2, args.reps // 2))
        results["dedup_sweep"][f"zipf_{zipf_a}"] = {
            "batch": batch,
            "dedup_ratio": scn.stats.dedup_ratio,
            "ms_per_step": t * 1e3,
            "examples_per_sec": batch / t,
        }

    # -- bucket-ladder ingest→update latency --------------------------------
    results["bucket_ladder"] = {}
    sizes = [37, 170, 700, 1400]
    for ladder in ((4096,), (256, 2048), (128, 256, 512, 1024, 2048, 4096)):
        cl = WeiPSCluster(FM_FTRL, ClusterConfig(
            num_master=2, num_slave=2, num_replicas=1, num_partitions=4,
            train_buckets=ladder, join_window=0.5))
        pipe = cl.make_train_pipeline()
        s = ClickStream(feature_space=1 << 16, fields=FM_FTRL.fields,
                        seed=2, feedback_delay=0.2)
        now = [0.0]

        def cycle():
            for n in sizes:
                pipe.ingest(s.events_batch(n, now[0]))
                now[0] += 1.0
                pipe.tick(now[0])
            pipe.flush(now[0] + 1.0)

        cycle()                                   # compile bucket shapes
        t = best_of(cycle, max(2, args.reps // 2))
        scn = cl.training.scenario()
        results["bucket_ladder"][str(list(ladder))] = {
            "ms_per_ingest_update_cycle": t * 1e3,
            "padding_fraction": scn.stats.padding_fraction,
            "compiled_bucket_shapes": len(scn.stats.bucket_counts),
        }

    # -- join-window timeliness sweep ---------------------------------------
    results["window_sweep"] = {}
    for window, fast in ((1.0, False), (5.0, False), (15.0, False),
                         (5.0, True)):
        j = SampleJoiner(window=window, emit_on_feedback=fast)
        s = ClickStream(feature_space=1 << 14, fields=F, seed=3,
                        feedback_delay=3.0, signal_scale=1.0)
        t, pos_n, tot, gen_pos = 0.0, 0, 0, 0
        pend_t = np.empty(0, np.float64)
        pend_v = np.empty(0, np.int64)

        def count(batch):
            nonlocal pos_n, tot
            if batch is not None and len(batch):
                pos_n += int((batch.labels > 0).sum())
                tot += len(batch)

        for _ in range(args.steps):
            ev = s.events_batch(max(64, N // 64), t)
            gen_pos += len(ev.fb_view_ids)
            j.offer_exposures(ev.t, ev.view_ids, ev.feature_ids)
            pend_t = np.concatenate([pend_t, ev.fb_t])
            pend_v = np.concatenate([pend_v, ev.fb_view_ids])
            due = pend_t <= t            # deliver matured feedback, in order
            if due.any():
                order = np.argsort(pend_t[due])
                count(j.offer_feedbacks(pend_t[due][order],
                                        pend_v[due][order]))
                pend_t, pend_v = pend_t[~due], pend_v[~due]
            count(j.drain_batch(t))
            t += 1.0
        if len(pend_v):
            count(j.offer_feedbacks(pend_t, pend_v))
        count(j.drain_batch(t + window + 1))
        key = f"window_{window}" + ("_fast" if fast else "")
        results["window_sweep"][key] = {
            "positive_fraction": pos_n / max(tot, 1),
            # model effect: how many true positives the window catches
            "captured_positive_fraction": pos_n / max(gen_pos, 1),
            "join_delay": j.join_delay_percentiles(),
            "late_feedback": j.late_feedback,
            "fast_emits": j.fast_emits,
        }

    out = {
        "config": {"events": args.events, "fields": F,
                   "steps": args.steps, "reps": args.reps,
                   "smoke": args.smoke},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    js = results["joiner_stage"]
    print(f"\njoiner throughput vs seed per-event loop: "
          f"{js['speedup']:.1f}x at {N} events "
          f"({js['vectorized_events_per_sec']/1e6:.2f}M events/s); "
          f"sample-equivalent: {js['sample_equivalent']}")


if __name__ == "__main__":
    main()
