"""Fault-tolerance choreography (paper §4.2 + §4.3): kill replicas and
master shards mid-stream, watch hot failover keep serving, partial cold
recovery restore the shard without a cluster restart, and a domino
downgrade roll the serving plane back after a poisoned update burst.

Run: PYTHONPATH=src python examples/fault_tolerance_demo.py
"""

import dataclasses
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs.weips_ctr import LR_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.data import ClickStream


def main() -> None:
    cfg = dataclasses.replace(LR_FTRL, ftrl_l1=0.01, ftrl_alpha=0.3)
    cl = WeiPSCluster(cfg, ClusterConfig(
        num_master=4, num_slave=2, num_replicas=2, num_partitions=8,
        downgrade_metric="logloss", downgrade_threshold=0.72,
        downgrade_window=3))
    stream = ClickStream(feature_space=1 << 12, fields=cfg.fields,
                         signal_scale=1.0, seed=0)

    now = 0.0

    def run(steps, label):
        nonlocal now
        for _ in range(steps):
            ids, y = stream.batch(128)
            cl.train_on_batch(ids, y, now=now)
            cl.sync_tick(now)
            now += 0.5
        print(f"[{label}] logloss={cl.validator.smoothed('logloss', 5):.4f} "
              f"auc={cl.validator.smoothed('auc', 5):.3f}")

    run(30, "warm-up")
    v_stable = cl.checkpoint(now)
    print(f"checkpointed stable version v{v_stable} "
          f"(queue offsets embedded)\n")

    # ---- 1. hot failover -------------------------------------------------
    print("== kill slave replica (0,0); serving must not fail ==")
    ids_eval, y_eval = stream.batch(512)
    p_before = cl.predict(ids_eval)
    cl.kill_slave_replica(0, 0)
    p_after = cl.predict(ids_eval)
    print(f"failed requests: 0; prediction drift after failover: "
          f"{np.abs(p_before - p_after).max():.2e} "
          f"(failovers={cl.replica_sets[0].failovers})\n")

    # ---- 2. partial cold recovery ----------------------------------------
    print("== kill master shard 2; partial recovery, no cluster restart ==")
    rows_before = len(cl.masters[2].tables['w'])
    cl.kill_master(2)
    try:
        cl.masters[2].pull("w", np.array([1]))
    except AssertionError:
        print("shard 2 down: training pulls fail (as expected)")
    v = cl.recover_master(2)
    cl.sync_tick(now)
    print(f"recovered shard 2 from v{v}: rows {rows_before} -> "
          f"{len(cl.masters[2].tables['w'])}; other shards untouched\n")
    run(10, "post-recovery")

    # ---- 3. domino downgrade ---------------------------------------------
    print("\n== adversarial shift: learned weights now predict wrongly ==")
    stream.corrupt(scale=2.0)
    for i in range(8):
        ids, y = stream.batch(128)
        cl.train_on_batch(ids, y, now=now)
        cl.sync_tick(now)
        now += 0.5
        v = cl.downgrade_check(now)
        if v is not None:
            print(f"domino downgrade fired after {i+1} bad batches -> "
                  f"rolled serving back to v{v}")
            break
    else:
        print("no downgrade (threshold not crossed)")
    print(f"downgrades: {cl.downgrader.downgrades}")


if __name__ == "__main__":
    main()
