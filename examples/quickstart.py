"""Quickstart — the paper's workload end-to-end: large-scale sparse CTR
online learning on WeiPS, driven through the online training plane.

One process simulates the whole symmetric fusion cluster: a click
stream emits exposure/feedback events; the vectorized SampleJoiner
window-joins them into labeled samples; the TrainPipeline admits,
dedups, and trains them in pow2 buckets against 4 master PS shards
(FM-FTRL); the streaming sync pipeline (collect -> gather -> push ->
scatter) deploys every update to 2 slave shards x 2 hot replicas within
one tick; predictors serve from the slaves; windowed progressive
validation monitors quality; checkpoints + domino downgrade guard
stability; backpressure keeps training from outrunning deployment.

Run: PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.configs.weips_ctr import FM_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.core.monitor import auc
from repro.data import ClickStream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--gather-mode", default="realtime",
                    choices=("realtime", "threshold", "period"))
    ap.add_argument("--codec", default="int8",
                    choices=("identity", "cast16", "int8"))
    ap.add_argument("--join-window", type=float, default=3.0)
    ap.add_argument("--emit-on-feedback", action="store_true",
                    help="positives train the moment feedback arrives")
    args = ap.parse_args()

    cluster = WeiPSCluster(FM_FTRL, ClusterConfig(
        num_master=4, num_slave=2, num_replicas=2, num_partitions=8,
        gather_mode=args.gather_mode, codec=args.codec,
        local_ckpt_interval=5.0, remote_ckpt_interval=60.0,
        join_window=args.join_window))
    pipeline = cluster.make_train_pipeline(
        emit_on_feedback=args.emit_on_feedback)
    stream = ClickStream(feature_space=1 << 18, fields=FM_FTRL.fields,
                         zipf_a=1.2, signal_scale=0.8, feedback_delay=1.0,
                         seed=0)
    scn = cluster.training.scenario()

    print(f"model={FM_FTRL.name} optimizer={FM_FTRL.optimizer} "
          f"codec={args.codec} gather={args.gather_mode} "
          f"join_window={args.join_window}s")
    t_start = time.time()
    now = 0.0
    for step in range(args.steps):
        # stream -> join -> admit -> dedup -> bucketed train ...
        pipeline.ingest(stream.events_batch(args.batch, now))
        cluster.train_scheduler.tick(now)
        cluster.sync_tick(now)                 # ... -> second-level deploy
        cluster.maybe_checkpoint(now)
        cluster.downgrade_check(now)
        now += 0.2
        if step % 50 == 0 or step == args.steps - 1:
            sm = cluster.sync_metrics(now)
            tm = sm["training"]["scenarios"][scn.name]
            jm = tm["pipeline"]["joiner"]
            print(f"step {step:4d} trained={tm['examples']:6d} "
                  f"logloss={tm['logloss']:.4f} auc={tm['auc']:.3f} "
                  f"calib={tm['calibration']:.2f} "
                  f"dedup={tm['dedup_ratio']:.2f} "
                  f"join_p50={jm['join_delay']['p50']:.1f}s "
                  f"in_flight={jm['in_flight']} "
                  f"sync_lag={sm['sync_lag_seconds']:.2f}s")
    cluster.train_scheduler.flush(now + args.join_window + 1)
    cluster.sync_tick(now + args.join_window + 1)

    # --- serve from the slave plane and compare with ground truth -------
    ids, y = stream.batch(2048)
    p = cluster.predict(ids)
    rows_total = sum(len(m.tables[g]) for m in cluster.masters
                     for g in cluster.groups)
    print(f"\nserving-plane AUC on fresh traffic: {auc(y, p):.3f}")
    print(f"PS rows: {rows_total}  "
          f"checkpoints: {cluster.store.versions()}")
    print(f"windowed progressive validation: "
          f"logloss={scn.evaluator.smoothed('logloss'):.4f} "
          f"auc={scn.evaluator.smoothed('auc'):.3f} "
          f"calibration={scn.evaluator.smoothed('calibration'):.3f}")
    jm = pipeline.metrics()["joiner"]
    print(f"joiner: emitted={jm['emitted']} late={jm['late_feedback']} "
          f"fast={jm['fast_emits']} "
          f"delay p50/p99={jm['join_delay']['p50']:.1f}/"
          f"{jm['join_delay']['p99']:.1f}s")
    print(f"wall: {time.time()-t_start:.1f}s for {args.steps} online steps")


if __name__ == "__main__":
    main()
