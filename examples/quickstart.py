"""Quickstart — the paper's workload end-to-end: large-scale sparse CTR
online learning on WeiPS.

One process simulates the whole symmetric fusion cluster: 4 master PS
shards train an FM-FTRL model on a Zipfian click stream; the streaming sync
pipeline (collect -> gather -> push -> scatter) deploys every update to
2 slave shards x 2 hot replicas within one tick; predictors serve from the
slaves; progressive validation monitors quality; checkpoints + domino
downgrade guard stability.

Run: PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.configs.weips_ctr import FM_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.core.monitor import auc
from repro.data import ClickStream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--gather-mode", default="realtime",
                    choices=("realtime", "threshold", "period"))
    ap.add_argument("--codec", default="int8",
                    choices=("identity", "cast16", "int8"))
    args = ap.parse_args()

    cluster = WeiPSCluster(FM_FTRL, ClusterConfig(
        num_master=4, num_slave=2, num_replicas=2, num_partitions=8,
        gather_mode=args.gather_mode, codec=args.codec,
        local_ckpt_interval=5.0, remote_ckpt_interval=60.0))
    stream = ClickStream(feature_space=1 << 18, fields=FM_FTRL.fields,
                         zipf_a=1.2, signal_scale=0.8, seed=0)

    print(f"model={FM_FTRL.name} optimizer={FM_FTRL.optimizer} "
          f"codec={args.codec} gather={args.gather_mode}")
    t_start = time.time()
    now = 0.0
    for step in range(args.steps):
        ids, y = stream.batch(args.batch)
        metrics = cluster.train_on_batch(ids, y, now=now)
        cluster.sync_tick(now)                     # second-level deployment
        cluster.maybe_checkpoint(now)
        cluster.downgrade_check(now)
        now += 0.2
        if step % 50 == 0 or step == args.steps - 1:
            sm = cluster.sync_metrics(now)
            print(f"step {step:4d} logloss={metrics['logloss']:.4f} "
                  f"auc={metrics['auc']:.3f} "
                  f"sync_lag={sm['sync_lag_seconds']:.2f}s "
                  f"pushed={sm['pushed_bytes']/1e6:.1f}MB "
                  f"dedup={sm['dedup_ratio']:.2f}")

    # --- serve from the slave plane and compare with ground truth -------
    ids, y = stream.batch(2048)
    p = cluster.predict(ids)
    rows_total = sum(len(m.tables[g]) for m in cluster.masters
                     for g in cluster.groups)
    print(f"\nserving-plane AUC on fresh traffic: {auc(y, p):.3f}")
    print(f"PS rows: {rows_total}  "
          f"checkpoints: {cluster.store.versions()}")
    print(f"progressive-validation logloss "
          f"first5={np.mean([h.values['logloss'] for h in cluster.validator.history[:5]]):.4f} "
          f"last5={np.mean([h.values['logloss'] for h in cluster.validator.history[-5:]]):.4f}")
    print(f"wall: {time.time()-t_start:.1f}s for {args.steps} online steps")


if __name__ == "__main__":
    main()
