"""Heterogeneous-cluster migration (paper §4.2.1d): "if the model owner
wants to migrate a model from cluster A with 10 shards to cluster B with
20 shards, WeiPS can automatically map all data slices."

This demo trains on a 10-shard master cluster, checkpoints, loads the
checkpoint into a fresh 20-shard cluster via the dynamic-routing recovery,
and proves bit-identical serving behaviour across the migration.

Run: PYTHONPATH=src python examples/reshard_migration.py
"""

import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import RoutingPlan
from repro.core.fault_tolerance import BackupPolicy, CheckpointStore, ColdBackup
from repro.core.ps import MasterShard
from repro.data import ClickStream
from repro.optim import get_optimizer


def main() -> None:
    rng = np.random.default_rng(0)
    opt = get_optimizer("ftrl", alpha=0.3, l1=0.01)
    groups = {"w": 1}

    # ---- cluster A: 10 shards ------------------------------------------
    plan_a = RoutingPlan(num_master=10, num_slave=1, num_partitions=1)
    cluster_a = [MasterShard(i, groups, opt) for i in range(10)]
    stream = ClickStream(feature_space=1 << 16, fields=16, signal_scale=1.0)
    for step in range(40):
        ids, y = stream.batch(256)
        uniq, inverse = np.unique(ids.reshape(-1), return_inverse=True)
        grads = rng.normal(size=(len(uniq), 1)).astype(np.float32) * 0.1
        for sid, sids in plan_a.split_by_master(uniq).items():
            pos = np.searchsorted(uniq, sids)
            cluster_a[sid].push_grad("w", sids, grads[pos], step=step)
    rows_a = sum(len(s.tables["w"]) for s in cluster_a)
    print(f"cluster A (10 shards): {rows_a} rows")

    store = CheckpointStore()
    backup = ColdBackup(cluster_a, store, BackupPolicy())
    v = backup.checkpoint(now=0.0)
    print(f"checkpoint v{v} written by 10 shards")

    # ---- migrate to cluster B: 20 shards --------------------------------
    plan_b = RoutingPlan(num_master=20, num_slave=1, num_partitions=1)
    cluster_b = [MasterShard(i, groups, opt) for i in range(20)]
    backup.recover_all(cluster_b, version=v, owner_of=plan_b.master_shard)
    rows_b = sum(len(s.tables["w"]) for s in cluster_b)
    print(f"cluster B (20 shards): {rows_b} rows "
          f"({'no rows lost' if rows_b == rows_a else 'MISMATCH'})")

    # every id lives on exactly its new owner, with identical values
    probe, _ = stream.batch(64)
    uniq = np.unique(probe.reshape(-1))
    w_a = np.zeros((len(uniq), 1), np.float32)
    for sid, sids in plan_a.split_by_master(uniq).items():
        pos = np.searchsorted(uniq, sids)
        w_a[pos] = cluster_a[sid].pull("w", sids, create=False)
    w_b = np.zeros((len(uniq), 1), np.float32)
    for sid, sids in plan_b.split_by_master(uniq).items():
        pos = np.searchsorted(uniq, sids)
        w_b[pos] = cluster_b[sid].pull("w", sids, create=False)
    np.testing.assert_array_equal(w_a, w_b)
    print(f"probe of {len(uniq)} ids: values bit-identical across the "
          "10->20 shard migration")
    for sid in (0, 7, 13, 19):
        ids = cluster_b[sid].tables["w"].all_ids()
        assert (plan_b.master_shard(ids) == sid).all()
    print("ownership verified: every row sits on its plan-B owner shard")


if __name__ == "__main__":
    main()
