"""Batched LM serving with second-level weight deployment: a background
"training" process keeps improving the model; the WeiPS sync engine streams
the updates; the serving driver hot-swaps them BETWEEN decode steps without
dropping in-flight sequences (the KV cache survives the swap).

Run: PYTHONPATH=src python examples/serve_lm.py [--requests 3]
"""

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.sync_engine import ModelSyncEngine, SyncConfig
from repro.data import lm_batches
from repro.serving.predictor import ServeDriver
from repro.training import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--decode-steps", type=int, default=48)
    ap.add_argument("--train-every", type=int, default=8,
                    help="train+sync cadence, in decode steps")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), vocab=1024)
    print(f"serving {cfg.name}: {cfg.param_counts()['total']/1e6:.1f}M "
          f"params, window={cfg.window_size}")

    # training plane
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    train_step = make_train_step(cfg)
    engine = ModelSyncEngine(cfg, state.params, SyncConfig(
        gather_mode="realtime", codec="cast16"))
    batches = lm_batches(cfg.vocab_size, 8, 64, seed=1)

    # serving plane starts from the replica's bootstrap state
    driver = ServeDriver(
        cfg=cfg, params=engine.replicas[0].device_params(dtype="float32"),
        batch=args.batch, max_len=args.decode_steps + 1,
        cache_dtype=jnp.float32)

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    swaps, lat = 0, []
    for i in range(args.decode_steps):
        t0 = time.perf_counter()
        tok = driver.step(tok)
        lat.append(time.perf_counter() - t0)
        if (i + 1) % args.train_every == 0:
            # the training plane advances; updates stream to the replica
            state, m = train_step(state, {"tokens": jnp.asarray(
                next(batches))})
            engine.collect_step(np.asarray(next(batches)), {})
            engine.tick(state.params, now=float(i))
            driver.hot_swap(engine.replicas[0].device_params(
                dtype="float32"))
            swaps += 1
            print(f"decode step {i+1}: hot-swapped serve weights "
                  f"(train loss {float(m['loss']):.3f}, "
                  f"staleness {engine.replicas[0].staleness(state.params):.1e})")

    gen = np.stack(driver.generated, axis=1)
    print(f"\ngenerated {gen.shape} tokens across {swaps} weight swaps "
          f"with uninterrupted KV caches")
    print(f"decode latency p50={np.median(lat)*1e3:.1f}ms "
          f"p99={np.quantile(lat, 0.99)*1e3:.1f}ms")
    print(f"sync: {engine.metrics()}")


if __name__ == "__main__":
    main()
