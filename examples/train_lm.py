"""Train a small LM (≈15M params, qwen2-family reduced config) for a few
hundred steps on CPU, with the WeiPS ModelSyncEngine streaming weights to a
serve replica throughout — then decode from the SERVE replica to prove the
deployed model works.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.sync_engine import ModelSyncEngine, SyncConfig
from repro.data import lm_batches
from repro.models import init_cache
from repro.serving.predictor import ServeDriver
from repro.training import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--sync-period", type=float, default=2.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=args.d_model,
                  layers_per_segment=args.layers, vocab=args.vocab)
    n_params = cfg.param_counts()["total"]
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M layers="
          f"{cfg.num_layers} vocab={cfg.vocab_size}")

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = make_train_step(cfg)
    engine = ModelSyncEngine(cfg, state.params, SyncConfig(
        gather_mode="period", period=args.sync_period, codec="cast16"))

    batches = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        tokens = jnp.asarray(next(batches))
        state, metrics = step_fn(state, {"tokens": tokens})
        losses.append(float(metrics["loss"]))
        engine.collect_step(np.asarray(tokens), {})
        engine.tick(state.params, now=time.time() - t0)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"(avg10={np.mean(losses[-10:]):.4f}) "
                  f"wall={time.time()-t0:.1f}s")
    engine.tick(state.params, now=1e9)

    print(f"\nloss first10={np.mean(losses[:10]):.4f} -> "
          f"last10={np.mean(losses[-10:]):.4f}")
    print("sync:", engine.metrics())
    print("serve staleness:",
          f"{engine.replicas[0].staleness(state.params):.2e}")

    # decode from the STREAMED serve replica (the deployed model)
    serve_params = engine.replicas[0].device_params(dtype="float32")
    driver = ServeDriver(cfg=cfg, params=serve_params, batch=4, max_len=32,
                         cache_dtype=jnp.float32)
    out = driver.generate(jnp.zeros((4, 1), jnp.int32), steps=16)
    print(f"greedy decode from serve replica: shape={out.shape}, "
          f"tokens[0]={out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
