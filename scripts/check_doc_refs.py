#!/usr/bin/env python
"""Check that intra-repo file references in markdown docs resolve.

A reference is any backtick-quoted token that looks like a repo path:
it contains a ``/`` or ends in a known file suffix. Tokens containing
spaces, globs, or placeholders are ignored; a trailing ``:<line>`` is
stripped. Bare filenames (no ``/``) may live anywhere in the tree.

With ``--check-bench`` the check also runs in reverse for benchmark
results: every ``BENCH_*.json`` in the repo root must be referenced by
at least one of the given docs. A committed result no doc mentions is
an orphan — it silently drifts from the documented performance story.

Usage: python scripts/check_doc_refs.py [--check-bench] DOC.md [...]
Exits 1 listing broken references / orphaned results, 0 when
everything resolves.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

SUFFIXES = (".py", ".md", ".json", ".yml", ".yaml", ".toml", ".txt")
ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "build", "__pycache__", ".pytest_cache"}


def iter_refs(text: str):
    for tok in re.findall(r"`([^`\n]+)`", text):
        tok = tok.strip().rstrip("/")
        tok = re.sub(r":\d+$", "", tok)          # path.py:123 → path.py
        if not re.fullmatch(r"[\w./-]+", tok) or tok.startswith("-"):
            continue
        if "/" in tok or tok.endswith(SUFFIXES):
            yield tok


def resolves(tok: str) -> bool:
    if (ROOT / tok).exists():
        return True
    if "/" not in tok:                           # bare filename: search tree
        for p in ROOT.rglob(tok):
            if not SKIP_DIRS.intersection(p.relative_to(ROOT).parts):
                return True
    return False


def orphaned_bench(referenced: set[str]) -> list[str]:
    """Committed BENCH_*.json files no checked doc references."""
    return sorted(p.name for p in ROOT.glob("BENCH_*.json")
                  if p.name not in referenced)


def main(argv: list[str]) -> int:
    check_bench = "--check-bench" in argv
    argv = [a for a in argv if a != "--check-bench"]
    if not argv:
        print(__doc__)
        return 2
    broken = []
    referenced: set[str] = set()
    for doc in argv:
        text = Path(doc).read_text()
        for tok in sorted(set(iter_refs(text))):
            referenced.add(tok.rsplit("/", 1)[-1])
            if not resolves(tok):
                broken.append(f"{doc}: `{tok}` does not resolve")
    if check_bench:
        for name in orphaned_bench(referenced):
            broken.append(
                f"{name}: orphaned benchmark result — referenced by no "
                f"checked doc")
    for line in broken:
        print(line)
    if not broken:
        extra = " and no benchmark result is orphaned" if check_bench else ""
        print(f"ok: all intra-repo references in {len(argv)} doc(s) "
              f"resolve{extra}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
