#!/usr/bin/env python
"""Check that intra-repo file references in markdown docs resolve.

A reference is any backtick-quoted token that looks like a repo path:
it contains a ``/`` or ends in a known file suffix. Tokens containing
spaces, globs, or placeholders are ignored; a trailing ``:<line>`` is
stripped. Bare filenames (no ``/``) may live anywhere in the tree.

Usage: python scripts/check_doc_refs.py DOC.md [DOC.md ...]
Exits 1 listing broken references, 0 when everything resolves.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

SUFFIXES = (".py", ".md", ".json", ".yml", ".yaml", ".toml", ".txt")
ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "build", "__pycache__", ".pytest_cache"}


def iter_refs(text: str):
    for tok in re.findall(r"`([^`\n]+)`", text):
        tok = tok.strip().rstrip("/")
        tok = re.sub(r":\d+$", "", tok)          # path.py:123 → path.py
        if not re.fullmatch(r"[\w./-]+", tok) or tok.startswith("-"):
            continue
        if "/" in tok or tok.endswith(SUFFIXES):
            yield tok


def resolves(tok: str) -> bool:
    if (ROOT / tok).exists():
        return True
    if "/" not in tok:                           # bare filename: search tree
        for p in ROOT.rglob(tok):
            if not SKIP_DIRS.intersection(p.relative_to(ROOT).parts):
                return True
    return False


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    broken = []
    for doc in argv:
        text = Path(doc).read_text()
        for tok in sorted(set(iter_refs(text))):
            if not resolves(tok):
                broken.append(f"{doc}: `{tok}` does not resolve")
    for line in broken:
        print(line)
    if not broken:
        print(f"ok: all intra-repo references in {len(argv)} doc(s) resolve")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
