#!/usr/bin/env python
"""Lint: every metric name the cluster's ``MetricsRegistry`` exports
must be documented in docs/OBSERVABILITY.md.

Builds a small in-process cluster, drives one train batch + sync tick +
predict so every provider has registered, flattens the registry to
dotted names, canonicalizes per-scenario / per-group segments to
``<scenario>`` / ``<group>`` placeholders, and checks each canonical
name appears as a backtick-quoted token in the doc. Exits 1 listing the
undocumented names — add the metric's row to the table in
docs/OBSERVABILITY.md (or rename it) to fix.

Run:  PYTHONPATH=src python scripts/check_metrics_docs.py
"""

from __future__ import annotations

import argparse
import re
import sys


def registry_names() -> tuple[list[str], set[str], set[str]]:
    import numpy as np

    from repro.configs.weips_ctr import FM_FTRL
    from repro.core import ClusterConfig, WeiPSCluster

    cl = WeiPSCluster(FM_FTRL, ClusterConfig(
        num_master=1, num_slave=2, num_replicas=1, num_partitions=2))
    ids = np.arange(64, dtype=np.int64).reshape(8, 8)
    cl.train_on_batch(ids, np.zeros(8, np.float32), now=0.0)
    cl.sync_tick(0.0)
    cl.predict(ids)
    scenarios = {s.name for s in cl.serving.registry} | \
        {s.name for s in cl.training.registry}
    groups = set(cl.groups)
    return sorted(cl.metrics_registry.collect(1.0)), scenarios, groups


def canonicalize(name: str, scenarios: set[str],
                 groups: set[str]) -> str:
    parts = []
    for seg in name.split("."):
        if seg in scenarios:
            parts.append("<scenario>")
        elif seg in groups:
            parts.append("<group>")
        else:
            parts.append(seg)
    return ".".join(parts)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("doc", nargs="?", default="docs/OBSERVABILITY.md")
    args = ap.parse_args()

    names, scenarios, groups = registry_names()
    canonical = sorted({canonicalize(n, scenarios, groups)
                        for n in names})
    with open(args.doc) as f:
        documented = set(re.findall(r"`([^`\n]+)`", f.read()))
    missing = [n for n in canonical if n not in documented]
    if missing:
        print(f"{args.doc} is missing {len(missing)} registered "
              f"metric name(s):", file=sys.stderr)
        for n in missing:
            print(f"  {n}", file=sys.stderr)
        return 1
    print(f"check_metrics_docs: {len(canonical)} canonical metric "
          f"names all documented in {args.doc}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
