from repro.configs.base import (ARCH_IDS, ModelConfig, Segment, LayerSpec,
                                all_configs, get_config, reduced, register)
from repro.configs.shapes import (SHAPES, InputShape, applicable, TRAIN_4K,
                                  PREFILL_32K, DECODE_32K, LONG_500K)
from repro.configs.weips_ctr import CTR_CONFIGS, CTRConfig

__all__ = [
    "ARCH_IDS", "ModelConfig", "Segment", "LayerSpec", "all_configs",
    "get_config", "reduced", "register", "SHAPES", "InputShape", "applicable",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K", "CTR_CONFIGS",
    "CTRConfig",
]
