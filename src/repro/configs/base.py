"""Config system: model architecture configs, registry, and layer segmentation.

Every assigned architecture is expressed as a ``ModelConfig``. Layer stacks
are described as *segments*: a segment is a repeating pattern of
``LayerSpec`` entries (mixer kind + ffn kind) executed ``repeats`` times
under ``jax.lax.scan``. This keeps HLO size independent of depth while
supporting heterogeneous interleaves (gemma3 5:1 local:global, jamba
attn:mamba 1:7, llama-vision cross-attn every 5th layer).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"            # global causal self-attention
LOCAL_ATTN = "local"     # sliding-window causal self-attention
MAMBA = "mamba"          # mamba2 / SSD block
CROSS_ATTN = "xattn"     # cross-attention to encoder states (VLM / enc-dec)
ENC_ATTN = "enc"         # bidirectional encoder self-attention

# ffn kinds
MLP = "mlp"
MOE = "moe"
NONE = "none"            # pure-mixer block (mamba2 has no FFN)

MIXER_KINDS = (ATTN, LOCAL_ATTN, MAMBA, CROSS_ATTN, ENC_ATTN)
FFN_KINDS = (MLP, MOE, NONE)


@dataclass(frozen=True)
class LayerSpec:
    """One layer position inside a segment pattern."""

    mixer: str
    ffn: str = MLP

    def __post_init__(self):
        if self.mixer not in MIXER_KINDS:
            raise ValueError(f"unknown mixer kind {self.mixer!r}")
        if self.ffn not in FFN_KINDS:
            raise ValueError(f"unknown ffn kind {self.ffn!r}")


@dataclass(frozen=True)
class Segment:
    """A repeating pattern of layers, executed with jax.lax.scan."""

    pattern: tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation bracket from the assignment
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    segments: tuple[Segment, ...]    # decoder stack
    # encoder stack (whisper) — empty for decoder-only models
    encoder_segments: tuple[Segment, ...] = ()
    encoder_len: int = 0             # stub frontend: #frames / #patches
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # >1 enables group-local dispatch (set to the data-axis size by the
    # optimized dry-run variants; see models/moe.py + §Perf)
    moe_dispatch_groups: int = 1
    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # attention details
    window_size: int = 0             # for LOCAL_ATTN layers
    qkv_bias: bool = False
    # context-parallel attention: shard the query sequence over `model`
    # instead of head_dim when heads don't divide the TP degree (avoids the
    # full-score all-reduce pathology; requires a mesh in scope — only the
    # dry-run/launchers enable it). See §Perf.
    context_parallel_attn: bool = False
    # chunked cross-entropy: compute logits/CE in S-chunks of this size
    # with the vocab head gathered once (0 = monolithic logits). See §Perf.
    loss_chunk: int = 0
    rope_theta: float = 500_000.0
    logit_softcap: float = 0.0
    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer: str = "adam"          # default training optimizer for this arch
    remat: bool = True
    # decode-shape applicability (long_500k needs sub-quadratic attention)
    supports_long_context: bool = False
    supports_decode: bool = True
    tie_embeddings: bool = False

    # ---- derived -----------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a 256 multiple so the vocab axis
        shards evenly on any production mesh axis (logits beyond
        ``vocab_size`` are masked in forward/decode)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.segments) + sum(
            s.num_layers for s in self.encoder_segments
        )

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return bool(self.encoder_segments)

    @property
    def has_encoder_context(self) -> bool:
        """Models whose inputs include stub frontend embeddings."""
        return self.encoder_len > 0

    def layer_specs(self) -> list[LayerSpec]:
        """Flat (unrolled) list of decoder layer specs, for accounting."""
        out: list[LayerSpec] = []
        for seg in self.segments:
            out.extend(list(seg.pattern) * seg.repeats)
        return out

    def validate(self) -> None:
        specs = self.layer_specs()
        if any(s.ffn == MOE for s in specs):
            assert self.num_experts > 0 and self.experts_per_token > 0, self.name
        if any(s.mixer == MAMBA for s in specs):
            assert self.ssm_state > 0, self.name
            assert self.d_inner % self.ssm_head_dim == 0, self.name
        if any(s.mixer == LOCAL_ATTN for s in specs):
            assert self.window_size > 0, self.name
        if any(s.mixer in (ATTN, LOCAL_ATTN, CROSS_ATTN, ENC_ATTN) for s in specs):
            assert self.num_heads % self.num_kv_heads == 0, self.name

    # ---- parameter counting (for roofline MODEL_FLOPS) ---------------
    def param_counts(self) -> dict[str, int]:
        """Returns {'total': N, 'active': N_active} parameter counts."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        active = total

        def attn_params(cross: bool = False) -> int:
            q = d * h * hd + (h * hd if self.qkv_bias else 0)
            k = d * kv * hd + (kv * hd if self.qkv_bias else 0)
            vp = d * kv * hd + (kv * hd if self.qkv_bias else 0)
            o = h * hd * d
            return q + k + vp + o + d  # + input norm

        def mlp_params() -> int:
            return 3 * d * ff + d  # gate/up/down + norm

        def moe_params() -> tuple[int, int]:
            router = d * self.num_experts
            per_expert = 3 * d * ff
            tot = router + self.num_experts * per_expert + d
            act = router + self.experts_per_token * per_expert + d
            return tot, act

        def mamba_params() -> int:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_num_heads
            in_proj = d * (2 * di + 2 * ns + nh)
            conv = self.ssm_conv_width * (di + 2 * ns)
            out_proj = di * d
            extra = nh * 3 + di  # A_log, D, dt_bias, gated-norm
            return in_proj + conv + out_proj + extra + d

        all_specs = self.layer_specs() + [
            s for seg in self.encoder_segments for s in list(seg.pattern) * seg.repeats
        ]
        for spec in all_specs:
            if spec.mixer in (ATTN, LOCAL_ATTN, ENC_ATTN):
                total += attn_params(); active += attn_params()
            elif spec.mixer == CROSS_ATTN:
                total += attn_params(cross=True); active += attn_params(cross=True)
            elif spec.mixer == MAMBA:
                total += mamba_params(); active += mamba_params()
            if spec.ffn == MLP:
                total += mlp_params(); active += mlp_params()
            elif spec.ffn == MOE:
                t, a = moe_params(); total += t; active += a
        total += d  # final norm
        active += d
        return {"total": total, "active": active}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "mamba2-1.3b",
    "llama-3.2-vision-90b",
    "qwen1.5-4b",
    "dbrx-132b",
    "qwen2-7b",
    "granite-moe-3b-a800m",
    "qwen2-1.5b",
    "whisper-medium",
    "jamba-1.5-large-398b",
    "gemma3-4b",
)
# The paper's own sparse CTR model family lives in configs/weips_ctr.py with
# its own config class (it is a sparse PS model, not a transformer).

_MODULE_FOR_ARCH = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = _MODULE_FOR_ARCH.get(name)
        if mod is None:
            raise KeyError(f"unknown architecture {name!r}; known: {ARCH_IDS}")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    for a in ARCH_IDS:
        get_config(a)
    return dict(_REGISTRY)


def reduced(cfg: ModelConfig, *, d_model: int = 256, layers_per_segment: int = 1,
            d_ff: Optional[int] = None, vocab: int = 512,
            num_experts: Optional[int] = None) -> ModelConfig:
    """Smoke-test variant of the same family: <=2 layers, d_model<=512, <=4 experts."""
    assert d_model <= 512
    n_exp = num_experts if num_experts is not None else (
        min(cfg.num_experts, 4) if cfg.num_experts else 0)
    topk = min(cfg.experts_per_token, max(1, n_exp // 2)) if n_exp else 0
    heads = max(2, min(4, cfg.num_heads))
    kv = 1 if cfg.num_kv_heads == 1 else 2
    hd = d_model // heads
    segs = tuple(
        Segment(pattern=s.pattern, repeats=min(s.repeats, layers_per_segment))
        for s in cfg.segments[:1]
    )
    enc_segs = tuple(
        Segment(pattern=s.pattern, repeats=min(s.repeats, layers_per_segment))
        for s in cfg.encoder_segments[:1]
    )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=d_ff if d_ff is not None else max(64, d_model * 2),
        vocab_size=vocab,
        segments=segs,
        encoder_segments=enc_segs,
        encoder_len=min(cfg.encoder_len, 16),
        num_experts=n_exp,
        experts_per_token=topk,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=32 if cfg.ssm_state else cfg.ssm_chunk,
        window_size=min(cfg.window_size, 16) if cfg.window_size else 0,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
