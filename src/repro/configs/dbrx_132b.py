"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""

from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig, Segment, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    segments=(Segment(pattern=(LayerSpec(ATTN, MOE),), repeats=40),),
    num_experts=16,
    experts_per_token=4,
    rope_theta=500_000.0,
    optimizer="adafactor",   # 132B-class training state must fit 16 GB/chip
    supports_long_context=False,
))
