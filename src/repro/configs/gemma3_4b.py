"""gemma3-4b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144. Pattern is 5 sliding
-window (1024) layers per global layer; 34 = 5 full 6-layer periods + a
4-local tail (handled as a second segment so every segment scans
homogeneously).
"""

from repro.configs.base import (ATTN, LOCAL_ATTN, MLP, LayerSpec, ModelConfig,
                                Segment, register)

_PERIOD = (LayerSpec(LOCAL_ATTN, MLP),) * 5 + (LayerSpec(ATTN, MLP),)
_TAIL = (LayerSpec(LOCAL_ATTN, MLP),)

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    segments=(
        Segment(pattern=_PERIOD, repeats=5),   # 30 layers
        Segment(pattern=_TAIL, repeats=4),     # +4 local tail = 34
    ),
    window_size=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    optimizer="adam",
    supports_long_context=True,   # sliding-window local attention
))
