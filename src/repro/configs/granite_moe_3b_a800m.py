"""granite-moe-3b-a800m [moe] — fine-grained experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
"""

from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig, Segment, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                 # fine-grained experts
    vocab_size=49155,
    segments=(Segment(pattern=(LayerSpec(ATTN, MOE),), repeats=32),),
    num_experts=40,
    experts_per_token=8,
    rope_theta=10_000.0,
    optimizer="adam",
    supports_long_context=False,
))
