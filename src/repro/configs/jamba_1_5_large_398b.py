"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period of 8 layers = 1 attention + 7 mamba; MoE on every other layer
(4 MoE + 4 MLP per period), following the Jamba block design.

Adaptation note (DESIGN.md §Assumptions): our SSM block is the Mamba-2/SSD
formulation (ssm_state=128) rather than Jamba's Mamba-1 selective scan —
the framework's single SSM substrate is SSD, and the sharding/sync story is
identical.
"""

from repro.configs.base import (ATTN, MAMBA, MLP, MOE, LayerSpec, ModelConfig,
                                Segment, register)

_PATTERN = (
    LayerSpec(ATTN, MOE),
    LayerSpec(MAMBA, MLP),
    LayerSpec(MAMBA, MOE),
    LayerSpec(MAMBA, MLP),
    LayerSpec(MAMBA, MOE),
    LayerSpec(MAMBA, MLP),
    LayerSpec(MAMBA, MOE),
    LayerSpec(MAMBA, MLP),
)

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    segments=(Segment(pattern=_PATTERN, repeats=9),),   # 72 layers
    num_experts=16,
    experts_per_token=2,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    rope_theta=1_000_000.0,
    optimizer="adafactor",   # 398B-class training state must fit 16 GB/chip
    supports_long_context=True,   # SSM-dominated, 1:7 attention
))
