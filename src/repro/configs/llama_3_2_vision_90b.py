"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. Every 5th layer is
a cross-attention layer attending to projected vision-patch embeddings. The
vision encoder (ViT + projector) is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings (B, 1024, d_model).
"""

from repro.configs.base import (ATTN, CROSS_ATTN, MLP, LayerSpec, ModelConfig,
                                Segment, register)

_PATTERN = (LayerSpec(CROSS_ATTN, MLP),) + (LayerSpec(ATTN, MLP),) * 4

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    segments=(Segment(pattern=_PATTERN, repeats=20),),   # 100 layers
    encoder_len=1024,                                    # stub patch embeddings
    rope_theta=500_000.0,
    optimizer="adafactor",   # 90B-class training state must fit 16 GB/chip
    supports_long_context=False,  # full attention — long_500k skipped
))
