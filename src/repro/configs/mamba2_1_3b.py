"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attention-free, d_ff=0, vocab=50280, ssm_state=128.
"""

from repro.configs.base import (MAMBA, NONE, LayerSpec, ModelConfig, Segment,
                                register)

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    d_model=2048,
    num_heads=1,          # attention-free; unused
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    segments=(Segment(pattern=(LayerSpec(MAMBA, NONE),), repeats=48),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
    optimizer="adam",
    supports_long_context=True,   # O(1) recurrent decode state
))
