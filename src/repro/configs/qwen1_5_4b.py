"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B].

40L d_model=2560 20H (GQA kv=20, i.e. MHA) d_ff=6912 vocab=151936.
"""

from repro.configs.base import ATTN, MLP, LayerSpec, ModelConfig, Segment, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    segments=(Segment(pattern=(LayerSpec(ATTN, MLP),), repeats=40),),
    qkv_bias=True,
    rope_theta=5_000_000.0,
    optimizer="adam",
    supports_long_context=False,
))
