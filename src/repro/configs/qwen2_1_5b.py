"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""

from repro.configs.base import ATTN, MLP, LayerSpec, ModelConfig, Segment, register

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671",
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    segments=(Segment(pattern=(LayerSpec(ATTN, MLP),), repeats=28),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    optimizer="adam",
    supports_long_context=False,
))
