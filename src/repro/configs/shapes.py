"""The four assigned input shapes, plus applicability rules per architecture."""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run pair, with a reason for skips.

    Rules from the assignment:
      - decode shapes lower serve_step; encoder-only archs have no decode.
      - long_500k needs sub-quadratic attention: run for SSM / hybrid /
        sliding-window archs only.
    """
    if shape.kind == "decode":
        if not cfg.supports_decode:
            return False, "encoder-only architecture has no decode step"
        if shape.name == "long_500k" and not cfg.supports_long_context:
            return False, (
                "pure full-attention stack: 524k-token decode requires "
                "sub-quadratic attention (see DESIGN.md shape skips)"
            )
    return True, ""
