"""The paper's own model family: large-scale sparse CTR models served by the
WeiPS parameter server — LR-FTRL, FM-FTRL, FM-SGD, DNN (paper §4.1.2:
"LR-FTRL has 3 sparse matrices, FM-FTRL has 6, FM-SGD has 2, DNN is multiple
sparse plus multiple dense matrices").

Features are hashed into a huge sparse ID space; only touched rows exist on
the PS (row-addressable sparse tables, see core/ps.py).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CTRConfig:
    name: str = "weips-ctr"
    model_type: str = "fm"          # "lr" | "fm" | "dnn"
    feature_space: int = 2 ** 22    # hashed sparse feature ID space
    fields: int = 32                # feature fields per example
    embed_dim: int = 8              # FM latent dim / DNN embedding dim
    dnn_hidden: tuple[int, ...] = (128, 64)
    optimizer: str = "ftrl"         # "ftrl" | "sgd" | "adagrad" | "adam"
    # FTRL hyper-parameters (McMahan 2011)
    ftrl_alpha: float = 0.05
    ftrl_beta: float = 1.0
    ftrl_l1: float = 1.0
    ftrl_l2: float = 1.0
    lr: float = 0.05                # for sgd/adagrad/adam variants


LR_FTRL = CTRConfig(name="weips-lr-ftrl", model_type="lr", embed_dim=1,
                    optimizer="ftrl")
FM_FTRL = CTRConfig(name="weips-fm-ftrl", model_type="fm", optimizer="ftrl")
FM_SGD = CTRConfig(name="weips-fm-sgd", model_type="fm", optimizer="sgd")
DNN_ADAM = CTRConfig(name="weips-dnn-adam", model_type="dnn", optimizer="adam")

CTR_CONFIGS = {c.name: c for c in (LR_FTRL, FM_FTRL, FM_SGD, DNN_ADAM)}
