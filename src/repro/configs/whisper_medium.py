"""whisper-medium [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. Whisper-medium is a
24-encoder-layer / 24-decoder-layer encoder-decoder; each decoder layer has
self-attention + cross-attention + MLP, which we express as two sub-layer
specs (ATTN/none then XATTN/mlp) per decoder layer. The mel-spectrogram +
conv feature extractor is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed frame embeddings (B, 1500, d_model).

Deviation note (DESIGN.md §Assumptions): we use RoPE in place of whisper's
learned absolute positions — positional scheme is orthogonal to the WeiPS
sync/deployment mechanics under study.
"""

from repro.configs.base import (ATTN, CROSS_ATTN, ENC_ATTN, MLP, NONE,
                                LayerSpec, ModelConfig, Segment, register)

_DEC_PATTERN = (LayerSpec(ATTN, NONE), LayerSpec(CROSS_ATTN, MLP))

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    segments=(Segment(pattern=_DEC_PATTERN, repeats=24),),
    encoder_segments=(Segment(pattern=(LayerSpec(ENC_ATTN, MLP),), repeats=24),),
    encoder_len=1500,         # stub conv frontend output frames
    rope_theta=10_000.0,
    optimizer="adam",
    supports_long_context=False,   # bounded decoder context (448-token family)
))
