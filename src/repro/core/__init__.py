"""WeiPS core: the paper's contribution — symmetric fusion of the training
parameter plane (master) and serving parameter plane (slave) via streaming
synchronization, with multi-level fault tolerance and domino downgrade."""

from repro.core.cluster import ClusterConfig, WeiPSCluster
from repro.core.hashmap import IdHashMap
from repro.core.ps import DenseBank, MasterShard, SlaveShard, SparseTable
from repro.core.queue import Consumer, PartitionedQueue, Record
from repro.core.routing import RoutingPlan, owner_segments, reshard_plan
from repro.core.streaming import (Collector, Gatherer, Pusher, Scatter,
                                  SyncPipeline)
from repro.core.transform import (Cast16Transform, Int8Transform, Transform,
                                  decode_record, make_transform)

__all__ = [
    "ClusterConfig", "WeiPSCluster", "DenseBank", "IdHashMap", "MasterShard",
    "SlaveShard",
    "SparseTable", "Consumer", "PartitionedQueue", "Record", "RoutingPlan",
    "owner_segments", "reshard_plan", "Collector", "Gatherer", "Pusher",
    "Scatter",
    "SyncPipeline", "Cast16Transform", "Int8Transform", "Transform",
    "decode_record", "make_transform",
]
