"""WeiPS core: the paper's contribution — symmetric fusion of the training
parameter plane (master) and serving parameter plane (slave) via streaming
synchronization, with multi-level fault tolerance and domino downgrade.

Exports resolve lazily (PEP 562): ``from repro.core import X`` imports only
the submodule that defines ``X``. This breaks the historical import cycle
(``repro.training`` → ``core.feature_filter`` → eager ``core.__init__`` →
``core.cluster`` → ``repro.training.pipeline`` mid-initialization) and
keeps worker processes of the multi-process runtime (``launch/worker.py``)
from paying the jax-model import cone just to reach the PS/queue layer.
"""

_EXPORTS = {
    "ClusterConfig": "repro.core.cluster",
    "WeiPSCluster": "repro.core.cluster",
    "DenseBank": "repro.core.ps",
    "IdHashMap": "repro.core.hashmap",
    "MasterShard": "repro.core.ps",
    "SlaveShard": "repro.core.ps",
    "SparseTable": "repro.core.ps",
    "Consumer": "repro.core.queue",
    "FileQueue": "repro.core.queue",
    "PartitionedQueue": "repro.core.queue",
    "Record": "repro.core.queue",
    "RoutingPlan": "repro.core.routing",
    "owner_segments": "repro.core.routing",
    "reshard_plan": "repro.core.routing",
    "Collector": "repro.core.streaming",
    "Gatherer": "repro.core.streaming",
    "Pusher": "repro.core.streaming",
    "Scatter": "repro.core.streaming",
    "SyncPipeline": "repro.core.streaming",
    "Cast16Transform": "repro.core.transform",
    "Int8Transform": "repro.core.transform",
    "Transform": "repro.core.transform",
    "decode_record": "repro.core.transform",
    "make_transform": "repro.core.transform",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
