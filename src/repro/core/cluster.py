"""WeiPSCluster: the full symmetric fusion system for the paper's online-
learning workload — trainer + master PS (training plane), predictor + slave
PS replicas (serving plane), joined by the streaming sync pipeline, with
cold/hot fault tolerance, progressive validation and domino downgrade.

This is the end-to-end object the examples and benchmarks drive.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.weips_ctr import CTRConfig
from repro.core.downgrade import (DominoDowngrade, SmoothedThresholdTrigger,
                                  VersionManager)
from repro.core.fault_tolerance import (BackupPolicy, Checkpoint,
                                        CheckpointStore, ColdBackup,
                                        ReplicaSet)
from repro.core.feature_filter import FeatureFilter
from repro.core.ps import MasterShard, SlaveShard
from repro.core.queue import FileQueue, PartitionedQueue
from repro.core.routing import RoutingPlan
from repro.core.scheduler import ComponentInfo, Scheduler
from repro.core.streaming import Collector, Gatherer, Pusher, Scatter
from repro.core.transform import make_transform
from repro.data.joiner import SampleJoiner
from repro.models import ctr as ctr_model
from repro.optim import get_optimizer
from repro.serving import RowRouter, ServingPlane
from repro.serving.scheduler import DEFAULT_BUCKETS
from repro.training.pipeline import TRAIN_BUCKETS, TrainPipeline
from repro.training.plane import TrainingPlane
from repro.training.scheduler import TrainScheduler


def _make_optimizer(cfg: CTRConfig):
    if cfg.optimizer == "ftrl":
        return get_optimizer("ftrl", alpha=cfg.ftrl_alpha, beta=cfg.ftrl_beta,
                             l1=cfg.ftrl_l1, l2=cfg.ftrl_l2)
    return get_optimizer(cfg.optimizer, lr=cfg.lr)


@dataclass
class ClusterConfig:
    num_master: int = 4
    num_slave: int = 2           # slave shards (serving partition count)
    num_replicas: int = 2        # hot-backup replicas per slave shard
    num_partitions: int = 8
    queue_dir: Optional[str] = None  # durable FileQueue root; None=in-memory
    gather_mode: str = "realtime"
    gather_threshold: int = 4096
    gather_period: float = 1.0
    codec: str = "identity"      # identity | cast16 | int8
    codec_backend: str = "numpy"  # numpy | pallas (delta_codec kernel)
    local_ckpt_interval: float = 30.0
    remote_ckpt_interval: float = 600.0
    ckpt_root: Optional[str] = None
    ckpt_incremental: bool = True   # local cadence writes delta checkpoints
    ckpt_compress: str = "none"     # none | int8 (delta_codec row codec)
    downgrade_metric: str = "logloss"
    downgrade_threshold: float = 1.5
    downgrade_window: int = 10
    feature_min_count: int = 1
    feature_ttl_steps: int = 100_000
    ps_backend: str = "numpy"    # numpy | pallas (sparse-row engine)
    # serving plane (src/repro/serving/)
    serve_max_lag: Optional[int] = None   # staleness bound in queue records;
    #                                       laggier replicas are skipped
    serve_cache_rows: int = 1 << 20       # serve-cache arena bound per scenario
    serve_buckets: tuple = DEFAULT_BUCKETS  # predict micro-batch bucket sizes
    serve_max_pending: Optional[int] = None  # admission depth bound in pending
    #                                       predict examples; over it the
    #                                       OLDEST tickets shed (serving twin
    #                                       of train_max_sync_lag)
    serve_deadline: Optional[float] = None  # seconds from admit to execution;
    #                                       expired tickets shed at flush
    # training plane (src/repro/training/)
    train_buckets: tuple = TRAIN_BUCKETS  # train micro-batch bucket sizes
    train_max_sync_lag: Optional[int] = None  # backpressure bound: pipelines
    #                                       throttle while Scatter.lag()
    #                                       exceeds this many records
    train_buffer_cap: int = 1 << 16       # per-pipeline sample buffer bound;
    #                                       beyond it the oldest samples shed
    join_window: float = 30.0             # default sample-join window (s)
    seed: int = 0


class WeiPSCluster:
    def __init__(self, model_cfg: CTRConfig,
                 cluster_cfg: Optional[ClusterConfig] = None, *,
                 clock=None):
        self.cfg = model_cfg
        self.ccfg = cluster_cfg or ClusterConfig()
        c = self.ccfg
        self.clock = clock      # injectable serve-latency clock (tests);
        #                         None = wall clock (time.perf_counter)
        self.plan = RoutingPlan(c.num_master, c.num_slave, c.num_partitions)
        self.groups = ctr_model.groups_for(model_cfg)
        self.optimizer = _make_optimizer(model_cfg)
        self.transform = make_transform(c.codec, self.optimizer,
                                        backend=c.codec_backend)
        self.scheduler = Scheduler()
        # a queue_dir swaps the in-memory log for the durable file-backed
        # one (same interface) — the stream then survives process death
        # and can be shared with the multi-process runtime (launch/).
        self.queue = FileQueue(c.queue_dir, c.num_partitions) \
            if c.queue_dir else PartitionedQueue(c.num_partitions)
        self.filter = FeatureFilter(c.feature_min_count, c.feature_ttl_steps)

        # ---- training plane -------------------------------------------
        self.masters = [MasterShard(i, self.groups, self.optimizer,
                                    backend=c.ps_backend)
                        for i in range(c.num_master)]
        self.collectors = []
        self.gatherers = []
        self.pushers = []
        for mshard in self.masters:
            col = Collector()
            mshard.collector = col
            self.collectors.append(col)
            self.gatherers.append(Gatherer(
                c.gather_mode, threshold=c.gather_threshold,
                period=c.gather_period))
            self.pushers.append(Pusher(mshard, self.queue, self.plan,
                                       self.transform))
            self.scheduler.register(ComponentInfo("master", mshard.shard_id))

        # ---- serving plane ---------------------------------------------
        self.replica_sets: list[ReplicaSet] = []
        self.scatters: list[Scatter] = []
        for sid in range(c.num_slave):
            rs = ReplicaSet([SlaveShard(sid, self.groups,
                                        backend=c.ps_backend,
                                        codec_backend=c.codec_backend)
                             for _ in range(c.num_replicas)])
            for rid, shard in enumerate(rs.replicas):
                sc = Scatter(shard, self.queue, self.plan)
                self.scatters.append(sc)
                rs.attach_scatter(shard, sc)   # staleness signal for picks
                self.scheduler.register(ComponentInfo("slave", sid, rid))
            self.replica_sets.append(rs)

        # the serving subsystem: vectorized pull + serve cache +
        # micro-batching scheduler + scenario registry. Its RowRouter is
        # shared with the training-plane pull (see _pull_rows) — the two
        # planes run the same routing/gather code, which is the symmetry
        # the paper names.
        admission = None
        if c.serve_max_pending is not None or c.serve_deadline is not None:
            from repro.serving.scheduler import AdmissionConfig
            admission = AdmissionConfig(max_pending=c.serve_max_pending,
                                        deadline=c.serve_deadline)
        self.serving = ServingPlane(
            self.plan, self.replica_sets, self.groups,
            max_replica_lag=c.serve_max_lag,
            cache_rows=c.serve_cache_rows, buckets=c.serve_buckets,
            ps_backend=c.ps_backend, admission=admission, clock=clock)
        self.add_scenario(model_cfg)          # default scenario
        for rs in self.replica_sets:
            for shard in rs.replicas:
                shard.on_apply = self.serving.on_applied

        # ---- training plane ---------------------------------------------
        # the symmetric twin of the serving subsystem: per-scenario
        # weighted/bucketed train steps, admission-gated row creation,
        # ingest pipelines with sync-lag backpressure (src/repro/training/)
        self.training = TrainingPlane(
            self.plan, self.masters, self.groups, self.optimizer,
            feature_filter=self.filter,
            on_new_groups=self._on_new_train_groups, seed=c.seed)
        self.train_scheduler = TrainScheduler(self.training)
        default_scn = self.training.add_scenario(model_cfg)
        self.scheduler.register_train_scenario(
            self.cfg.name, default_scn.name,
            {"model_type": model_cfg.model_type,
             "groups": sorted(default_scn.store_groups)})
        # compat aliases: the default scenario IS the old single-model
        # training state (same dict objects — mutations shared)
        self.dense = default_scn.dense
        self.dense_slots = default_scn.dense_slots

        # ---- stability machinery ----------------------------------------
        self.validator = default_scn.validator
        self.store = CheckpointStore(c.ckpt_root)
        self.cold_backup = ColdBackup(
            self.masters, self.store,
            BackupPolicy(c.local_ckpt_interval, c.remote_ckpt_interval,
                         incremental=c.ckpt_incremental,
                         compress=c.ckpt_compress),
            queue=self.queue, rng=random.Random(c.seed),
            codec_backend=c.codec_backend)
        self.versions = VersionManager(self.store)
        self.downgrader = DominoDowngrade(
            SmoothedThresholdTrigger(
                metric=c.downgrade_metric, threshold=c.downgrade_threshold,
                window=c.downgrade_window),
            self.versions, self._hot_switch)

        self._predict = ctr_model.predict_fn(model_cfg)

        # ---- observability ----------------------------------------------
        # one registry of stable dotted metric names over every
        # subsystem's counters; sync_metrics() is a thin tree view of it
        from repro.obs.metrics import MetricsRegistry
        self.metrics_registry = MetricsRegistry()
        self._register_metrics(self.metrics_registry)

    # ------------------------------------------------------------------
    # training plane (src/repro/training/)
    # ------------------------------------------------------------------
    @property
    def step(self) -> int:
        return self.training.scenario().step

    def _pull_rows(self, ids: np.ndarray):
        """Gather (B, F, dim) row tensors for every group from masters —
        the training-plane pull, running the SAME argsort ownership pass
        and bulk gather as the serving plane (``RowRouter``); only the
        fetch differs (master read vs. replica read)."""
        b, f = ids.shape
        uniq, inverse = RowRouter.unique(ids)
        vals = self.training.pull_unique(self.training.scenario(), uniq)
        return RowRouter.expand(vals, inverse, (b, f)), uniq, inverse

    def train_on_batch(self, ids: np.ndarray, y: np.ndarray,
                       now: float = 0.0,
                       weights: Optional[np.ndarray] = None) -> dict:
        """One online-learning step for the default scenario:
        predict-before-train validation, then gradient push through the
        PS optimizer (``TrainingPlane.train_batch``)."""
        return self.training.train_batch(
            self.training.scenario(), ids, y, now=now, weights=weights)

    def _on_new_train_groups(self, created: dict[str, int]) -> None:
        """An isolated training scenario added namespaced groups: create
        their serve tables on every slave replica (the sync stream will
        carry their records like any other group) and widen the serving
        plane's store-group view."""
        for rs in self.replica_sets:
            for shard in rs.replicas:
                for g, dim in created.items():
                    shard.add_group(g, dim)
        self.serving.store_groups.update(created)

    def add_train_scenario(self, cfg: CTRConfig, *,
                           name: Optional[str] = None,
                           share_groups: bool = False):
        """Train an additional model scenario off the shared PS. With
        ``share_groups`` the scenario refines the store's own groups (an
        LR head on an FM store); without it the groups (and dense head)
        are namespaced ``<name>/...`` — isolated parameters on shared
        infrastructure. Membership is published to the coordination
        registry like serving scenarios are."""
        scn = self.training.add_scenario(cfg, name=name,
                                         share_groups=share_groups)
        self.scheduler.register_train_scenario(
            self.cfg.name, scn.name,
            {"model_type": cfg.model_type,
             "groups": sorted(scn.store_groups),
             "shared": share_groups})
        return scn

    def make_train_pipeline(self, scenario: Optional[str] = None, *,
                            window: Optional[float] = None,
                            emit_on_feedback: bool = False,
                            neg_sample_rate: float = 1.0) -> TrainPipeline:
        """Build the ingest pipeline (join → admit → dedup → bucketed
        train) for a scenario, backpressure-bound to this cluster's sync
        plane, and register it with the train scheduler."""
        c = self.ccfg
        scn = self.training.scenario(scenario)
        joiner = SampleJoiner(
            window=c.join_window if window is None else window,
            emit_on_feedback=emit_on_feedback,
            neg_sample_rate=neg_sample_rate, seed=c.seed)
        return TrainPipeline(
            self.training, scn, joiner, buckets=c.train_buckets,
            lag_fn=self._sync_lag_records,
            max_sync_lag=c.train_max_sync_lag,
            buffer_cap=c.train_buffer_cap)

    def _sync_lag_records(self) -> int:
        """Records produced to the queue but not yet applied by the
        laggiest live serving replica — the backpressure signal."""
        return max((sc.lag() for sc in self.scatters if sc.shard.alive),
                   default=0)

    # ------------------------------------------------------------------
    # sync plane
    # ------------------------------------------------------------------
    def sync_tick(self, now: float, *, scatter: bool = True) -> int:
        n = 0
        for col, gat, push, master in zip(self.collectors, self.gatherers,
                                          self.pushers, self.masters):
            gat.offer(col.drain())
            if gat.ready(now):
                n += push.push(gat.flush(now), now)
        if scatter:
            for sc in self.scatters:
                if sc.shard.alive:
                    sc.poll(now=now)
        return n

    def expire_features(self, now: float) -> int:
        """Feature-filter expiry: delete stale rows, stream the deletions."""
        n = 0
        for m in self.masters:
            for group, table in m.tables.items():
                stale = self.filter.expired(table, m.step)
                if len(stale):
                    m.delete_rows(group, stale)
                    n += len(stale)
        return n

    # ------------------------------------------------------------------
    # serving plane
    # ------------------------------------------------------------------
    def serve_rows(self, ids: np.ndarray,
                   scenario: Optional[str] = None) -> dict[str, np.ndarray]:
        """Predictor pull path — delegated to the serving subsystem:
        serve-cache probe, then one argsort ownership pass over the
        misses feeding lag-bounded replica reads with failover."""
        return self.serving.serve_rows(ids, scenario)

    def predict(self, ids: np.ndarray,
                scenario: Optional[str] = None) -> np.ndarray:
        """Serving-plane predict through the micro-batching scheduler
        (pad-to-bucket, one jit compile per bucket shape)."""
        return self.serving.predict(ids, scenario)

    def add_scenario(self, cfg: CTRConfig, *,
                     name: Optional[str] = None):
        """Serve an additional model scenario (a group subset of the
        shared PS — e.g. an LR head off an FM store) with its own predict
        fn, cache namespace, scheduler, and metrics; membership is
        published to the coordination registry."""
        scn = self.serving.add_scenario(cfg, name=name)
        self.scheduler.register_scenario(
            self.cfg.name, scn.name,
            {"model_type": cfg.model_type, "groups": sorted(scn.groups)})
        return scn

    def _serve_dense(self) -> dict[str, np.ndarray]:
        # version-memoized via the serving plane's DenseCache (the seed
        # re-pulled and re-reshaped every tensor on every predict)
        return self.serving.serve_dense()

    # ------------------------------------------------------------------
    # stability plane
    # ------------------------------------------------------------------
    def maybe_checkpoint(self, now: float) -> Optional[int]:
        v = self.cold_backup.maybe_checkpoint(
            now, metrics={"logloss": self.validator.smoothed("logloss"),
                          "auc": self.validator.smoothed("auc")})
        if v is not None:
            self.scheduler.publish_version(self.cfg.name, v)
        return v

    def checkpoint(self, now: float, tier: str = "local") -> int:
        v = self.cold_backup.checkpoint(
            now, tier=tier,
            metrics={"logloss": self.validator.smoothed("logloss"),
                     "auc": self.validator.smoothed("auc")})
        self.scheduler.publish_version(self.cfg.name, v)
        return v

    def _serve_state(self, version: Optional[int] = None) -> dict:
        """Materialize a checkpoint chain into serving-plane rows: per
        group, the merged columnar row set across all master shards with
        ONE serve transform (train state -> inference weights) applied,
        plus the chain's queue offsets and merged dense bank."""
        from repro.core.fault_tolerance import merge_dense, merge_shard_tables
        state = self.cold_backup.materialize(version)
        groups = {}
        for g, rows in merge_shard_tables(state["shard_snaps"]).items():
            serve = self.transform.serve_values(rows["w"], rows["slots"])
            groups[g] = (rows["ids"], serve)
        dense = {"tensors": {}, "slots": {}, "versions": {}}
        for snap in state["shard_snaps"].values():
            merge_dense(dense, snap["dense"])
        return {"groups": groups, "dense": dense,
                "queue_offsets": state["queue_offsets"],
                "version": state["version"]}

    def _load_serve_rows(self, shards: list, ids: np.ndarray,
                         group: str, serve: np.ndarray) -> None:
        """Route serve rows to slave shards with one argsort ownership
        pass (the seed looped num_slave boolean masks per snapshot)."""
        from repro.core.fault_tolerance import iter_owner_segments
        by_sid: dict[int, list] = {}
        for shard in shards:
            by_sid.setdefault(shard.shard_id, []).append(shard)
        for sid, idx in iter_owner_segments(self.plan.slave_shard(ids)):
            reps = by_sid.get(sid, ())
            if not reps:
                continue
            seg_ids = ids.take(idx, mode="clip")
            seg_serve = serve.take(idx, axis=0, mode="clip")
            for shard in reps:
                shard.tables[group].scatter(seg_ids, seg_serve)

    @staticmethod
    def _apply_dense_state(shard: SlaveShard, dense: dict) -> None:
        """Install a materialized dense bank on a serving replica (the
        slave holds flattened decoded tensors + version counters, so
        replayed dense records older than the restored version LWW-skip
        and newer ones apply)."""
        for name, t in dense["tensors"].items():
            shard.dense[name] = np.asarray(t, np.float32).reshape(1, -1)
            shard.dense_versions[name] = dense["versions"][name]

    def _hot_switch(self, ckpt: Checkpoint) -> None:
        """Downgrade execution: rebuild slave serve state from the
        checkpoint *chain* (full + deltas materialized by the cold-backup
        plane, master-state -> serve transform), then seek every scatter
        to the checkpoint's queue offsets for consistent replay."""
        from repro.core.ps import SparseTable
        state = self._serve_state(ckpt.version)
        replicas = [shard for rs in self.replica_sets
                    for shard in rs.replicas]
        for shard in replicas:
            for g, dim in self.groups.items():
                shard.tables[g] = SparseTable(
                    dim, backend=self.ccfg.ps_backend)
            shard._applied_seq = {}
            shard.dense = {}
            shard.dense_versions = {}
            self._apply_dense_state(shard, state["dense"])
        for g, (ids, serve) in state["groups"].items():
            if len(ids):
                self._load_serve_rows(replicas, ids, g, serve)
        for sc in self.scatters:
            sc.seek(ckpt.queue_offsets)
        # the rebuild happened outside the stream — every cached serve
        # row and dense tensor is suspect, flush wholesale
        self.serving.invalidate_all()

    def downgrade_check(self, now: float) -> Optional[int]:
        """Domino-downgrade trigger read — fed by the default scenario's
        windowed ``StreamingEvaluator`` (the training plane's
        progressive-validation signal), closing the train→metric→degrade
        loop: a distribution shift the trainer sees trips the serving
        rollback."""
        return self.downgrader.maybe_downgrade(
            now, self.training.scenario().evaluator)

    # ------------------------------------------------------------------
    # chaos / recovery controls (fault-tolerance benchmarks)
    # ------------------------------------------------------------------
    def kill_master(self, shard_id: int) -> None:
        self.masters[shard_id].kill()
        self.scheduler.mark_dead("master", shard_id)

    def recover_master(self, shard_id: int) -> int:
        v = self.cold_backup.recover_shard(self.masters[shard_id])
        # streaming replay: re-push everything this shard owns, so slaves
        # reconverge even for updates lost after the checkpoint
        m = self.masters[shard_id]
        for group, table in m.tables.items():
            ids = table.all_ids()
            if len(ids):
                m.collector.record(group, ids, "upsert")
        return v

    def _bootstrap_replica(self, shard: SlaveShard) -> Optional[dict]:
        """Checkpoint-restore bootstrap for a fresh serving replica
        (§4.2.2, via the cold-backup plane instead of a peer full copy):
        load the latest checkpoint chain, keep only rows this shard owns,
        and return the stored queue offsets — the caller's Scatter
        replays the stream from there (streaming catch-up)."""
        if self.store.latest() is None:
            return None
        state = self._serve_state()
        for g, (ids, serve) in state["groups"].items():
            if len(ids):
                self._load_serve_rows([shard], ids, g, serve)
        self._apply_dense_state(shard, state["dense"])
        return dict(state["queue_offsets"])

    def add_slave_replica(self, shard_id: int) -> SlaveShard:
        """Grow a replica set online: checkpoint-restore + streaming
        catch-up when a checkpoint exists, else full copy from a healthy
        peer (whose consumer offsets the new Scatter inherits)."""
        c = self.ccfg
        rs = self.replica_sets[shard_id]
        shard = SlaveShard(shard_id, self.groups, backend=c.ps_backend,
                           codec_backend=c.codec_backend)
        offsets = rs.add_replica(shard, bootstrap=self._bootstrap_replica)
        if offsets is None:
            # peer-copied state already reflects everything the peer's
            # scatter applied — start the new consumer there, not at 0
            for sc in self.scatters:
                if sc.shard in rs.replicas and sc.shard is not shard \
                        and sc.shard.alive:
                    offsets = sc.offsets()
                    break
        sc = Scatter(shard, self.queue, self.plan, offsets=offsets)
        self.scatters.append(sc)
        rs.attach_scatter(shard, sc)
        shard.on_apply = self.serving.on_applied   # before catch-up: the
        # replayed records invalidate any cached rows they rewrite
        self.scheduler.register(ComponentInfo(
            "slave", shard_id, len(rs.replicas) - 1))
        sc.poll()          # streaming catch-up: ckpt offsets -> queue head
        return shard

    def kill_slave_replica(self, shard_id: int, replica_idx: int) -> None:
        self.replica_sets[shard_id].replicas[replica_idx].kill()
        self.scheduler.mark_dead("slave", shard_id, replica_idx)

    def _device_mirror_metrics(self) -> dict:
        """Aggregate device-mirror upload counters over every table a
        pallas path may have mirrored: master training tables, replica
        serve tables, and scenario cache arenas. All zeros (with
        ``tables: 0``) under the numpy backend."""
        agg = {"tables": 0, "syncs": 0, "key_full_uploads": 0,
               "key_incremental_uploads": 0, "key_bytes_uploaded": 0,
               "arena_bytes_uploaded": 0}
        tables = [t for m in self.masters for t in m.tables.values()]
        tables += [t for rs in self.replica_sets for rep in rs.replicas
                   for t in rep.tables.values()]
        tables += [scn.cache.table for scn in self.serving.registry]
        for t in tables:
            mm = t.mirror_metrics()
            if mm is None:
                continue
            agg["tables"] += 1
            for k in ("syncs", "key_full_uploads",
                      "key_incremental_uploads", "key_bytes_uploaded",
                      "arena_bytes_uploaded"):
                agg[k] += mm[k]
        return agg

    def _register_metrics(self, reg) -> None:
        """Wire every subsystem's counters into the cluster's
        ``MetricsRegistry`` at the exact dotted paths ``sync_metrics``
        has always exported — the registry's ``tree`` IS the
        sync-metrics dict, so the schema cannot drift from the registry
        (tests/test_metrics_schema.py locks both)."""
        from repro.core.monitor import PercentileRing
        reg.register("sync_lag_seconds", lambda now: max(
            (now - sc.last_record_time for sc in self.scatters
             if sc.shard.alive), default=0.0))
        # event→deployed staleness (push→scatter→cache-visible) across
        # every live scatter consumer — the harness's headline SLO
        reg.register("staleness", lambda: PercentileRing.merged_percentiles(
            [sc.staleness for sc in self.scatters if sc.shard.alive],
            (50, 99)))
        reg.register("sync_lag_records", self._sync_lag_records)
        reg.register("pushed_bytes",
                     lambda: sum(p.pushed_bytes for p in self.pushers))
        reg.register("queue_bytes", lambda: self.queue.produced_bytes)
        reg.register("dedup_ratio", lambda: float(np.mean(
            [g.stats.dedup_ratio for g in self.gatherers])))
        reg.register("replica_failovers",
                     lambda: sum(rs.failovers for rs in self.replica_sets))
        reg.register("replica_lag_skips",
                     lambda: sum(rs.lag_skips for rs in self.replica_sets))
        reg.register("device_mirror", self._device_mirror_metrics)
        self.serving.register_metrics(reg, prefix="serving")
        # one source of truth for the benchmark and the monitor:
        # joiner counters (late_feedback, join-delay percentiles),
        # backpressure shed/throttle counts, dedup/padding ratios
        self.training.register_metrics(reg, prefix="training")

    def sync_metrics(self, now: float) -> dict:
        """Thin view over the metrics registry: the same nested dict
        this method has returned since PR 2, assembled from the
        providers each subsystem registered (``repro.obs.metrics``)."""
        return self.metrics_registry.tree(now)
