"""Domino downgrade (paper §4.3.2): smoothed-threshold trigger + hot version
switch back to a stable checkpointed version, with queue-offset replay.

Any stored version qualifies as a switch target — full or delta: the
executor's ``switch_fn`` restores through the cold-backup chain
(``ColdBackup.materialize`` folds full+deltas into full-equivalent state)
and seeks the serving consumers to the checkpoint's queue offsets, so
streaming replay resumes exactly where the restored state left off. See
``docs/FAULT_TOLERANCE.md`` for the runbook.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.fault_tolerance import Checkpoint, CheckpointStore
from repro.core.monitor import ProgressiveValidator


@dataclass
class SmoothedThresholdTrigger:
    """Fires when the *smoothed* metric crosses ``threshold``. Smoothing
    over ``window`` contrast points suppresses single-batch false alarms
    (§4.3.2a). ``direction`` = "above" (e.g. logloss) or "below" (auc)."""

    metric: str = "logloss"
    threshold: float = 1.0
    window: int = 10
    direction: str = "above"
    min_points: int = 5

    def check(self, validator: ProgressiveValidator) -> bool:
        if len(validator.history) < self.min_points:
            return False
        v = validator.smoothed(self.metric, self.window)
        return v > self.threshold if self.direction == "above" \
            else v < self.threshold


class VersionManager:
    """Registry of model versions = checkpoints + their metrics; supports
    the two switching strategies: latest-stable and best-metric (§4.3.2b)."""

    def __init__(self, store: CheckpointStore):
        self.store = store
        self.current_version: Optional[int] = None
        self.bad_versions: set[int] = set()

    def stable_versions(self) -> list[int]:
        return [v for v in self.store.versions() if v not in self.bad_versions]

    def pick(self, strategy: str = "latest",
             metric: str = "logloss", direction: str = "min") -> int:
        candidates = self.stable_versions()
        assert candidates, "no stable version to downgrade to"
        if strategy == "latest":
            return candidates[-1]
        if strategy == "best":
            def score(v):
                m = self.store.load(v).metrics.get(metric)
                if m is None:
                    return float("inf") if direction == "min" else -float("inf")
                return m
            return (min if direction == "min" else max)(candidates, key=score)
        raise ValueError(strategy)


class DominoDowngrade:
    """Trigger + execution. ``switch_fn(ckpt)`` performs the hot switch:
    reload slave state from the checkpoint (materializing its full+delta
    chain — see ``WeiPSCluster._hot_switch``) and seek scatters to the
    stored queue offsets so streaming resumes consistently."""

    def __init__(self, trigger: SmoothedThresholdTrigger,
                 versions: VersionManager,
                 switch_fn: Callable[[Checkpoint], None],
                 strategy: str = "latest", cooldown: float = 0.0):
        self.trigger = trigger
        self.versions = versions
        self.switch_fn = switch_fn
        self.strategy = strategy
        # refractory window after a switch: the smoothed trigger metric
        # still averages pre-switch contrast points for up to ``window``
        # batches, so without a cooldown one bad stretch cascades through
        # every stored version before the restored model gets a reading.
        self.cooldown = cooldown
        self.downgrades: list[tuple[float, int]] = []

    def active(self, now: float) -> bool:
        """True while the last downgrade's cooldown window is open — the
        "fired" state; it un-fires when the window closes without the
        trigger tripping again."""
        return bool(self.downgrades) and \
            (now - self.downgrades[-1][0]) < self.cooldown

    def maybe_downgrade(self, now: float,
                        validator: ProgressiveValidator) -> Optional[int]:
        if self.active(now):
            return None
        if not self.trigger.check(validator):
            return None
        return self.execute(now)

    def execute(self, now: float, version: Optional[int] = None) -> int:
        """Manual or automatic downgrade to ``version`` (or per strategy)."""
        cur = self.versions.current_version
        if cur is not None:
            self.versions.bad_versions.add(cur)
        v = version if version is not None else self.versions.pick(
            self.strategy)
        ckpt = self.versions.store.load(v)
        self.switch_fn(ckpt)
        self.versions.current_version = v
        self.downgrades.append((now, v))
        return v
