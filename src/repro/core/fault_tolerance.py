"""Multi-level fault tolerance (paper §4.2).

Cold backup (master): an incremental checkpoint/recovery plane —
  a) random-trigger scheduling (jittered per-cluster cadence so saves
     never aggregate traffic). Saves themselves are synchronous and
     in-process in this simulation; on a real deployment the columnar
     snapshot handed to ``CheckpointStore.save`` is the natural async
     boundary (ship it to a background uploader thread),
  b) hierarchical storage — frequent LOCAL tier, infrequent REMOTE tier
     (``CheckpointStore``); local-tier evictions past the retention
     window are *demoted* to the remote tier, never silently lost,
  c) full + delta checkpoints: the remote cadence writes full columnar
     snapshots, the local cadence writes deltas holding only the rows
     written since the previous checkpoint (``SparseTable`` mutation
     clock) plus evicted ids; restore chains full+deltas back together
     (``ColdBackup.materialize``) and is bit-equal to a full restore,
  d) queue offsets embedded in every checkpoint (streaming replay resumes
     exactly → strong consistency option),
  e) dynamic routing on load — a checkpoint written by N shards loads into
     M shards with one vectorized argsort ownership pass (reshard
     migration, §4.2.1d),
  f) partial recovery — restore a single crashed shard without restarting
     the cluster,
  g) optional int8 payload compression through the ``kernels/
     delta_codec.py`` row codec (``BackupPolicy.compress="int8"``).

Hot backup (slave): multi-replica sets with failover routing; a fresh
replica bootstraps from checkpoint-restore + streaming catch-up when a
checkpoint plane is wired (``ReplicaSet.add_replica(bootstrap=...)``),
falling back to a full copy from a healthy peer.

``docs/FAULT_TOLERANCE.md`` documents the checkpoint wire format, the
full/delta chaining rules, and the recovery runbook.
"""

from __future__ import annotations

import logging
import os
import pickle
import random
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.ps import MasterShard, SlaveShard
from repro.core.routing import owner_segments

logger = logging.getLogger(__name__)

_ROW_KEYS = ("ids", "w", "last_touch", "touch_count")


@dataclass
class Checkpoint:
    version: int
    created_at: float
    shard_snaps: dict[int, dict]          # shard_id -> snapshot
    queue_offsets: dict[int, int]         # partition -> offset at save time
    num_shards: int
    metrics: dict = field(default_factory=dict)
    tier: str = "local"
    kind: str = "full"                    # "full" | "delta"
    base: Optional[int] = None            # previous chain link (deltas)


def checkpoint_nbytes(ckpt: Checkpoint) -> int:
    """Payload size of a checkpoint: every numpy array in its shard snaps
    (ids, rows, slots, touch stats, compressed blocks, dense tensors)."""

    def walk(obj) -> int:
        if isinstance(obj, np.ndarray):
            return obj.nbytes
        if isinstance(obj, dict):
            return sum(walk(v) for v in obj.values())
        return 0

    return sum(walk(s) for s in ckpt.shard_snaps.values())


# ---------------------------------------------------------------------------
# int8 checkpoint compression (the delta_codec row path, reused verbatim)
# ---------------------------------------------------------------------------
def _pack_rows(a: np.ndarray, backend: str) -> dict:
    """(n, d) f32 -> {"q" int8 (n, d), "scale" f32 (n, 1)} via the same
    arithmetic as the streaming int8 codec (bit-compatible across
    numpy/pallas backends — see kernels/delta_codec.py)."""
    from repro.core.transform import Int8Transform
    if backend == "pallas" and a.size:
        from repro.kernels import ops
        q, s = ops.quantize_rows(
            np.ascontiguousarray(a, dtype=np.float32))
        return {"q": np.asarray(q), "scale": np.asarray(s)}
    return Int8Transform._quantize_np(a)


def _unpack_rows(p: dict, backend: str) -> np.ndarray:
    from repro.core.transform import Int8Transform
    return Int8Transform.decode(p, backend=backend)


def _compress_table_snap(tsnap: dict, backend: str) -> dict:
    out = dict(tsnap)
    out["codec"] = "int8"
    out["w"] = _pack_rows(tsnap["w"], backend)
    out["slots"] = {n: _pack_rows(v, backend)
                    for n, v in tsnap["slots"].items()}
    return out


def _table_rows(tsnap: dict, backend: str = "numpy") -> dict:
    """Raw columnar rows of a (possibly compressed) table snapshot."""
    rows = {k: tsnap[k] for k in _ROW_KEYS}
    rows["slots"] = tsnap["slots"]
    if "deleted" in tsnap:
        rows["deleted"] = tsnap["deleted"]
    if tsnap.get("codec") == "int8":
        rows["w"] = _unpack_rows(tsnap["w"], backend)
        rows["slots"] = {n: _unpack_rows(v, backend)
                        for n, v in tsnap["slots"].items()}
    return rows


# ---------------------------------------------------------------------------
# columnar row-set algebra (chain merge + ownership routing)
# ---------------------------------------------------------------------------
def _empty_rows(like: dict) -> dict:
    return {"ids": np.empty(0, np.int64),
            "w": np.empty((0,) + like["w"].shape[1:], like["w"].dtype),
            "slots": {n: np.empty((0,) + v.shape[1:], v.dtype)
                      for n, v in like["slots"].items()},
            "last_touch": np.empty(0, np.int64),
            "touch_count": np.empty(0, np.int64)}


def _take_rows(rows: dict, idx) -> dict:
    out = {k: rows[k][idx] for k in _ROW_KEYS}
    out["slots"] = {n: v[idx] for n, v in rows["slots"].items()}
    return out


def _concat_rows(parts: list[dict]) -> dict:
    if len(parts) == 1:
        return parts[0]
    out = {k: np.concatenate([p[k] for p in parts]) for k in _ROW_KEYS}
    out["slots"] = {n: np.concatenate([p["slots"][n] for p in parts])
                    for n in parts[0]["slots"]}
    return out


def _merge_rows(base: dict, delta: dict) -> dict:
    """Overlay a delta row set onto a base: deletes drop base rows, then
    delta rows override base rows id-wise (last writer wins). One
    vectorized pass — no per-id Python."""
    deleted = delta.get("deleted", np.empty(0, np.int64))
    if len(deleted):
        base = _take_rows(base, ~np.isin(base["ids"], deleted))
    if not len(delta["ids"]):
        return base
    if not len(base["ids"]):
        return {k: delta[k] for k in (*_ROW_KEYS, "slots")}
    cat_ids = np.concatenate([base["ids"], delta["ids"]])
    # last occurrence wins: unique over the reversed array finds, for
    # every id, its final position in concatenation order
    _, first_rev = np.unique(cat_ids[::-1], return_index=True)
    take = len(cat_ids) - 1 - first_rev
    merged = {k: np.concatenate([base[k], delta[k]]).take(take, axis=0)
              for k in _ROW_KEYS}
    merged["slots"] = {
        n: np.concatenate([base["slots"][n], delta["slots"][n]])
        .take(take, axis=0) for n in base["slots"]}
    return merged


def merge_dense(bank: dict, dense: dict) -> None:
    """Overlay a (possibly delta) dense snapshot onto an accumulating
    bank dict — newer version counters win per tensor."""
    for k, t in dense["tensors"].items():
        if dense["versions"][k] > bank["versions"].get(k, -1):
            bank["tensors"][k] = t
            if k in dense["slots"]:
                bank["slots"][k] = dense["slots"][k]
            bank["versions"][k] = dense["versions"][k]


def merge_shard_tables(shard_snaps: dict[int, dict]) -> dict[str, dict]:
    """Concatenate every shard's rows per group (ids are disjoint across
    shards) into one columnar row set — the input of ownership routing."""
    groups: dict[str, list[dict]] = {}
    for snap in shard_snaps.values():
        for g, rows in snap["tables"].items():
            if len(rows["ids"]):
                groups.setdefault(g, []).append(rows)
    return {g: _concat_rows(parts) for g, parts in groups.items()}


def iter_owner_segments(owner: np.ndarray):
    """Segment routing for recovery: one argsort over the whole set,
    replacing the O(shards x snaps) per-destination lambda filter of the
    seed recovery. Shared with the streaming pusher and the serving pull
    path — the canonical implementation lives in ``core.routing``."""
    return owner_segments(owner)


def iter_owner_rows(rows: dict, owner: np.ndarray):
    """``iter_owner_segments`` applied to a columnar row set: yields
    (owner_id, rows_slice)."""
    for dst, idx in iter_owner_segments(owner):
        yield dst, _take_rows(rows, idx)


def fold_chain(links_shard_snaps, codec_backend: str = "numpy") \
        -> dict[int, dict]:
    """Fold a full+delta chain of per-shard snapshots (apply order: full
    first) into full-equivalent columnar state — deletes drop rows, delta
    rows override base rows, dense tensors merge by version counter.

    ``links_shard_snaps`` iterates ``{shard_id: snapshot}`` per chain
    link, each snapshot in the ``MasterShard.snapshot`` /
    ``delta_snapshot`` wire format (possibly int8-compressed). Shared by
    ``ColdBackup.materialize`` (in-process checkpoints) and the
    multi-process runtime's manifest store (per-shard part files) — one
    implementation of the chain-merge semantics for both planes."""
    snaps: dict[int, dict] = {}
    for link in links_shard_snaps:
        for sid, snap in link.items():
            tables = {g: _table_rows(t, codec_backend)
                      for g, t in snap["tables"].items()}
            cur = snaps.get(sid)
            if cur is None:
                cur = {"shard_id": sid, "step": snap["step"],
                       "tables": {g: _merge_rows(_empty_rows(r), r)
                                  for g, r in tables.items()},
                       "dense": {"tensors": {}, "slots": {},
                                 "versions": {}}}
                snaps[sid] = cur
            else:
                cur["step"] = snap["step"]
                for g, rows in tables.items():
                    cur["tables"][g] = _merge_rows(
                        cur["tables"].get(g) or _empty_rows(rows), rows)
            dense = snap.get("dense")
            if dense:
                merge_dense(cur["dense"], dense)
    return snaps


class CheckpointStore:
    """Two-tier checkpoint storage. The local tier is in-memory (stands in
    for local disk); the remote tier serializes to files under ``root`` —
    slower, durable, written at a longer interval (paper §4.2.1b).

    Retention: at most ``keep`` checkpoints stay in the local tier. An
    evicted local-only checkpoint is *demoted* to the remote tier when a
    ``root`` is configured (so delta chains stay loadable); without a
    root it is log-dropped and recorded in ``dropped`` — never silently
    lost, and any retained delta whose chain ran through the dropped
    link is cascade-dropped with it. ``versions()`` therefore always
    reflects what ``load``-and-``materialize`` can actually serve."""

    def __init__(self, root: Optional[str] = None, keep: int = 8):
        self.root = root
        self.keep = keep
        self._local: dict[int, Checkpoint] = {}
        self._remote: dict[int, str] = {}
        # version -> base link (None for fulls); kept for every version
        # ever saved so chain integrity is checkable without loading
        # (remote loads unpickle the whole checkpoint)
        self._base: dict[int, Optional[int]] = {}
        self.dropped: list[int] = []
        if root:
            os.makedirs(root, exist_ok=True)

    def _write_remote(self, ckpt: Checkpoint) -> None:
        path = os.path.join(self.root, f"ckpt_{ckpt.version}.pkl")
        with open(path, "wb") as f:
            pickle.dump(ckpt, f, protocol=4)
        self._remote[ckpt.version] = path

    def chain_intact(self, version: int) -> bool:
        """True when every link from ``version`` back to its full base is
        still loadable (metadata walk — no checkpoint loads)."""
        v: Optional[int] = version
        while v is not None:
            if v not in self._local and v not in self._remote:
                return False
            v = self._base.get(v)
        return True

    def chain_depth(self, version: int) -> int:
        """Links from ``version`` back to (and including) its full base,
        by metadata walk."""
        d, v = 0, version
        while v is not None:
            d += 1
            v = self._base.get(v)
        return d

    def _drop(self, version: int, why: str) -> None:
        self._local.pop(version, None)
        self.dropped.append(version)
        logger.warning("checkpoint v%d dropped by local retention (%s)",
                       version, why)

    def save(self, ckpt: Checkpoint, tier: str = "local") -> None:
        ckpt.tier = tier
        self._local[ckpt.version] = ckpt
        self._base[ckpt.version] = ckpt.base
        if tier == "remote" and self.root:
            self._write_remote(ckpt)
        # retention: evict oldest local entries past the window
        while len(self._local) > self.keep:
            oldest = min(self._local)
            evicted = self._local.pop(oldest)
            if oldest in self._remote:
                continue                         # still served from remote
            if self.root:                        # demote instead of losing
                evicted.tier = "remote"
                self._write_remote(evicted)
                continue
            self.dropped.append(oldest)
            logger.warning(
                "checkpoint v%d dropped by local retention (no remote "
                "root configured)", oldest)
            # cascade: retained deltas that chained through the dropped
            # link are unrecoverable — drop them too, so versions()
            # never lists a checkpoint materialize() would fail on
            for v in sorted(self._local):
                if not self.chain_intact(v):
                    self._drop(v, f"chain through dropped v{oldest}")

    def load(self, version: int) -> Checkpoint:
        if version in self._local:
            return self._local[version]
        if version in self._remote:
            with open(self._remote[version], "rb") as f:
                return pickle.load(f)
        raise KeyError(f"no checkpoint version {version}")

    def versions(self) -> list[int]:
        return sorted(set(self._local) | set(self._remote))

    def latest(self) -> Optional[int]:
        v = self.versions()
        return v[-1] if v else None


@dataclass
class BackupPolicy:
    """Per-model fault-tolerance strategy — hot-switchable (§4.2.1c)."""

    local_interval: float = 30.0          # < 1 hour in production
    remote_interval: float = 3600.0       # hour/day level
    jitter: float = 0.25                  # random trigger fraction
    incremental: bool = True              # local cadence writes deltas
    compress: str = "none"                # "none" | "int8" (delta_codec)


class ColdBackup:
    """Checkpoint scheduler + recovery for the master cluster.

    The remote cadence emits FULL columnar checkpoints; the local cadence
    emits DELTA checkpoints (dirty rows + evicted ids since the previous
    checkpoint) when ``policy.incremental`` — each delta records its
    ``base`` so restore can chain full+deltas back together. Any recovery
    forces the next checkpoint to be full (the restored tables start a
    fresh mutation clock, so old dirty marks are meaningless)."""

    def __init__(self, shards: list[MasterShard], store: CheckpointStore,
                 policy: BackupPolicy, queue=None,
                 rng: Optional[random.Random] = None,
                 codec_backend: str = "numpy"):
        self.shards = shards
        self.store = store
        self.policy = policy
        self.queue = queue
        self.rng = rng or random.Random(0)
        self.codec_backend = codec_backend
        self._version = 0
        self._next_local = self._jittered(0.0, policy.local_interval)
        self._next_remote = self._jittered(0.0, policy.remote_interval)
        # delta bookkeeping: per-shard {group: mutation clock} and
        # {dense name: version} at the previous checkpoint
        self._marks: dict[int, dict[str, int]] = {}
        self._dense_marks: dict[int, dict[str, int]] = {}
        self._last_version: Optional[int] = None
        self._force_full = True

    def _jittered(self, now: float, interval: float) -> float:
        j = 1.0 + self.rng.uniform(-self.policy.jitter, self.policy.jitter)
        return now + interval * j

    def maybe_checkpoint(self, now: float,
                         metrics: Optional[dict] = None) -> Optional[int]:
        tier = None
        if now >= self._next_remote:
            tier = "remote"
            self._next_remote = self._jittered(now,
                                               self.policy.remote_interval)
            self._next_local = self._jittered(now, self.policy.local_interval)
        elif now >= self._next_local:
            tier = "local"
            self._next_local = self._jittered(now, self.policy.local_interval)
        if tier is None:
            return None
        return self.checkpoint(now, tier=tier, metrics=metrics)

    def checkpoint(self, now: float, tier: str = "local",
                   metrics: Optional[dict] = None) -> int:
        # a delta needs its whole base chain still loadable — retention
        # may have dropped a link (no remote root), in which case the
        # cadence self-heals by re-basing on a fresh full
        can_delta = (tier == "local" and self.policy.incremental
                     and self._last_version is not None
                     and not self._force_full
                     and self.store.chain_intact(self._last_version))
        if can_delta and self.store.root is None:
            # without a remote root a chain longer than the retention
            # window would evict its own base; re-base before that
            can_delta = (self.store.chain_depth(self._last_version) + 1
                         < self.store.keep)
        kind = "delta" if can_delta else "full"
        self._version += 1
        offsets = (self.queue.latest_offsets() if self.queue is not None
                   else {})
        snaps: dict[int, dict] = {}
        for s in self.shards:
            if not s.alive:
                continue
            if kind == "full":
                snaps[s.shard_id] = s.snapshot()
            else:
                snaps[s.shard_id] = s.delta_snapshot(
                    self._marks.get(s.shard_id, {}),
                    self._dense_marks.get(s.shard_id, {}))
            # advance marks to the clocks captured in this snapshot, and
            # trim eviction-log entries the marks now cover: future
            # deltas only ever ask for (mark, now] (marks never move
            # back — recovery forces the next checkpoint full), so the
            # log stays bounded by eviction traffic per ckpt interval
            self._marks[s.shard_id] = {
                g: t["version"] for g, t in snaps[s.shard_id]["tables"].items()}
            self._dense_marks[s.shard_id] = dict(s.dense.versions)
            for g, t in s.tables.items():
                t.trim_evict_log(self._marks[s.shard_id][g])
        if self.policy.compress == "int8":
            for snap in snaps.values():
                snap["tables"] = {
                    g: _compress_table_snap(t, self.codec_backend)
                    for g, t in snap["tables"].items()}
        ckpt = Checkpoint(
            version=self._version, created_at=now,
            shard_snaps=snaps,
            queue_offsets=offsets,
            num_shards=len(self.shards),
            metrics=dict(metrics or {}),
            kind=kind,
            base=self._last_version if kind == "delta" else None,
        )
        self.store.save(ckpt, tier=tier)
        self._last_version = self._version
        self._force_full = False
        return self._version

    # -- chain resolution --------------------------------------------------
    def chain(self, version: int) -> list[Checkpoint]:
        """The restore chain for ``version``: [full, delta, ..., delta]
        in apply order. Raises KeyError if a link was dropped by
        retention (configure a store root to demote instead)."""
        out = []
        v: Optional[int] = version
        while True:
            ckpt = self.store.load(v)
            out.append(ckpt)
            if ckpt.kind == "full":
                break
            assert ckpt.base is not None, \
                f"delta checkpoint v{ckpt.version} has no base"
            v = ckpt.base
        return out[::-1]

    def materialize(self, version: Optional[int] = None) -> dict:
        """Resolve a checkpoint version into full-equivalent state:
        decompress payloads and fold the full+delta chain (deletes drop
        rows, delta rows override base rows). Returns
        ``{version, queue_offsets, num_shards, shard_snaps}`` where every
        shard snap holds plain columnar rows — the single input format of
        all recovery paths."""
        v = version if version is not None else self.store.latest()
        assert v is not None, "no checkpoint available"
        links = self.chain(v)
        snaps = fold_chain((c.shard_snaps for c in links),
                           self.codec_backend)
        tip = links[-1]
        return {"version": tip.version, "created_at": tip.created_at,
                "queue_offsets": tip.queue_offsets,
                "num_shards": tip.num_shards, "shard_snaps": snaps}

    # -- recovery ---------------------------------------------------------
    def recover_shard(self, shard: MasterShard,
                      version: Optional[int] = None) -> int:
        """Partial fault tolerance (§4.2.1e): restore ONE shard from the
        newest checkpoint (chaining deltas as needed); the rest of the
        cluster keeps serving."""
        state = self.materialize(version)
        shard.clear()
        snap = state["shard_snaps"].get(shard.shard_id)
        if snap is not None:
            shard.load_snapshot(snap)
        shard.alive = True
        self._force_full = True
        return state["version"]

    def recover_all(self, shards: list[MasterShard],
                    version: Optional[int] = None,
                    owner_of: Optional[Callable] = None) -> int:
        """Full recovery with dynamic routing (§4.2.1d): the checkpoint may
        have been written by a different shard count; ``owner_of(ids)`` maps
        IDs to the *new* shard layout. Routing is one argsort ownership
        pass over the merged columnar row set per group — the seed's
        per-(shard, snapshot) lambda filter re-ran ``owner_of`` over every
        id for every destination."""
        state = self.materialize(version)
        for s in shards:
            s.clear()
            s.alive = True
        self._force_full = True
        snaps = state["shard_snaps"]
        if owner_of is None and state["num_shards"] == len(shards):
            for s in shards:
                snap = snaps.get(s.shard_id)
                if snap is not None:
                    s.load_snapshot(snap)
            return state["version"]
        assert owner_of is not None, (
            "shard count changed: recovery needs an owner_of routing fn")
        step = max((s["step"] for s in snaps.values()), default=0)
        by_id = {s.shard_id: s for s in shards}
        for s in shards:
            s.step = step
        for g, rows in merge_shard_tables(snaps).items():
            owner = np.asarray(owner_of(rows["ids"]), dtype=np.int64)
            for dst, part in iter_owner_rows(rows, owner):
                by_id[dst].load_table_rows(g, part)
        # dense tensors live on shard 0 by convention (see WeiPSCluster)
        dense = {"tensors": {}, "slots": {}, "versions": {}}
        for snap in snaps.values():
            merge_dense(dense, snap["dense"])
        if dense["tensors"]:
            from repro.core.ps import DenseBank
            by_id.get(0, shards[0]).dense = DenseBank.restore(dense)
        return state["version"]


class ReplicaSet:
    """Hot backup (§4.2.2): multi-replica load balancing over slave shards
    holding the same shard_id. Stateless LB + stateful replicas;
    consistency via checkpoint-restore + streaming catch-up (preferred)
    or full-sync from a peer.

    The serving plane attaches each replica's ``Scatter`` so selection can
    enforce a staleness bound: a replica whose consumer offsets trail the
    master's push head by more than ``max_lag`` records is skipped while a
    fresher healthy replica exists (availability still wins — when every
    replica exceeds the bound, the freshest one serves)."""

    def __init__(self, replicas: list[SlaveShard],
                 bootstrap: Optional[Callable[[SlaveShard],
                                              Optional[dict]]] = None):
        assert replicas
        self.replicas = replicas
        self.bootstrap = bootstrap
        self._rr = 0
        self._scatters: dict[int, object] = {}    # id(shard) -> Scatter
        self.failovers = 0
        self.lag_skips = 0

    def healthy(self) -> list[SlaveShard]:
        return [r for r in self.replicas if r.alive]

    def attach_scatter(self, shard: SlaveShard, scatter) -> None:
        """Register the consumer feeding ``shard`` — its offsets are the
        staleness signal the lag bound compares against the queue head."""
        self._scatters[id(shard)] = scatter

    def replica_lag(self, shard: SlaveShard) -> int:
        """Records produced to this shard's partitions not yet applied
        (0 when no scatter is attached — nothing to lag behind)."""
        sc = self._scatters.get(id(shard))
        return sc.lag() if sc is not None else 0

    def pick(self, max_lag: Optional[int] = None) -> SlaveShard:
        """Round-robin over healthy replicas; failover transparently.
        With ``max_lag`` set, replicas over the staleness bound are
        skipped unless no healthy replica is within it."""
        h = self.healthy()
        if not h:
            raise RuntimeError("all replicas down")
        if max_lag is not None and len(h) > 1:
            lags = [self.replica_lag(r) for r in h]
            fresh = [r for r, lag in zip(h, lags) if lag <= max_lag]
            if fresh and len(fresh) < len(h):
                self.lag_skips += len(h) - len(fresh)
                h = fresh
            elif not fresh:
                # every replica is stale: availability over freshness —
                # serve the one closest to the stream head
                h = [h[int(np.argmin(lags))]]
        r = h[self._rr % len(h)]
        self._rr += 1
        return r

    def read(self, fn: Callable[[SlaveShard], "np.ndarray"], *,
             max_lag: Optional[int] = None):
        """Serving read with failover retry — the request never fails
        while any replica lives (zero-downtime claim of §4.2.2)."""
        for _ in range(len(self.replicas)):
            r = self.pick(max_lag=max_lag)
            try:
                return fn(r)
            except AssertionError:
                self.failovers += 1
                continue
        raise RuntimeError("all replicas down")

    def lookup(self, group: str, ids: np.ndarray,
               max_lag: Optional[int] = None) -> np.ndarray:
        return self.read(lambda r: r.lookup(group, ids), max_lag=max_lag)

    def add_replica(self, shard: SlaveShard, *,
                    bootstrap: Optional[Callable] = None) -> Optional[dict]:
        """Bootstrap a fresh replica. With a ``bootstrap`` fn (per-call or
        set on the replica set), restore serve state from the checkpoint
        plane; it returns the checkpoint's queue offsets for the caller
        to seek a Scatter at — streaming catch-up covers everything since
        (see ``WeiPSCluster.add_slave_replica``). Otherwise fall back to
        a full copy from a healthy peer (returns None: the caller
        attaches a Scatter at the peer's offsets)."""
        fn = bootstrap if bootstrap is not None else self.bootstrap
        offsets = fn(shard) if fn is not None else None
        if offsets is None:
            shard.full_sync_from(self.healthy()[0])
        self.replicas.append(shard)
        return offsets
