"""Multi-level fault tolerance (paper §4.2).

Cold backup (master): checkpoints with
  a) random-trigger + async-save semantics (jittered per-shard schedule so
     saves never aggregate traffic),
  b) hierarchical storage — frequent LOCAL tier, infrequent REMOTE tier,
  c) queue offsets embedded in every checkpoint (streaming replay resumes
     exactly → strong consistency option),
  d) dynamic routing on load — a checkpoint written by N shards loads into
     M shards (reshard migration),
  e) partial recovery — restore a single crashed shard without restarting
     the cluster.

Hot backup (slave): multi-replica sets with failover routing; a fresh
replica bootstraps by full sync from a healthy peer then streaming catch-up.
"""

from __future__ import annotations

import os
import pickle
import random
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.ps import MasterShard, SlaveShard


@dataclass
class Checkpoint:
    version: int
    created_at: float
    shard_snaps: dict[int, dict]          # shard_id -> snapshot
    queue_offsets: dict[int, int]         # partition -> offset at save time
    num_shards: int
    metrics: dict = field(default_factory=dict)
    tier: str = "local"


class CheckpointStore:
    """Two-tier checkpoint storage. The local tier is in-memory (stands in
    for local disk); the remote tier serializes to files under ``root`` —
    slower, durable, written at a longer interval (paper §4.2.1b)."""

    def __init__(self, root: Optional[str] = None, keep: int = 8):
        self.root = root
        self.keep = keep
        self._local: dict[int, Checkpoint] = {}
        self._remote: dict[int, str] = {}
        if root:
            os.makedirs(root, exist_ok=True)

    def save(self, ckpt: Checkpoint, tier: str = "local") -> None:
        ckpt.tier = tier
        self._local[ckpt.version] = ckpt
        if tier == "remote" and self.root:
            path = os.path.join(self.root, f"ckpt_{ckpt.version}.pkl")
            with open(path, "wb") as f:
                pickle.dump(ckpt, f, protocol=4)
            self._remote[ckpt.version] = path
        # retention
        while len(self._local) > self.keep:
            oldest = min(self._local)
            if oldest in self._remote:
                self._local.pop(oldest)
            else:
                self._local.pop(oldest)

    def load(self, version: int) -> Checkpoint:
        if version in self._local:
            return self._local[version]
        if version in self._remote:
            with open(self._remote[version], "rb") as f:
                return pickle.load(f)
        raise KeyError(f"no checkpoint version {version}")

    def versions(self) -> list[int]:
        return sorted(set(self._local) | set(self._remote))

    def latest(self) -> Optional[int]:
        v = self.versions()
        return v[-1] if v else None


@dataclass
class BackupPolicy:
    """Per-model fault-tolerance strategy — hot-switchable (§4.2.1c)."""

    local_interval: float = 30.0          # < 1 hour in production
    remote_interval: float = 3600.0       # hour/day level
    jitter: float = 0.25                  # random trigger fraction
    incremental: bool = True              # queue doubles as incremental log


class ColdBackup:
    """Checkpoint scheduler + recovery for the master cluster."""

    def __init__(self, shards: list[MasterShard], store: CheckpointStore,
                 policy: BackupPolicy, queue=None,
                 rng: Optional[random.Random] = None):
        self.shards = shards
        self.store = store
        self.policy = policy
        self.queue = queue
        self.rng = rng or random.Random(0)
        self._version = 0
        self._next_local = self._jittered(0.0, policy.local_interval)
        self._next_remote = self._jittered(0.0, policy.remote_interval)

    def _jittered(self, now: float, interval: float) -> float:
        j = 1.0 + self.rng.uniform(-self.policy.jitter, self.policy.jitter)
        return now + interval * j

    def maybe_checkpoint(self, now: float,
                         metrics: Optional[dict] = None) -> Optional[int]:
        tier = None
        if now >= self._next_remote:
            tier = "remote"
            self._next_remote = self._jittered(now,
                                               self.policy.remote_interval)
            self._next_local = self._jittered(now, self.policy.local_interval)
        elif now >= self._next_local:
            tier = "local"
            self._next_local = self._jittered(now, self.policy.local_interval)
        if tier is None:
            return None
        return self.checkpoint(now, tier=tier, metrics=metrics)

    def checkpoint(self, now: float, tier: str = "local",
                   metrics: Optional[dict] = None) -> int:
        self._version += 1
        offsets = (self.queue.latest_offsets() if self.queue is not None
                   else {})
        ckpt = Checkpoint(
            version=self._version, created_at=now,
            shard_snaps={s.shard_id: s.snapshot() for s in self.shards
                         if s.alive},
            queue_offsets=offsets,
            num_shards=len(self.shards),
            metrics=dict(metrics or {}),
        )
        self.store.save(ckpt, tier=tier)
        return self._version

    # -- recovery ---------------------------------------------------------
    def recover_shard(self, shard: MasterShard,
                      version: Optional[int] = None) -> int:
        """Partial fault tolerance (§4.2.1e): restore ONE shard from the
        newest checkpoint; the rest of the cluster keeps serving."""
        v = version if version is not None else self.store.latest()
        assert v is not None, "no checkpoint available"
        ckpt = self.store.load(v)
        shard.clear()
        snap = ckpt.shard_snaps.get(shard.shard_id)
        if snap is not None:
            shard.load_snapshot(snap)
        shard.alive = True
        return v

    def recover_all(self, shards: list[MasterShard],
                    version: Optional[int] = None,
                    owner_of: Optional[Callable] = None) -> int:
        """Full recovery with dynamic routing (§4.2.1d): the checkpoint may
        have been written by a different shard count; ``owner_of(ids)`` maps
        IDs to the *new* shard layout."""
        v = version if version is not None else self.store.latest()
        assert v is not None, "no checkpoint available"
        ckpt = self.store.load(v)
        for s in shards:
            s.clear()
            s.alive = True
        if owner_of is None and ckpt.num_shards == len(shards):
            for s in shards:
                snap = ckpt.shard_snaps.get(s.shard_id)
                if snap is not None:
                    s.load_snapshot(snap)
            return v
        assert owner_of is not None, (
            "shard count changed: recovery needs an owner_of routing fn")
        for snap in ckpt.shard_snaps.values():
            for s in shards:
                sid = s.shard_id
                s.load_snapshot(
                    snap, ids_filter=lambda ids, sid=sid:
                    owner_of(ids) == sid)
        return v


class ReplicaSet:
    """Hot backup (§4.2.2): multi-replica load balancing over slave shards
    holding the same shard_id. Stateless LB + stateful replicas, consistency
    via full-sync + streaming catch-up."""

    def __init__(self, replicas: list[SlaveShard]):
        assert replicas
        self.replicas = replicas
        self._rr = 0
        self.failovers = 0

    def healthy(self) -> list[SlaveShard]:
        return [r for r in self.replicas if r.alive]

    def pick(self) -> SlaveShard:
        """Round-robin over healthy replicas; failover transparently."""
        h = self.healthy()
        if not h:
            raise RuntimeError("all replicas down")
        r = h[self._rr % len(h)]
        self._rr += 1
        return r

    def lookup(self, group: str, ids: np.ndarray) -> np.ndarray:
        """Serving read with failover retry — the request never fails while
        any replica lives (zero-downtime claim of §4.2.2)."""
        for _ in range(len(self.replicas)):
            r = self.pick()
            try:
                return r.lookup(group, ids)
            except AssertionError:
                self.failovers += 1
                continue
        raise RuntimeError("all replicas down")

    def add_replica(self, shard: SlaveShard) -> SlaveShard:
        """Bootstrap: full sync from a healthy peer, then the caller
        attaches a Scatter for streaming catch-up."""
        peer = self.healthy()[0]
        shard.full_sync_from(peer)
        self.replicas.append(shard)
        return shard
