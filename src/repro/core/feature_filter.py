"""Feature admission and expiry (paper §4.1c: "feature filter").

Admission: probabilistic / count-threshold entry so one-off junk features
never allocate PS rows. Expiry: rows untouched for ``ttl_steps`` are
deleted — and the deletion is *streamed* to slaves (the sync mechanism must
support parameter deletion, §4.1c).

Both paths are batched: admission counts live in a vectorized
``IdHashMap`` (id → running count) and expiry is one masked scan over the
table's ``last_touch`` column — no per-id Python.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.hashmap import IdHashMap


@dataclass
class FeatureFilter:
    min_count: int = 1            # admissions below this never create rows
    ttl_steps: int = 10_000       # expiry horizon (in master steps)
    counts: IdHashMap = field(default_factory=IdHashMap)

    def admit(self, ids: np.ndarray) -> np.ndarray:
        """Returns the unique ids admitted for row creation: those whose
        cumulative observation count has reached ``min_count``."""
        ids = np.asarray(ids, dtype=np.int64)
        if self.min_count <= 1:
            return ids
        uniq, batch_counts = np.unique(ids, return_counts=True)
        total = self.counts.lookup(uniq, default=0) + batch_counts
        self.counts.put(uniq, total)
        return uniq[total >= self.min_count]

    def expired(self, table, step: int) -> np.ndarray:
        """IDs whose last touch is older than ttl_steps."""
        ids = table.all_ids()
        if len(ids) == 0:
            return ids
        sl = table.lookup(ids)
        stale = table.last_touch[sl] < (step - self.ttl_steps)
        return ids[stale]
