"""Feature admission and expiry (paper §4.1c: "feature filter").

Admission: probabilistic / count-threshold entry so one-off junk features
never allocate PS rows. Expiry: rows untouched for ``ttl_steps`` are
deleted — and the deletion is *streamed* to slaves (the sync mechanism must
support parameter deletion, §4.1c).

Both paths are batched: admission counts live in a vectorized
``IdHashMap`` (id → running count) and expiry is one masked scan over the
table's ``last_touch`` column — no per-id Python.

The admission map itself is bounded: once it tracks more than
``max_tracked`` ids, a decay-and-trim pass halves every count, drops
ids that reach zero, and (if still over half the bound) evicts the
lowest-count survivors down to ``max_tracked // 2``. One-off junk ids
age out instead of accumulating forever; ids recurring often enough to
accumulate counts between trims keep (half) their admission progress.
Ids seen only once per trim interval cannot make progress under
capacity pressure — an unavoidable property of ANY bounded admission
map whose bound is smaller than the distinct-id traffic between trims
(size the bound accordingly). The map size is bounded by
``max_tracked`` plus one batch's distinct ids, never by the lifetime
id space.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.hashmap import IdHashMap


@dataclass
class FeatureFilter:
    min_count: int = 1            # admissions below this never create rows
    ttl_steps: int = 10_000       # expiry horizon (in master steps)
    max_tracked: int = 1 << 20    # admission-map bound (ids); decay past it
    counts: IdHashMap = field(default_factory=IdHashMap)
    trims: int = 0

    def admit(self, ids: np.ndarray) -> np.ndarray:
        """Returns the unique ids admitted for row creation: those whose
        cumulative observation count has reached ``min_count``."""
        ids = np.asarray(ids, dtype=np.int64)
        if self.min_count <= 1:
            return ids
        uniq, batch_counts = np.unique(ids, return_counts=True)
        total = self.counts.lookup(uniq, default=0) + batch_counts
        self.counts.put(uniq, total)
        if len(self.counts) > self.max_tracked:
            self._trim()
        return uniq[total >= self.min_count]

    def _trim(self) -> None:
        """Decay-and-trim: halve every admission count, drop ids that hit
        zero, then (if still over half the bound) evict the lowest-count
        survivors down to ``max_tracked // 2`` — the next trim can only
        fire after another ``max_tracked // 2`` distinct ids, which is
        the window recurring ids get to accumulate progress. Admission
        state only gates row *creation*, so decaying an already-admitted
        id never touches its existing PS row."""
        ids, counts = self.counts.items()
        counts = counts // 2
        keep = counts > 0
        ids, counts = ids[keep], counts[keep]
        target = max(1, self.max_tracked // 2)
        if len(ids) > target:
            top = np.argpartition(counts, len(counts) - target)[-target:]
            ids, counts = ids[top], counts[top]
        fresh = IdHashMap(max(16, len(ids) * 4))
        if len(ids):
            fresh.put(ids, counts)
        self.counts = fresh
        self.trims += 1

    def expired(self, table, step: int) -> np.ndarray:
        """IDs whose last touch is older than ttl_steps."""
        ids = table.all_ids()
        if len(ids) == 0:
            return ids
        sl = table.lookup(ids)
        stale = table.last_touch[sl] < (step - self.ttl_steps)
        return ids[stale]
