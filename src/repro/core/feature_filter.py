"""Feature admission and expiry (paper §4.1 c: "feature filter").

Admission: probabilistic / count-threshold entry so one-off junk features
never allocate PS rows. Expiry: rows untouched for ``ttl_steps`` are
deleted — and the deletion is *streamed* to slaves (the sync mechanism must
support parameter deletion, §4.1c).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class FeatureFilter:
    min_count: int = 1            # admissions below this never create rows
    ttl_steps: int = 10_000       # expiry horizon (in master steps)
    seen: dict = field(default_factory=dict)

    def admit(self, ids: np.ndarray) -> np.ndarray:
        """Returns the subset of ids admitted for row creation."""
        if self.min_count <= 1:
            return ids
        out = []
        for rid in np.asarray(ids).tolist():
            c = self.seen.get(rid, 0) + 1
            self.seen[rid] = c
            if c >= self.min_count:
                out.append(rid)
        return np.asarray(out, dtype=np.int64)

    def expired(self, table, step: int) -> np.ndarray:
        """IDs whose last touch is older than ttl_steps."""
        ids = table.all_ids()
        if len(ids) == 0:
            return ids
        sl = table._lookup(ids)
        stale = table.last_touch[sl] < (step - self.ttl_steps)
        return ids[stale]
