"""Vectorized open-addressing id→value hash map (the PS addressing core).

The parameter-server hot path resolves *minibatches* of int64 feature IDs
to arena slots. A Python ``dict`` forces a per-row interpreter loop —
hundreds of ns per ID, worse once the table outgrows cache — which caps
the whole PS at toy throughput (Monolith/PERSIA both make collisionless /
open-addressed embedding addressing the first-order fix). ``IdHashMap``
keeps the table as two flat NumPy arrays (keys, values) with linear
probing, so ``lookup`` / ``put`` / ``delete`` over a batch of N ids run a
handful of vectorized passes.

Probe structure (tuned for batch cost, not per-id cost):
  1. one single-slot round over the whole batch — at ≤50 % load this
     resolves the large majority of ids with two array gathers;
  2. windowed rounds over the shrinking remainder: each round fetches
     ``_WINDOW`` consecutive slots per unresolved id, so an id whose
     remaining cluster run is shorter than the window resolves in one
     round instead of run-length rounds.

Slot occupancy is encoded in the key array itself with two reserved
sentinels (the two most-negative int64 values — see ``EMPTY``/``TOMB``),
halving hot-path gather traffic versus a separate state array. Any other
int64 is a valid id. Deletion tombstones; the map rehashes (reclaiming
tombstones) when live + tombstone load crosses 25 %, which also keeps
cluster runs short for the windowed probe.
"""

from __future__ import annotations

import numpy as np

EMPTY = np.int64(-2 ** 63)          # reserved: empty slot
TOMB = np.int64(-2 ** 63 + 1)       # reserved: tombstone (deleted slot)

_WINDOW = 8           # slots fetched per vectorized tail round

_FIB = np.uint64(0x9E3779B97F4A7C15)      # ⌊2^64/φ⌋, odd


def home_slots(ids: np.ndarray, shift: np.uint64) -> np.ndarray:
    """Fibonacci hashing: the top ``64-shift`` bits of ``id·⌊2^64/φ⌋``.
    Two vector ops (multiply wraps mod 2^64, then shift) versus ~9 for a
    full SplitMix64 finalizer — at ≤25 % load with windowed tail probing
    the weaker low-bit avalanche costs nothing, and golden-ratio steps
    spread sequential ids perfectly. ``ids`` must be a contiguous int64
    array (the uint64 view is a free reinterpret, as is the int64 view of
    the result — slot indices are far below 2^63)."""
    return ((ids.view(np.uint64) * _FIB) >> shift).view(np.int64)


class IdHashMap:
    """Open-addressed int64→int64 map with batched, loop-free operations.

    Ids may be any int64 except the two reserved sentinel values
    (``EMPTY``, ``TOMB`` — the two most-negative int64s)."""

    def __init__(self, capacity: int = 1024):
        # structural version: bumped whenever the key table's CONTENTS or
        # layout change (alloc/rehash, insert, delete). Device mirrors of
        # the probe state (kernels/hashmap_probe.py) key their staleness
        # off this counter.
        self.version = 0
        # dirty-slot journal (off by default — zero overhead for maps with
        # no device mirror): once ``track_dirty_slots`` arms it, every
        # mutation records WHICH table slots it wrote, so a mirror can
        # re-upload just those slots instead of the whole key table on
        # every version bump. ``_journal_floor`` is the version before
        # which per-slot knowledge is lost (journal armed later, realloc,
        # clear, or overflow) — ``dirty_slots_since`` answers None there
        # and the mirror falls back to a full upload.
        self._journal: list[tuple[int, np.ndarray]] | None = None
        self._journal_floor = 0
        self._journal_slots = 0
        self._alloc(1 << max(4, int(capacity - 1).bit_length()))

    def _alloc(self, cap: int) -> None:
        self._cap = cap
        self._shift = np.uint64(64 - (cap.bit_length() - 1))
        self._imask = cap - 1
        self._keys = np.full(cap, EMPTY, dtype=np.int64)
        self._vals = np.zeros(cap, dtype=np.int64)
        self._size = 0
        self._tombs = 0
        self.version += 1
        self._journal_reset()           # layout changed: every slot moved

    # -- dirty-slot journal (device-mirror incremental sync) ----------------
    def track_dirty_slots(self) -> None:
        """Arm the journal (idempotent). Mutations before this call are
        not covered — ``dirty_slots_since`` of an older version answers
        None (full upload)."""
        if self._journal is None:
            self._journal = []
            self._journal_floor = self.version
            self._journal_slots = 0

    def _journal_reset(self) -> None:
        if self._journal is not None:
            self._journal = []
        self._journal_floor = self.version
        self._journal_slots = 0

    def _note_dirty(self, slots: np.ndarray) -> None:
        if self._journal is None or not len(slots):
            return
        # bound journal memory: past a quarter of the capacity a full
        # upload is cheaper than replaying the log anyway
        self._journal_slots += len(slots)
        if self._journal_slots * 4 > self._cap:
            self._journal_reset()
            return
        self._journal.append((self.version, np.asarray(slots, np.int64)))

    def dirty_slots_since(self, version: int) -> np.ndarray | None:
        """Unique table slots written after ``version``, or None when the
        journal cannot answer (unarmed, armed later than ``version``,
        realloc/clear/overflow since) — None means re-upload everything."""
        if self._journal is None or version < self._journal_floor:
            return None
        parts = [s for v, s in self._journal if v > version]
        if not parts:
            return np.empty(0, np.int64)
        return np.unique(np.concatenate(parts))

    def trim_dirty_log(self, version: int) -> None:
        """Drop journal entries at or below ``version`` — safe once every
        mirror has synced past it."""
        if self._journal is None:
            return
        self._journal = [(v, s) for v, s in self._journal if v > version]
        self._journal_slots = int(sum(len(s) for _, s in self._journal))

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        return self._size

    def __contains__(self, rid: int) -> bool:
        return bool(self.lookup(np.array([rid], np.int64))[0] >= 0)

    @property
    def capacity(self) -> int:
        return self._cap

    @property
    def load_factor(self) -> float:
        return (self._size + self._tombs) / self._cap

    @property
    def shift(self) -> np.uint64:
        """The Fibonacci-hash shift for the current capacity — with
        ``key_table`` this is the whole probe state a device-resident
        mirror needs (see ``kernels/hashmap_probe.py``)."""
        return self._shift

    @property
    def key_table(self) -> np.ndarray:
        """The raw slot-id array (``EMPTY``/``TOMB`` sentinels included),
        NOT a copy: read-only input for device probe mirrors. Stale after
        any mutation — check ``version``."""
        return self._keys

    @property
    def val_table(self) -> np.ndarray:
        """The raw value array, positionally aligned with ``key_table``
        (garbage at non-live slots). Same staleness contract."""
        return self._vals

    def clear(self) -> None:
        """Empty the map WITHOUT shrinking — one memset versus a realloc.
        Reset-and-refill consumers (the serve cache's cold flush) keep
        their grown capacity, so the refill pays no growth rehashes and
        the next probe hits the presized EMPTY-home fast path."""
        self._keys.fill(EMPTY)
        self._size = 0
        self._tombs = 0
        self.version += 1
        self._journal_reset()           # every slot changed: full upload

    def keys(self) -> np.ndarray:
        return self._keys[self._keys > TOMB].copy()    # sentinels are the
                                                       # two smallest int64s

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        live = self._keys > TOMB
        return self._keys[live].copy(), self._vals[live].copy()

    # -- probing ------------------------------------------------------------
    def _probe(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Table positions for ``ids``: (pos, found). Where ``found`` is
        False the chain reached an EMPTY slot and ``pos`` is meaningless.

        In-window resolution is order-safe: inserts claim the first
        non-FULL slot from an id's home, so a live key never sits after an
        EMPTY slot on its own chain."""
        ids = np.ascontiguousarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=bool)
        bad = None
        if int(ids.min()) <= int(TOMB):      # sentinel-valued queries can
            bad = ids <= TOMB                # never be stored: mask them
            ids = np.where(bad, np.int64(0), ids)
        # round 1: single slot, whole batch. ``mode="clip"`` everywhere:
        # indices are in-bounds by construction, and clip skips NumPy's
        # per-element bounds-check slow path (~5× faster gathers).
        cur = home_slots(ids, self._shift)
        k = self._keys.take(cur, mode="clip")
        hit = k == ids
        pos = cur                    # unresolved entries are overwritten in
        found = hit                  # the tail; garbage where found=False
        # ids missing at an EMPTY home slot are definitive misses (inserts
        # claim the first non-FULL slot from home, so a live key never sits
        # past an EMPTY slot on its own chain): resolve them here instead
        # of paying a windowed tail round. The test runs over the round-1
        # miss subset only, so all-hit hot batches skip it entirely —
        # while miss-heavy batches (cold serve pulls probing a near-empty
        # cache) drop from one (m, W) window gather to an (m,) compare.
        idx = np.flatnonzero(~hit)
        if idx.size:
            idx = idx[k.take(idx, mode="clip") != EMPTY]
        if idx.size:
            # tail rounds: window per unresolved id
            cur = (cur[idx] + 1) & self._imask
            tgt = ids[idx]
            win = np.arange(_WINDOW)
            for _ in range(self._cap // _WINDOW + 2):
                cand = (cur[:, None] + win) & self._imask      # (m, W)
                kw = self._keys.take(cand, mode="clip")
                hitw = kw == tgt[:, None]
                ha = hitw.any(axis=1)
                if ha.any():
                    rows = np.nonzero(ha)[0]
                    pos[idx[rows]] = cand[rows, hitw.argmax(axis=1)[rows]]
                    found[idx[rows]] = True
                cont = ~ha & ~(kw == EMPTY).any(axis=1)
                sel = np.nonzero(cont)[0]
                if sel.size == 0:
                    break
                idx = idx[sel]
                tgt = tgt[sel]
                cur = (cur[sel] + _WINDOW) & self._imask
            else:
                raise RuntimeError("IdHashMap probe did not terminate")
        if bad is not None:
            found[bad] = False
        return pos, found

    def lookup_mask(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched get: (values, found). Values are garbage where ``found``
        is False — the zero-branch primitive the PS ensure path builds on."""
        pos, found = self._probe(ids)
        return self._vals.take(pos, mode="clip"), found

    def lookup(self, ids: np.ndarray, default: int = -1) -> np.ndarray:
        """Batched get: values for ids, ``default`` where missing."""
        v, found = self.lookup_mask(ids)
        if found.all():                       # hot path: every id present
            return v
        return np.where(found, v, np.int64(default))

    # -- mutation -----------------------------------------------------------
    def put(self, ids: np.ndarray, vals: np.ndarray) -> None:
        """Batched upsert. ``ids`` must be unique within the call (batch
        callers dedupe with np.unique; duplicate ids in one put would race
        for the same chain)."""
        ids = np.asarray(ids, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.int64)
        pos, found = self._probe(ids)
        if found.any():
            self._vals[pos[found]] = vals[found]
            # value-only rewrites move no keys but DO change the slot→val
            # mapping a device mirror holds: version them like any other
            # table mutation so mirrors refresh those slots
            self.version += 1
            self._note_dirty(pos[found])
        miss = ~found
        if miss.any():
            self._insert_new(ids[miss], vals[miss])

    def insert(self, ids: np.ndarray, vals: np.ndarray) -> None:
        """Batched insert of ids the caller KNOWS are unique and absent
        (e.g. just confirmed by ``lookup``) — skips the existence probe."""
        self._insert_new(np.asarray(ids, dtype=np.int64),
                         np.asarray(vals, dtype=np.int64))

    def _maybe_grow(self, extra: int) -> None:
        # grow at 25 % load (live + tombstones): short cluster runs keep
        # the probe at ~one vectorized round per batch (space/time trade in
        # the Monolith collisionless-table spirit: 16 B/id of map overhead
        # is noise next to the parameter rows it addresses).
        if (self._size + self._tombs + extra) * 4 < self._cap:
            return
        cap = self._cap
        need = self._size + extra                 # rehash clears tombstones
        while need * 4 >= cap:
            cap *= 2
        live = self._keys > TOMB
        keys, vals = self._keys[live].copy(), self._vals[live].copy()
        self._alloc(cap)
        if len(keys):
            self._insert_new(keys, vals)

    def _insert_new(self, ids: np.ndarray, vals: np.ndarray) -> None:
        """Insert ids known to be unique AND absent. Round-based
        write-and-verify claiming: every pending id blindly writes its
        (id, val) pair to its current probe slot — candidates racing for
        one slot overwrite each other, but the LAST writer lands both
        arrays consistently — then one re-gather of the key column
        identifies the winners. Losers (and ids whose slot was already
        occupied) advance one step and retry — all vectorized. Versus a
        scatter-claim election into a side array this halves the scatter
        traffic of the dominant round (the whole batch, on a bulk fill)
        and needs no per-capacity scratch; versus the sort a
        ``np.unique(return_index)`` election costs it is O(m) per round.
        Blind writes are safe because candidate slots are free by the
        occupancy test taken in the same round, and ids are unique."""
        if len(ids) and (ids <= TOMB).any():
            raise ValueError("ids -2**63 and -2**63+1 are reserved")
        self._maybe_grow(len(ids))
        self.version += 1
        n = len(ids)
        if n == 0:
            return
        claimed: list[np.ndarray] = []      # journal: slots won per round
        vals = np.asarray(vals, dtype=np.int64)
        pos = home_slots(np.ascontiguousarray(ids), self._shift)
        # int32 pending indices (row counts are far below 2^31): half the
        # bookkeeping bytes of int64 on compress/advance passes
        pending = np.arange(n, dtype=np.int32)
        # bulk-fill shortcut (cleared/presized map, the serve-cache cold
        # install): with no occupants, round-1 contention is batch-internal
        # only — skip the occupancy gather and the tombstone accounting
        pristine = self._size == 0 and self._tombs == 0
        for _ in range(2 * self._cap + 2):
            p = pos.take(pending, mode="clip")
            if pristine:
                kf = None                                # everything free
                whole, cand, cp = True, pending, p
            else:
                k = self._keys.take(p, mode="clip")
                free = k <= TOMB                         # EMPTY or TOMB
                whole = free.all()
                if whole:
                    cand, cp, kf = pending, p, k
                elif free.any():
                    cand, cp, kf = pending[free], p[free], k[free]
                else:
                    cand = None
            if cand is not None:
                idc = ids.take(cand, mode="clip")
                self._keys[cp] = idc
                self._vals[cp] = vals.take(cand, mode="clip")
                winmask = self._keys.take(cp, mode="clip") == idc
                nwin = int(winmask.sum())
                if kf is not None:
                    # pre-write occupancy at the won slots: reclaimed
                    # tombstones come off the tombstone count
                    self._tombs -= int((kf[winmask] == TOMB).sum())
                self._size += nwin
                if self._journal is not None and nwin:
                    claimed.append(cp[winmask])
                if nwin == len(pending):
                    if claimed:
                        self._note_dirty(np.concatenate(claimed))
                    return
                if whole:
                    # cand IS pending: losers drop out by mask, no O(n)
                    # won-table bookkeeping (this is the dominant round of
                    # a bulk fill — the whole batch is here)
                    pending = pending[~winmask]
                else:
                    won = np.zeros(n, dtype=bool)
                    won[cand[winmask]] = True
                    pending = pending[~won.take(pending, mode="clip")]
            # every survivor now sits on a FULL slot (pre-occupied or just
            # claimed by a race winner): advance the whole front. A
            # pristine table's survivors lost to a batch sibling, so the
            # table is no longer conflict-free past round 1.
            pristine = False
            pos[pending] = (pos.take(pending, mode="clip") + 1) & self._imask
        raise RuntimeError("IdHashMap insert did not terminate (table full?)")

    def delete(self, ids: np.ndarray) -> int:
        """Batched delete (tombstoning); returns #ids actually removed."""
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        pos, found = self._probe(ids)
        p = pos[found]
        if len(p):
            self._keys[p] = TOMB
            k = len(p)
            self._size -= k
            self._tombs += k
            self.version += 1
            self._note_dirty(p)
        return int(len(p))
