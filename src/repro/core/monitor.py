"""Model metrics monitoring via progressive validation (paper §4.3.1).

The prediction made on each training batch *before* its gradients are
applied is the evaluation signal: real-time (the data is the live stream)
and lossless (the same samples still train the model afterwards). Metrics
are kept as time series with windowed smoothing for the downgrade trigger
(core/downgrade.py; runbook in docs/FAULT_TOLERANCE.md). Validation is
in-process and synchronous with the training step — there is no separate
evaluator service in this simulation.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def logloss(y: np.ndarray, p: np.ndarray, eps: float = 1e-7) -> float:
    p = np.clip(p, eps, 1 - eps)
    return float(-np.mean(y * np.log(p) + (1 - y) * np.log(1 - p)))


def auc(y: np.ndarray, p: np.ndarray) -> float:
    """Rank-based AUC (ties averaged)."""
    order = np.argsort(p, kind="mergesort")
    ranks = np.empty(len(p), dtype=np.float64)
    ranks[order] = np.arange(1, len(p) + 1)
    # average ranks for ties
    sp = p[order]
    i = 0
    while i < len(sp):
        j = i
        while j + 1 < len(sp) and sp[j + 1] == sp[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j + 2) / 2.0
        i = j + 1
    n_pos = int(y.sum())
    n_neg = len(y) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y == 1].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


@dataclass
class MetricPoint:
    t: float
    step: int
    values: dict[str, float]


class ProgressiveValidator:
    """Accumulates predict-before-train metrics per batch."""

    def __init__(self, window: int = 50):
        self.history: list[MetricPoint] = []
        self.window = window

    def observe(self, t: float, step: int, y: np.ndarray,
                p: np.ndarray) -> MetricPoint:
        pt = MetricPoint(t=t, step=step, values={
            "logloss": logloss(y, p),
            "auc": auc(y, p),
            "pctr": float(np.mean(p)),
            "ctr": float(np.mean(y)),
        })
        self.history.append(pt)
        return pt

    def smoothed(self, metric: str, window: Optional[int] = None) -> float:
        """Smoothing over the last ``window`` contrast points (§4.3.2a)."""
        w = window or self.window
        pts = self.history[-w:]
        if not pts:
            return math.nan
        return float(np.mean([p.values[metric] for p in pts]))

    def latest(self, metric: str) -> float:
        return self.history[-1].values[metric] if self.history else math.nan
