"""Model metrics monitoring via progressive validation (paper §4.3.1).

The prediction made on each training batch *before* its gradients are
applied is the evaluation signal: real-time (the data is the live stream)
and lossless (the same samples still train the model afterwards). Metrics
are kept as time series with windowed smoothing for the downgrade trigger
(core/downgrade.py; runbook in docs/FAULT_TOLERANCE.md). Validation is
in-process and synchronous with the training step — there is no separate
evaluator service in this simulation.

Two evaluators:

* ``ProgressiveValidator`` — unbounded per-batch history (exact AUC per
  batch); the checkpoint-metrics source.
* ``StreamingEvaluator`` — the training plane's downgrade signal: bounded
  per-batch *aggregates* (weighted logloss sums + prediction histograms),
  so windowed logloss / AUC / calibration over the last W batches are
  computed from summed aggregates in O(bins) — example-weighted across
  the window rather than a mean of batch means, and supporting the
  pipeline's sample weights (negative-downsampling correction). It
  duck-types the trigger interface (``history`` + ``smoothed``), so
  ``SmoothedThresholdTrigger`` reads either evaluator unchanged.

Latency/staleness machinery shared by the SLO harness
(``benchmarks/e2e_slo.py``), the serving plane's admission controller,
and the sync plane's staleness meter:

* ``PercentileRing`` — a fixed-size ring of recent scalar observations
  (latencies, join delays, staleness seconds) answering windowed
  percentile queries in O(ring). Promoted from the joiner's private
  join-delay ring so every plane reads the SAME percentile machinery.
  It too duck-types the trigger interface: ``smoothed("p99")`` over a
  ring of predict latencies makes ``SmoothedThresholdTrigger`` a
  latency-SLO trigger with zero new code.
* ``ManualClock`` — an injectable time source (callable, like
  ``time.perf_counter``) that only advances when told to. Threaded
  through the predict scheduler's admission controller and the SLO
  harness, it replays overload scenarios deterministically in tier-1
  tests: queueing delay becomes exact simulated seconds instead of
  machine-dependent wall time.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def logloss(y: np.ndarray, p: np.ndarray, eps: float = 1e-7) -> float:
    p = np.clip(p, eps, 1 - eps)
    return float(-np.mean(y * np.log(p) + (1 - y) * np.log(1 - p)))


def auc(y: np.ndarray, p: np.ndarray) -> float:
    """Rank-based AUC (ties averaged)."""
    order = np.argsort(p, kind="mergesort")
    ranks = np.empty(len(p), dtype=np.float64)
    ranks[order] = np.arange(1, len(p) + 1)
    # average ranks for ties
    sp = p[order]
    i = 0
    while i < len(sp):
        j = i
        while j + 1 < len(sp) and sp[j + 1] == sp[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j + 2) / 2.0
        i = j + 1
    n_pos = int(y.sum())
    n_neg = len(y) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y == 1].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


@dataclass
class MetricPoint:
    t: float
    step: int
    values: dict[str, float]


class ManualClock:
    """Deterministic injectable time source. Call it like
    ``time.perf_counter`` (the default clock everywhere one is
    injectable); it returns the same instant until ``advance``/``set``
    move it — simulated seconds under test control."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t

    def set(self, t: float) -> float:
        self.t = float(t)
        return self.t


class PercentileRing:
    """Fixed-size ring of recent observations with windowed percentiles.

    ``record`` accepts scalars or arrays; once more than ``size`` values
    have been recorded the oldest are overwritten — memory stays O(size)
    for unbounded streams, and percentiles describe the *recent* window,
    which is what an SLO cares about (a latency spike an hour ago must
    not dilute the current p99).

    Trigger duck-typing: ``history`` (sized) + ``smoothed(metric,
    window)`` with metric one of ``p<q>`` / ``mean`` / ``max`` — so
    ``SmoothedThresholdTrigger`` can fire on a latency or staleness ring
    exactly as it fires on an evaluator's logloss.
    """

    def __init__(self, size: int = 1 << 14):
        assert size > 0
        self.size = int(size)
        self._buf = np.zeros(self.size, np.float64)
        self._n = 0                     # total values ever recorded

    def __len__(self) -> int:
        return min(self._n, self.size)

    @property
    def count(self) -> int:
        """Total observations ever recorded (not capped by the ring)."""
        return self._n

    @property
    def history(self):
        """Trigger interface: the retained window, oldest→newest."""
        return self.values()

    def record(self, values) -> None:
        v = np.atleast_1d(np.asarray(values, np.float64))
        n = len(v)
        if n == 0:
            return
        if n >= self.size:              # whole ring replaced — lay the
            # surviving tail at the ring positions its chronological
            # indices map to, so values() reconstructs order correctly
            tail = v[n - self.size:]
            at = (self._n + n - self.size) % self.size
            take = self.size - at
            self._buf[at:] = tail[:take]
            self._buf[:at] = tail[take:]
            self._n += n
            return
        at = self._n % self.size
        take = min(n, self.size - at)
        self._buf[at:at + take] = v[:take]
        if take < n:                    # wrap
            self._buf[:n - take] = v[take:]
        self._n += n

    def values(self) -> np.ndarray:
        """Retained observations in chronological order."""
        n = len(self)
        if self._n <= self.size:
            return self._buf[:n]
        at = self._n % self.size
        return np.concatenate([self._buf[at:], self._buf[:at]])

    def percentiles(self, qs=(50, 99)) -> dict[str, float]:
        n = len(self)
        if n == 0:
            return {f"p{q}": 0.0 for q in qs}
        vals = np.percentile(self._buf[:n], qs)
        return {f"p{q}": float(v) for q, v in zip(qs, vals)}

    def smoothed(self, metric: str, window: Optional[int] = None) -> float:
        """Trigger interface: windowed statistic over the last ``window``
        observations (whole retained ring when None)."""
        vals = self.values()
        if window is not None:
            vals = vals[-window:]
        if len(vals) == 0:
            return math.nan
        if metric == "mean":
            return float(np.mean(vals))
        if metric == "max":
            return float(np.max(vals))
        if metric.startswith("p"):
            return float(np.percentile(vals, float(metric[1:])))
        raise ValueError(f"unknown ring metric {metric!r}")

    def reset(self) -> None:
        self._n = 0

    @staticmethod
    def merged_percentiles(rings: list["PercentileRing"],
                           qs=(50, 99)) -> dict[str, float]:
        """Percentiles over the union of several rings' retained windows
        (e.g. one staleness figure across every scatter consumer)."""
        vals = [r.values() for r in rings if len(r)]
        if not vals:
            return {f"p{q}": 0.0 for q in qs}
        cat = np.concatenate(vals)
        out = np.percentile(cat, qs)
        return {f"p{q}": float(v) for q, v in zip(qs, out)}


class ProgressiveValidator:
    """Accumulates predict-before-train metrics per batch."""

    def __init__(self, window: int = 50):
        self.history: list[MetricPoint] = []
        self.window = window

    def observe(self, t: float, step: int, y: np.ndarray,
                p: np.ndarray) -> MetricPoint:
        pt = MetricPoint(t=t, step=step, values={
            "logloss": logloss(y, p),
            "auc": auc(y, p),
            "pctr": float(np.mean(p)),
            "ctr": float(np.mean(y)),
        })
        self.history.append(pt)
        return pt

    def smoothed(self, metric: str, window: Optional[int] = None) -> float:
        """Smoothing over the last ``window`` contrast points (§4.3.2a)."""
        w = window or self.window
        pts = self.history[-w:]
        if not pts:
            return math.nan
        return float(np.mean([p.values[metric] for p in pts]))

    def latest(self, metric: str) -> float:
        return self.history[-1].values[metric] if self.history else math.nan


def _hist_auc(pos: np.ndarray, neg: np.ndarray) -> float:
    """AUC from per-bin positive/negative mass (ties within a bin count
    half — the binned equivalent of rank-based AUC)."""
    p_tot, n_tot = pos.sum(), neg.sum()
    if p_tot <= 0 or n_tot <= 0:
        return 0.5
    neg_below = np.concatenate(([0.0], np.cumsum(neg)[:-1]))
    return float((pos * (neg_below + 0.5 * neg)).sum() / (p_tot * n_tot))


class StreamingEvaluator:
    """Windowed streaming progressive validation from per-batch aggregates.

    ``observe`` folds one pre-update prediction batch into weighted
    aggregates (logloss sum, prediction histograms split by label, pctr /
    ctr sums); windowed metrics sum the last W aggregates — memory is
    O(window × bins) regardless of stream length. ``calibration`` is the
    pCTR/CTR ratio (1.0 = perfectly calibrated), the metric the paper's
    monitoring dashboards track alongside AUC."""

    def __init__(self, window: int = 50, bins: int = 256):
        self.window = window
        self.bins = bins
        self.history: deque = deque(maxlen=window)   # MetricPoint per batch
        self._agg: deque = deque(maxlen=window)      # aligned aggregates

    def observe(self, t: float, step: int, y: np.ndarray, p: np.ndarray,
                weights: Optional[np.ndarray] = None) -> MetricPoint:
        y = np.asarray(y, np.float64)
        p = np.asarray(p, np.float64)
        w = np.ones(len(y)) if weights is None else \
            np.asarray(weights, np.float64)
        eps = 1e-7
        pc = np.clip(p, eps, 1 - eps)
        ll = -(y * np.log(pc) + (1 - y) * np.log(1 - pc))
        bi = np.minimum((p * self.bins).astype(np.int64), self.bins - 1)
        agg = {
            "w": float(w.sum()),
            "ll": float((w * ll).sum()),
            "wp": float((w * p).sum()),
            "wy": float((w * y).sum()),
            "pos": np.bincount(bi, weights=w * y, minlength=self.bins),
            "neg": np.bincount(bi, weights=w * (1 - y),
                               minlength=self.bins),
        }
        self._agg.append(agg)
        point = MetricPoint(t=t, step=step,
                            values=self._windowed(len(self._agg)))
        self.history.append(point)
        return point

    def _windowed(self, w: int) -> dict[str, float]:
        aggs = list(self._agg)[-w:]
        if not aggs:
            return {"logloss": math.nan, "auc": 0.5, "calibration": 1.0,
                    "pctr": math.nan, "ctr": math.nan}
        wsum = sum(a["w"] for a in aggs)
        pos = np.sum([a["pos"] for a in aggs], axis=0)
        neg = np.sum([a["neg"] for a in aggs], axis=0)
        wp = sum(a["wp"] for a in aggs)
        wy = sum(a["wy"] for a in aggs)
        return {
            "logloss": sum(a["ll"] for a in aggs) / max(wsum, 1e-12),
            "auc": _hist_auc(pos, neg),
            "calibration": wp / max(wy, 1e-12),
            "pctr": wp / max(wsum, 1e-12),
            "ctr": wy / max(wsum, 1e-12),
        }

    def smoothed(self, metric: str, window: Optional[int] = None) -> float:
        """Windowed metric over the last ``window`` batches (defaults to
        the evaluator's own window) — the downgrade trigger's read."""
        if not self._agg:
            return math.nan
        return self._windowed(window or self.window)[metric]

    def latest(self, metric: str) -> float:
        return self.history[-1].values[metric] if self.history else math.nan
