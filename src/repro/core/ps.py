"""Parameter-server storage: row-addressable sparse tables (arena-backed)
and dense banks, composed into master (training) and slave (serving) shards.

Master shards hold *training* state: parameter rows plus optimizer slots
(FTRL ``z,n``, Adam ``m,v``, ...). Slave shards hold *serving* state only:
the transformed inference weights — the paper's heterogeneous-parameter
split (§1.2.1).

The row hot path is fully batched: ID→slot resolution goes through a
vectorized open-addressing hash map (``core.hashmap.IdHashMap``) and row
gather/update/scatter are single fancy-indexed (or Pallas-kernel) passes —
no per-row Python anywhere. ``backend`` selects the row engine:

  * ``"numpy"``  — NumPy fancy indexing; the reference path, and the fast
    path on CPU-only hosts.
  * ``"pallas"`` — batched gather through the ``embedding_lookup`` Pallas
    kernel (interpret mode off-TPU, Mosaic on TPU); FTRL row updates fuse
    through ``ftrl_row_update`` (see ``Optimizer.update_rows``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.hashmap import EMPTY as _NO_ID
from repro.core.hashmap import IdHashMap
from repro.optim import Optimizer
from repro.optim.optimizers import FTRL

PS_BACKENDS = ("numpy", "pallas")


class _DeviceMirror:
    """Lazily-synced device copy of a ``SparseTable``'s probe state (key
    limbs + slot map) and arenas — what lets the ``pallas`` backend run
    probe→gather→update→scatter entirely on device (``ops.fused_lookup``
    / ``ops.fused_ftrl_apply``) while the host NumPy arrays stay
    authoritative for snapshots, deltas, and the numpy paths.

    Staleness is cheap to detect, never scanned for: the hash map's
    structural ``version`` covers the probe state, and the table's
    mutation clock covers the arenas — rows with ``row_version`` past the
    last synced clock are re-uploaded incrementally through the scatter
    kernel (bulk re-upload when most of the table moved). The key limbs
    sync the same way: the map's dirty-slot journal
    (``IdHashMap.track_dirty_slots``) names the slots each version bump
    touched, so steady-state inserts upload a few slots, not the whole
    table. Fused updates write both sides with the same kernel outputs,
    then ``mark_synced`` — steady-state training batches upload nothing
    but ids and grads.

    Placement: maps small enough for the whole-table VMEM probe upload
    exact-capacity limb arrays; past ``VMEM_SLOT_BOUND`` (or when the
    table pins ``device_placement``) the limbs are wrap-padded
    (``hashmap_probe.wrap_pad_limbs``) and probed by the windowed-DMA
    HBM kernel. Upload traffic is counted (``sync_metrics`` surfaces
    it)."""

    def __init__(self, table: "SparseTable"):
        self._t = table
        self._map_version = -1
        self._synced_mut = -1
        self.keys_lo = self.keys_hi = self.slot_of = None
        self.arenas: dict = {}
        self._placement: Optional[str] = None   # resolved at key sync
        self._cap = 0                           # capacity at last key sync
        self._pad = 0                           # wrap-pad rows (hbm only)
        self.syncs = 0
        self.key_full_uploads = 0
        self.key_incremental_uploads = 0
        self.key_bytes_uploaded = 0
        self.arena_bytes_uploaded = 0
        table._map.track_dirty_slots()

    @property
    def shift(self) -> int:
        return int(self._t._map.shift)

    @property
    def placement(self) -> str:
        """Key-table placement at the last sync ("vmem" | "hbm") — the
        static arg fused kernel calls must pass so the probe matches the
        uploaded layout."""
        assert self._placement is not None, "sync() before placement"
        return self._placement

    def _resolve_placement(self, cap: int) -> str:
        forced = self._t.device_placement
        if forced != "auto":
            return forced
        from repro.kernels.hashmap_probe import VMEM_SLOT_BOUND
        return "hbm" if cap > VMEM_SLOT_BOUND else "vmem"

    def _sync_keys(self, m) -> None:
        import jax.numpy as jnp

        from repro.kernels import hashmap_probe as _hm
        from repro.kernels import ops
        cap = m.capacity
        placement = self._resolve_placement(cap)
        slots = None
        if (self.keys_lo is not None and cap == self._cap
                and placement == self._placement):
            slots = m.dirty_slots_since(self._map_version)
        if slots is None:
            # full upload: first sync, realloc/rehash, clear, placement
            # flip, or journal overflow
            klo, khi = ops.int64_limbs(m.key_table)
            self._pad = 0
            if placement == "hbm":
                klo, khi = _hm.wrap_pad_limbs(klo, khi, cap=cap)
                self._pad = klo.shape[0] - cap
            self.keys_lo = jnp.asarray(klo)
            self.keys_hi = jnp.asarray(khi)
            self.slot_of = jnp.asarray(m.val_table.astype(np.int32))
            self.key_full_uploads += 1
            self.key_bytes_uploaded += (klo.nbytes + khi.nbytes
                                        + m.capacity * 4)
        else:
            if len(slots):
                klo, khi = ops.int64_limbs(m.key_table[slots])
                sl = jnp.asarray(slots.astype(np.int32))
                self.keys_lo = self.keys_lo.at[sl].set(jnp.asarray(klo))
                self.keys_hi = self.keys_hi.at[sl].set(jnp.asarray(khi))
                self.slot_of = self.slot_of.at[sl].set(
                    jnp.asarray(m.val_table[slots].astype(np.int32)))
                self.key_bytes_uploaded += len(slots) * 12
                if self._pad:
                    # dirty slots inside the wrap-pad mirror region must
                    # land in both places
                    wrap = slots[slots < self._pad]
                    if len(wrap):
                        wlo, whi = ops.int64_limbs(m.key_table[wrap])
                        wl = jnp.asarray((wrap + cap).astype(np.int32))
                        self.keys_lo = self.keys_lo.at[wl].set(
                            jnp.asarray(wlo))
                        self.keys_hi = self.keys_hi.at[wl].set(
                            jnp.asarray(whi))
                        self.key_bytes_uploaded += len(wrap) * 8
            self.key_incremental_uploads += 1
        self._placement = placement
        self._cap = cap
        self._map_version = m.version
        m.trim_dirty_log(m.version)

    def sync(self) -> None:
        import jax.numpy as jnp

        from repro.kernels import ops
        t = self._t
        m = t._map
        self.syncs += 1
        if self._map_version != m.version:
            self._sync_keys(m)
        host = {"w": t._w, **t._slots}
        row_bytes = sum(v.itemsize * v.shape[1] for v in host.values())
        if not self.arenas or self.arenas["w"].shape != t._w.shape:
            self.arenas = {k: jnp.asarray(v) for k, v in host.items()}
            self.arena_bytes_uploaded += sum(v.nbytes for v in host.values())
        elif self._synced_mut != t._mut:
            top = t._top
            dirty = np.flatnonzero(t.row_version[:top] > self._synced_mut)
            if len(dirty) * 4 > top:
                self.arenas = {k: jnp.asarray(v) for k, v in host.items()}
                self.arena_bytes_uploaded += sum(v.nbytes
                                                 for v in host.values())
            elif len(dirty):
                sl = dirty.astype(np.int32)
                self.arenas = {
                    k: ops.embedding_scatter(a, sl, host[k][dirty])
                    for k, a in self.arenas.items()}
                self.arena_bytes_uploaded += len(dirty) * row_bytes
        self._synced_mut = t._mut

    def mark_synced(self) -> None:
        """Record that the device arenas already hold the table's state at
        the current clock (a fused kernel just wrote both sides)."""
        self._synced_mut = self._t._mut

    def metrics(self) -> dict:
        return {"syncs": self.syncs,
                "placement": self._placement or "unsynced",
                "key_full_uploads": self.key_full_uploads,
                "key_incremental_uploads": self.key_incremental_uploads,
                "key_bytes_uploaded": self.key_bytes_uploaded,
                "arena_bytes_uploaded": self.arena_bytes_uploaded}


class SparseTable:
    """Row-addressable table over a huge hashed ID space; only touched rows
    exist. Arena storage: a growable (capacity, dim) array + a vectorized
    id→slot hash map, so batched ``ensure``/``lookup``/``evict`` and
    gather/scatter run with no per-row loops."""

    def __init__(self, dim: int, slot_names: tuple[str, ...] = (),
                 init_capacity: int = 1024, dtype=np.float32,
                 backend: str = "numpy"):
        assert backend in PS_BACKENDS, f"backend must be one of {PS_BACKENDS}"
        self.dim = dim
        self.dtype = dtype
        self.backend = backend
        # device key-table placement for the pallas backend: "auto" routes
        # by capacity (VMEM below ~2M slots, HBM/windowed-DMA above);
        # "vmem"/"hbm" pin it (tests and benchmarks exercise the HBM path
        # at small capacities this way)
        self.device_placement = "auto"
        self.slot_names = tuple(slot_names)
        self._map = IdHashMap(init_capacity)
        cap = max(1, init_capacity)
        # reverse map slot→id; _NO_ID (a reserved key sentinel, so it can
        # never collide with a real id — ids like -1 are legal) marks
        # unused slots. all_ids() scans this instead of the (4× larger)
        # hash-map key array.
        self._id_of = np.full(cap, _NO_ID, dtype=np.int64)
        self._free = np.empty(0, dtype=np.int64)
        self._top = 0                     # next never-used arena slot
        self._w = np.zeros((cap, dim), dtype=dtype)
        self._slots = {n: np.zeros((cap, dim), dtype=np.float32)
                       for n in self.slot_names}
        self.last_touch = np.zeros((cap,), dtype=np.int64)
        self.touch_count = np.zeros((cap,), dtype=np.int64)
        # dirty-row bookkeeping for incremental checkpoints: a per-table
        # mutation clock stamped onto every written row, plus a log of
        # (clock, ids) evictions so a delta can replay deletes. Same
        # pattern as the streaming plane's touch/seq tracking, but keyed
        # to the table (not the queue) so checkpoints need no queue scan.
        self.row_version = np.zeros((cap,), dtype=np.int64)
        self._mut = 0
        self._evict_log: list[tuple[int, np.ndarray]] = []
        self._dev: Optional[_DeviceMirror] = None   # pallas: lazy mirror

    def _mirror(self) -> _DeviceMirror:
        if self._dev is None:
            self._dev = _DeviceMirror(self)
        return self._dev

    # -- capacity ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self._map)

    def _grow(self, need: int) -> None:
        cap = self._w.shape[0]
        new_cap = max(need, cap * 2)
        def grow(a, fill=0):
            out = np.full((new_cap,) + a.shape[1:], fill, dtype=a.dtype)
            out[:cap] = a
            return out
        self._w = grow(self._w)
        self._slots = {n: grow(a) for n, a in self._slots.items()}
        self._id_of = grow(self._id_of, fill=_NO_ID)
        self.last_touch = grow(self.last_touch)
        self.touch_count = grow(self.touch_count)
        self.row_version = grow(self.row_version)

    def _alloc_slots(self, k: int) -> np.ndarray:
        """Pop ``k`` arena slots: freed slots first (LIFO), then fresh."""
        out = np.empty(k, dtype=np.int64)
        take = min(k, len(self._free))
        if take:
            out[:take] = self._free[len(self._free) - take:][::-1]
            self._free = self._free[:len(self._free) - take]
        fresh = k - take
        if fresh:
            out[take:] = np.arange(self._top, self._top + fresh)
            self._top += fresh
            if self._top > self._w.shape[0]:
                self._grow(self._top)
        return out

    # -- id resolution (batched, no per-row Python) -----------------------
    def ensure(self, ids: np.ndarray) -> np.ndarray:
        """Arena slots for ids, creating zeroed rows as needed. Lookup-first
        so the hot path (all rows exist) is a single batched probe — no
        dedup sort, no insert machinery."""
        ids = np.asarray(ids, dtype=np.int64)
        sl, found = self._map.lookup_mask(ids)
        if not found.all():
            sl = self._fill_missing(ids, sl, found)
        return sl

    def _fill_missing(self, ids: np.ndarray, sl: np.ndarray,
                      found: np.ndarray) -> np.ndarray:
        """Create zeroed rows for the ids ``found`` marks absent, patching
        their entries in ``sl`` (callers pass the probe result they already
        hold, so the miss path costs one probe, not two)."""
        miss = ~found
        new_ids = np.unique(ids[miss])            # sorted unique
        new_sl = self._alloc_slots(len(new_ids))
        self._map.insert(new_ids, new_sl)
        self._id_of[new_sl] = new_ids
        self._w[new_sl] = 0.0
        for a in self._slots.values():
            a[new_sl] = 0.0
        self.last_touch[new_sl] = 0
        self.touch_count[new_sl] = 0
        self._mut += 1
        self.row_version[new_sl] = self._mut
        sl[miss] = new_sl[np.searchsorted(new_ids, ids[miss])]
        return sl

    def lookup(self, ids: np.ndarray) -> np.ndarray:
        """Slots for existing ids; -1 where missing."""
        return self._map.lookup(np.asarray(ids, dtype=np.int64))

    def evict(self, ids: np.ndarray) -> int:
        """Batched row removal; freed slots are reused by later ensures."""
        uniq = np.unique(np.asarray(ids, dtype=np.int64))
        sl = self._map.lookup(uniq)
        have = sl >= 0
        if have.any():
            s = sl[have]
            self._map.delete(uniq[have])
            self._id_of[s] = _NO_ID
            self._free = np.concatenate([self._free, s])
            self._mut += 1
            self._evict_log.append((self._mut, uniq[have].copy()))
        return int(have.sum())

    # -- slot-level row access (shared by gather/scatter/apply_batch) -----
    def _fetch(self, arena: np.ndarray, sl: np.ndarray) -> np.ndarray:
        if self.backend == "pallas" and len(sl):
            from repro.kernels import ops
            out = ops.embedding_lookup(arena, sl.astype(np.int32))
            return np.asarray(out, dtype=arena.dtype)
        # take(mode="clip") with in-bounds-by-construction slots: ~an order
        # faster than arena[sl] (skips the bounds-checked gather path)
        if arena.shape[1] == 1:      # dim-1 rows (LR): element gather beats
            return arena.reshape(-1).take(sl, mode="clip")[:, None]  # row memcpys
        return arena.take(sl, axis=0, mode="clip")

    def read_rows(self, sl: np.ndarray, *, want_w: bool = True,
                  slot_names: Optional[tuple] = None):
        """(w, slots) for resolved arena slots — backend-routed gather.
        ``want_w=False`` / ``slot_names`` skip columns the caller will not
        read (the pusher's transform declares its inputs: an FTRL codec
        derives w from (z, n) and never touches the stored w; a plain
        weight codec never touches the slots). Skipped w is a (n, 0)
        placeholder so row counts stay consistent."""
        names = self.slot_names if slot_names is None else slot_names
        w = self._fetch(self._w, sl) if want_w else \
            np.empty((len(sl), 0), dtype=self.dtype)
        slots = {n: self._fetch(self._slots[n], sl) for n in names}
        return w, slots

    def write_rows(self, sl: np.ndarray, w: np.ndarray,
                   slots: Optional[dict] = None, *, step: int = 0) -> None:
        self._w[sl] = w
        if slots:
            for n, v in slots.items():
                self._slots[n][sl] = v
        self.last_touch[sl] = step
        self.touch_count[sl] += 1
        self._mut += 1
        self.row_version[sl] = self._mut

    # -- access -------------------------------------------------------------
    def gather(self, ids: np.ndarray, *, create: bool = False,
               want_w: bool = True, slot_names: Optional[tuple] = None):
        """Returns (w (n,dim), slots dict name->(n,dim)). Missing rows are
        zeros unless ``create``. ``want_w``/``slot_names`` select columns
        (see ``read_rows``)."""
        ids = np.asarray(ids, dtype=np.int64)
        if create:
            sl, found = self._map.lookup_mask(ids)
            if not found.all():               # rare: rows to create
                sl = self._fill_missing(ids, sl, found)
            return self.read_rows(sl, want_w=want_w, slot_names=slot_names)
        if (self.backend == "pallas" and want_w and len(ids)
                and not (self.slot_names if slot_names is None
                         else slot_names)):
            # fused device path: probe + gather in one jit against the
            # table mirror — the serve-lookup shape (w only, no slots)
            return self._gather_device(ids), {}
        sl = self.lookup(ids)
        ok = sl >= 0
        if ok.all():
            # hot path (pusher flushes gather the master's own dirty ids,
            # which always exist): plain read, no missing-row masking —
            # the np.where passes below would add ~2x the gather's memory
            # traffic for nothing
            return self.read_rows(sl, want_w=want_w, slot_names=slot_names)
        names = self.slot_names if slot_names is None else slot_names
        safe = np.where(ok, sl, 0)
        if want_w:
            w = self._fetch(self._w, safe)
            w = np.where(ok[:, None], w, np.zeros((), dtype=self.dtype))
        else:
            w = np.empty((len(sl), 0), dtype=self.dtype)
        slots = {}
        for n in names:
            v = self._fetch(self._slots[n], safe)
            slots[n] = np.where(ok[:, None], v, np.float32(0.0))
        return w, slots

    def scatter(self, ids: np.ndarray, w: np.ndarray,
                slots: Optional[dict] = None, *, step: int = 0) -> None:
        self.write_rows(self.ensure(ids), w, slots, step=step)

    def insert_rows(self, ids: np.ndarray, w: np.ndarray,
                    slots: Optional[dict] = None, *, step: int = 0) -> None:
        """Probe-free bulk install of rows whose ids are unique and KNOWN
        absent — e.g. the miss set a ``lookup`` just reported (the serve
        cache's fill path). Equivalent end state to ``scatter`` on absent
        ids, but skips its existence probe, the miss-path ``np.unique``
        re-sort, and the zero-init write the values immediately overwrite
        — the dominant costs of a cold cache fill."""
        ids = np.asarray(ids, dtype=np.int64)
        if not len(ids):
            return
        fresh = not len(self._free)
        sl = self._alloc_slots(len(ids))
        self._map.insert(ids, sl)
        # with an empty free list (the post-reset refill) the allocated
        # slots are one contiguous run — slice writes are straight memcpys
        # where fancy-index scatters pay per-element address math
        dst = slice(int(sl[0]), int(sl[0]) + len(ids)) if fresh else sl
        self._id_of[dst] = ids
        self._w[dst] = w
        if slots:
            for n, v in slots.items():
                self._slots[n][dst] = v
        else:
            for a in self._slots.values():
                a[dst] = 0.0
        self.last_touch[dst] = step
        self.touch_count[dst] = 1
        self._mut += 1
        self.row_version[dst] = self._mut

    def reset(self) -> None:
        """Empty the table but KEEP its allocations (map capacity, arena).
        A reset-and-refill consumer (serve-cache flush) then re-inserts
        into a presized map — no growth rehashes, and cold probes resolve
        on the EMPTY-home fast path. Arena contents are left stale: rows
        are unreachable once the map is cleared, and every (re)insert path
        writes before exposing a slot."""
        self._map.clear()
        self._id_of[:self._top] = _NO_ID
        self._free = np.empty(0, dtype=np.int64)
        self._top = 0
        self._mut += 1
        self._evict_log.clear()

    def lookup_device(self, ids: np.ndarray):
        """Serve-path rows via the device-resident mirror: one jitted
        probe→gather chain (``ops.fused_lookup``), missing rows zeros.
        Bit-equal to the host probe + gather (``tests/test_ps_backend``).

        Returns ``(rows, found, slot)`` where ``rows`` is the DEVICE
        array (callers that feed a jitted predict keep it on device — no
        host round-trip) and ``found``/``slot`` are small host arrays:
        the found mask comes off the device probe (the serve cache counts
        misses from it instead of re-probing on host) and ``slot`` lets
        LRU stats update without a host lookup."""
        from repro.kernels import ops
        mir = self._mirror()
        mir.sync()
        ilo, ihi = ops.int64_limbs(np.asarray(ids, np.int64))
        rows, found, slot = ops.fused_lookup(
            mir.keys_lo, mir.keys_hi, mir.slot_of, mir.arenas["w"],
            ilo, ihi, shift=mir.shift, placement=mir.placement)
        return rows, np.asarray(found), np.asarray(slot)

    def _gather_device(self, ids: np.ndarray) -> np.ndarray:
        rows, _found, _slot = self.lookup_device(ids)
        return np.asarray(rows, dtype=self.dtype)

    def mirror_metrics(self) -> Optional[dict]:
        """Device-mirror upload counters (None until a pallas path has
        touched this table) — aggregated into ``cluster.sync_metrics``."""
        return self._dev.metrics() if self._dev is not None else None

    def fused_ftrl_update(self, ids: np.ndarray, sl: np.ndarray,
                          grads: np.ndarray, *, alpha: float, beta: float,
                          l1: float, l2: float, step: int = 0) -> np.ndarray:
        """The fused sparse training hot path (pallas backend): one jitted
        probe→gather→FTRL→scatter chain over the device mirror — no host
        hop between stages. ``ids`` must be unique and already resolved to
        arena slots ``sl`` (``ensure`` ran: row creation stays host-side).
        The kernel's row outputs are written back to the host arrays at
        ``sl`` — both sides hold identical bits, so the mirror marks
        itself synced and the next batch uploads nothing but ids+grads.
        Returns the new serve weights ``w'`` for the rows."""
        from repro.kernels import ops
        mir = self._mirror()
        mir.sync()
        ilo, ihi = ops.int64_limbs(ids)
        z_a, n_a, w_a, z2, n2, w2, found = ops.fused_ftrl_apply(
            mir.keys_lo, mir.keys_hi, mir.slot_of,
            mir.arenas["z"], mir.arenas["n"], mir.arenas["w"],
            ilo, ihi, np.asarray(grads, np.float32),
            shift=mir.shift, alpha=alpha, beta=beta, l1=l1, l2=l2,
            placement=mir.placement)
        mir.arenas["z"], mir.arenas["n"], mir.arenas["w"] = z_a, n_a, w_a
        assert bool(np.asarray(found).all()), \
            "fused_ftrl_update on ids absent from the map (run ensure first)"
        w_np = np.asarray(w2).astype(self.dtype, copy=False)
        self.write_rows(sl, w_np, {"z": np.asarray(z2),
                                   "n": np.asarray(n2)}, step=step)
        mir.mark_synced()
        return w_np

    def all_ids(self) -> np.ndarray:
        live = self._id_of[:self._top]
        return live[live != _NO_ID]

    def nbytes(self) -> int:
        live = len(self)
        per_row = self._w.itemsize * self.dim * (1 + len(self._slots))
        return live * per_row

    # -- snapshot (checkpointing) -------------------------------------------
    @property
    def version(self) -> int:
        """Mutation-clock reading; rows with ``row_version > v`` are dirty
        relative to a snapshot taken at clock ``v``."""
        return self._mut

    def snapshot(self) -> dict:
        ids = self.all_ids()
        sl = self.lookup(ids)                     # one probe for everything
        w, slots = self.read_rows(sl)
        return {"ids": ids, "w": w, "slots": slots,
                "last_touch": self.last_touch[sl].copy(),
                "touch_count": self.touch_count[sl].copy(),
                "version": self._mut}

    def delta_snapshot(self, since: int) -> dict:
        """Columnar snapshot of ONLY the rows written after clock ``since``
        plus the ids evicted after it — the payload of an incremental
        checkpoint. One vectorized scan of the reverse map + row_version;
        no hash probes."""
        live = self._id_of[:self._top] != _NO_ID
        sl = np.flatnonzero(live & (self.row_version[:self._top] > since))
        w, slots = self.read_rows(sl)
        dead = [ids for mut, ids in self._evict_log if mut > since]
        deleted = np.unique(np.concatenate(dead)) if dead else \
            np.empty(0, np.int64)
        return {"ids": self._id_of[sl].copy(), "w": w, "slots": slots,
                "last_touch": self.last_touch[sl].copy(),
                "touch_count": self.touch_count[sl].copy(),
                "deleted": deleted, "since": since, "version": self._mut}

    def trim_evict_log(self, before: int) -> None:
        """Drop eviction entries at or below clock ``before`` — safe once
        every future delta will be taken against a mark >= ``before``."""
        self._evict_log = [(m, i) for m, i in self._evict_log if m > before]

    def load_rows(self, rows: dict) -> None:
        """Bulk-insert snapshot rows whose ids are unique and NOT yet
        present — the restore hot path (tables start cleared). Skips the
        ensure probe, the miss-path np.unique sort, and the zero-init
        write that ``ensure`` + ``write_rows`` would pay."""
        ids = np.asarray(rows["ids"], dtype=np.int64)
        if not len(ids):
            return
        sl = self._alloc_slots(len(ids))
        self._map.insert(ids, sl)
        self._id_of[sl] = ids
        self._w[sl] = rows["w"]
        for n, v in rows["slots"].items():
            self._slots[n][sl] = v
        self.last_touch[sl] = rows["last_touch"]
        self.touch_count[sl] = rows["touch_count"]
        self._mut += 1
        self.row_version[sl] = self._mut

    @classmethod
    def restore(cls, snap: dict, dim: int, slot_names: tuple[str, ...],
                dtype=np.float32, backend: str = "numpy") -> "SparseTable":
        t = cls(dim, slot_names, init_capacity=max(16, len(snap["ids"])),
                dtype=dtype, backend=backend)
        t.load_rows(snap)                 # probe-free insert: table is new
        return t


@dataclass
class DenseBank:
    """Named dense tensors (DNN hidden layers etc.) with version counters."""

    tensors: dict[str, np.ndarray] = field(default_factory=dict)
    slots: dict[str, dict[str, np.ndarray]] = field(default_factory=dict)
    versions: dict[str, int] = field(default_factory=dict)

    def put(self, name: str, value: np.ndarray,
            slots: Optional[dict] = None) -> None:
        self.tensors[name] = value
        if slots is not None:
            self.slots[name] = slots
        self.versions[name] = self.versions.get(name, 0) + 1

    def snapshot(self) -> dict:
        return {
            "tensors": {k: v.copy() for k, v in self.tensors.items()},
            "slots": {k: {n: a.copy() for n, a in s.items()}
                      for k, s in self.slots.items()},
            "versions": dict(self.versions),
        }

    def snapshot_delta(self, since: dict[str, int]) -> dict:
        """Same format as ``snapshot`` but holding only tensors whose
        version counter moved past ``since[name]``."""
        names = [k for k, v in self.versions.items()
                 if v > since.get(k, -1)]
        return {
            "tensors": {k: self.tensors[k].copy() for k in names},
            "slots": {k: {n: a.copy() for n, a in self.slots[k].items()}
                      for k in names if k in self.slots},
            "versions": {k: self.versions[k] for k in names},
        }

    @classmethod
    def restore(cls, snap: dict) -> "DenseBank":
        return cls(tensors=dict(snap["tensors"]),
                   slots={k: dict(v) for k, v in snap["slots"].items()},
                   versions=dict(snap["versions"]))


class MasterShard:
    """Training-side PS shard: sparse groups with optimizer slots + a dense
    bank. Gradient pushes update rows through the optimizer and notify the
    collector (dirty IDs only — paper §4.1.1)."""

    def __init__(self, shard_id: int, groups: dict[str, int],
                 optimizer: Optimizer, collector=None,
                 backend: str = "numpy"):
        """groups: {group_name: row_dim}"""
        self.shard_id = shard_id
        self.optimizer = optimizer
        self.backend = backend
        self.tables = {
            g: SparseTable(dim, tuple(sorted(
                optimizer.init_slots(np.zeros((dim,), np.float32)).keys())),
                backend=backend)
            for g, dim in groups.items()
        }
        self.dense = DenseBank()
        self.collector = collector
        self.step = 0
        self.fused_batches = 0      # pushes taken by the fused device path
        self.alive = True

    def add_group(self, group: str, dim: int) -> None:
        """Create a new sparse group online (multi-scenario training: an
        isolated scenario's namespaced tables appear after construction).
        Idempotent for an existing group of the same dim."""
        if group in self.tables:
            assert self.tables[group].dim == dim, \
                f"group {group!r} exists with dim {self.tables[group].dim}"
            return
        self.tables[group] = SparseTable(dim, tuple(sorted(
            self.optimizer.init_slots(
                np.zeros((dim,), np.float32)).keys())), backend=self.backend)

    def pull(self, group: str, ids: np.ndarray, *, create: bool = True):
        """Trainer pull: returns current *training* weights for ids."""
        assert self.alive, f"master shard {self.shard_id} is down"
        w, _ = self.tables[group].gather(ids, create=create)
        return w

    def apply_batch(self, group: str, ids: np.ndarray, grads: np.ndarray,
                    *, step: Optional[int] = None) -> np.ndarray:
        """The fused PS hot path: one batched hash → gather → optimizer
        update → scatter pass for a whole minibatch. Duplicate ids are
        deduplicated with their gradients summed (the correct sparse-grad
        semantics). Returns the unique ids touched."""
        assert self.alive, f"master shard {self.shard_id} is down"
        t = self.tables[group]
        st = self.step if step is None else step
        ids = np.asarray(ids, dtype=np.int64)
        grads = np.asarray(grads, dtype=np.float32)
        uniq, inv, counts = np.unique(ids, return_inverse=True,
                                      return_counts=True)
        if len(uniq) != len(ids):
            # segment-sum duplicate-id grads (sort + reduceat: orders of
            # magnitude faster than np.add.at's buffered scatter-add)
            order = np.argsort(inv, kind="stable")
            starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
            grads = np.add.reduceat(
                grads.take(order, axis=0, mode="clip"), starts, axis=0)
        elif len(ids) > 1 and not (ids[1:] >= ids[:-1]).all():
            # unique but unsorted: slots are resolved for sorted ``uniq``,
            # so grad rows must be permuted to match
            grads = grads.take(np.argsort(inv, kind="stable"), axis=0,
                               mode="clip")
        sl = t.ensure(uniq)
        if (self.backend == "pallas" and isinstance(self.optimizer, FTRL)
                and t.slot_names == ("n", "z")):
            # fused device route: ensure resolved/created the rows on the
            # host (authoritative side), then probe→gather→FTRL→scatter
            # runs as one jitted chain over the table's device mirror
            o = self.optimizer
            t.fused_ftrl_update(uniq, sl, grads, alpha=o.alpha, beta=o.beta,
                                l1=o.l1, l2=o.l2, step=st)
            self.fused_batches += 1
        else:
            w, slots = t.read_rows(sl)
            new_w, new_slots = self.optimizer.update_rows(
                w, slots, grads, st, backend=self.backend)
            t.write_rows(sl, new_w.astype(t.dtype, copy=False), new_slots,
                         step=st)
        self.step = st + 1
        if self.collector is not None:
            self.collector.record(group, uniq, "upsert")
        return uniq

    def push_grad(self, group: str, ids: np.ndarray, grads: np.ndarray,
                  *, step: Optional[int] = None) -> None:
        """Apply gradient rows through the optimizer; record dirty IDs."""
        self.apply_batch(group, ids, grads, step=step)

    def push_dense(self, name: str, value: np.ndarray,
                   slots: Optional[dict] = None) -> None:
        assert self.alive
        self.dense.put(name, value, slots)
        if self.collector is not None:
            self.collector.record_dense(name)

    def delete_rows(self, group: str, ids: np.ndarray) -> None:
        """Feature-filter expiry: remove rows and emit delete records."""
        self.tables[group].evict(ids)
        if self.collector is not None:
            self.collector.record(group, ids, "delete")

    def register_metrics(self, reg, prefix: str = "") -> None:
        """Publish this shard's counters into a
        ``repro.obs.metrics.MetricsRegistry`` (dotted under ``prefix``
        when given; per-table ``_DeviceMirror`` sync counters under
        ``<prefix>device_mirror.<group>``)."""
        from repro.obs.metrics import join
        reg.register(join(prefix, "step"), lambda: self.step)
        reg.register(join(prefix, "fused_batches"),
                     lambda: self.fused_batches)
        reg.register(join(prefix, "rows"),
                     lambda: {g: len(t) for g, t in self.tables.items()})
        reg.register(join(prefix, "device_mirror"),
                     lambda: {g: m for g, t in self.tables.items()
                              if (m := t.mirror_metrics()) is not None})

    # -- fault tolerance ---------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "shard_id": self.shard_id,
            "step": self.step,
            "kind": "full",
            "tables": {g: t.snapshot() for g, t in self.tables.items()},
            "dense": self.dense.snapshot(),
        }

    def delta_snapshot(self, marks: dict[str, int],
                       dense_marks: dict[str, int]) -> dict:
        """Incremental snapshot: per group, only the rows written after
        ``marks[group]`` (the table's mutation clock at the previous
        checkpoint) plus the ids evicted since; dense tensors only where
        the version counter moved."""
        return {
            "shard_id": self.shard_id,
            "step": self.step,
            "kind": "delta",
            "tables": {g: t.delta_snapshot(marks.get(g, 0))
                       for g, t in self.tables.items()},
            "dense": self.dense.snapshot_delta(dense_marks),
        }

    def load_table_rows(self, group: str, rows: dict) -> None:
        """Bulk-load columnar rows (ids/w/slots + touch stats) into one
        group — the unit the vectorized recovery router emits. An empty
        table takes the probe-free ``SparseTable.load_rows`` insert; a
        live table (merging load) falls back to ensure + write."""
        if not len(rows["ids"]):
            return
        t = self.tables[group]
        if len(t) == 0:
            t.load_rows(rows)
            return
        sl = t.ensure(rows["ids"])
        t.write_rows(sl, rows["w"], rows["slots"])
        t.last_touch[sl] = rows["last_touch"]
        t.touch_count[sl] = rows["touch_count"]

    def load_snapshot(self, snap: dict, *, ids_filter=None) -> None:
        self.step = snap["step"]
        for g, tsnap in snap["tables"].items():
            rows = {k: tsnap[k] for k in
                    ("ids", "w", "slots", "last_touch", "touch_count")}
            if ids_filter is not None:
                keep = ids_filter(rows["ids"])
                rows = {"slots": {k: v[keep]
                                  for k, v in rows["slots"].items()},
                        **{k: rows[k][keep] for k in
                           ("ids", "w", "last_touch", "touch_count")}}
            self.load_table_rows(g, rows)
        # a filtered load is a partial/routed restore — table rows only;
        # dense tensors follow the unfiltered owner-shard load
        if ids_filter is None and snap.get("dense") is not None:
            self.dense = DenseBank.restore(snap["dense"])

    def kill(self) -> None:
        self.alive = False

    def clear(self) -> None:
        for g, t in list(self.tables.items()):
            self.tables[g] = SparseTable(t.dim, t.slot_names, dtype=t.dtype,
                                         backend=t.backend)
        self.dense = DenseBank()


class SlaveShard:
    """Serving-side PS shard: inference weights only, idempotent versioned
    application of stream records (last-writer-wins by ``seq``)."""

    def __init__(self, shard_id: int, groups: dict[str, int],
                 backend: str = "numpy", codec_backend: str = "numpy"):
        self.shard_id = shard_id
        self.backend = backend
        self.codec_backend = codec_backend   # decode engine (transform.py)
        self.tables = {g: SparseTable(dim, backend=backend)
                       for g, dim in groups.items()}
        self.dense: dict[str, np.ndarray] = {}
        self.dense_versions: dict[str, int] = {}
        # (group, producer, partition) -> last applied seq, for LWW
        # idempotence. Keyed per partition stream: ids route to
        # partitions deterministically, so partitions are independent
        # ordered streams — a flush that touches only partition p must
        # not mark another partition's in-flight records stale.
        self._applied_seq: dict[tuple[str, int, int], int] = {}
        # serving-plane invalidation hook: called with (group, ids, op)
        # for every applied sparse batch, so predictor-side caches can
        # drop rows the stream just rewrote (deletes included). Dense
        # records need no hook — they carry a version counter the dense
        # cache compares directly.
        self.on_apply = None
        self.alive = True
        self.applied_records = 0
        self.skipped_records = 0

    def add_group(self, group: str, dim: int) -> None:
        """Create a new serve group online (mirrors
        ``MasterShard.add_group`` so scenario tables stream through the
        scatter like any other group)."""
        if group in self.tables:
            assert self.tables[group].dim == dim, \
                f"group {group!r} exists with dim {self.tables[group].dim}"
            return
        self.tables[group] = SparseTable(dim, backend=self.backend)

    @staticmethod
    def _seq_key(record) -> tuple[str, int, int]:
        return (record.group, record.producer,
                record.meta.get("partition", -1))

    def apply(self, record) -> bool:
        """Apply one stream record; returns False if skipped (stale)."""
        assert self.alive, f"slave shard {self.shard_id} is down"
        key = self._seq_key(record)
        last = self._applied_seq.get(key, -1)
        # strictly-older records are stale (LWW). Equal-seq records are
        # sibling chunks of the SAME flush covering disjoint IDs (or exact
        # redeliveries, which are idempotent full-value upserts) — apply.
        if record.seq < last:
            self.skipped_records += 1
            return False
        from repro.core.transform import decode_record
        if record.group.startswith("dense/"):
            name = record.group[len("dense/"):]
            ver = int(record.ids[0])
            if self.dense_versions.get(name, -1) < ver:
                self.dense[name] = decode_record(record,
                                                 backend=self.codec_backend)
                self.dense_versions[name] = ver
        elif record.op == "delete":
            self.tables[record.group].evict(record.ids)
            if self.on_apply is not None:
                self.on_apply(record.group, record.ids, "delete")
        else:
            values = decode_record(record, backend=self.codec_backend)
            self.tables[record.group].scatter(record.ids, values)
            if self.on_apply is not None:
                self.on_apply(record.group, record.ids, "upsert")
        self._applied_seq[key] = max(last, record.seq)
        self.applied_records += 1
        return True

    def apply_batch(self, records: list) -> list:
        """Batched idempotent application of a poll's worth of records:
        sparse upserts are coalesced per group into ONE decoded value block
        and ONE ``SparseTable.scatter`` (concatenation preserves arrival
        order, so overlapping ids within the batch resolve last-writer-wins
        exactly like sequential ``apply`` — numpy fancy assignment writes
        the later occurrence). Dense records and deletes are versioned /
        destructive and keep the singleton ``apply`` path. Returns the
        records actually applied (stale ones are skipped and counted)."""
        assert self.alive, f"slave shard {self.shard_id} is down"
        from repro.core.transform import decode_record
        applied: list = []
        rows: dict[str, tuple[list, list]] = {}

        def flush(group) -> None:
            ids_l, val_l = rows.pop(group)
            ids = ids_l[0] if len(ids_l) == 1 else np.concatenate(ids_l)
            vals = val_l[0] if len(val_l) == 1 else \
                np.concatenate(val_l, axis=0)
            self.tables[group].scatter(ids, vals)
            if self.on_apply is not None:
                self.on_apply(group, ids, "upsert")

        for rec in records:
            if rec.group.startswith("dense/") or rec.op == "delete":
                # a delete must not overtake coalesced-but-unwritten
                # upserts for its group (the deferred scatter would
                # resurrect the evicted rows) — flush those first
                if rec.op == "delete" and rec.group in rows:
                    flush(rec.group)
                if self.apply(rec):
                    applied.append(rec)
                continue
            key = self._seq_key(rec)
            last = self._applied_seq.get(key, -1)
            if rec.seq < last:
                self.skipped_records += 1
                continue
            ids_l, val_l = rows.setdefault(rec.group, ([], []))
            ids_l.append(rec.ids)
            val_l.append(decode_record(rec, backend=self.codec_backend))
            self._applied_seq[key] = max(last, rec.seq)
            self.applied_records += 1
            applied.append(rec)
        for group in list(rows):
            flush(group)
        return applied

    def lookup(self, group: str, ids: np.ndarray) -> np.ndarray:
        """Latency-path query: serve weights (missing rows -> zeros)."""
        assert self.alive, f"slave shard {self.shard_id} is down"
        w, _ = self.tables[group].gather(ids, create=False)
        return w

    def register_metrics(self, reg, prefix: str = "") -> None:
        """Publish this shard's apply counters into a
        ``repro.obs.metrics.MetricsRegistry``."""
        from repro.obs.metrics import join
        reg.register(join(prefix, "applied"), lambda: self.applied_records)
        reg.register(join(prefix, "skipped"), lambda: self.skipped_records)
        reg.register(join(prefix, "rows"),
                     lambda: {g: len(t) for g, t in self.tables.items()})

    # -- hot backup ----------------------------------------------------------
    def full_sync_from(self, other: "SlaveShard") -> None:
        """Bootstrap a fresh replica: full copy then streaming catch-up."""
        for g, t in other.tables.items():
            snap = t.snapshot()
            self.tables[g] = SparseTable.restore(
                snap, t.dim, (), dtype=t.dtype, backend=self.backend)
        self.dense = {k: v.copy() for k, v in other.dense.items()}
        self.dense_versions = dict(other.dense_versions)
        self._applied_seq = dict(other._applied_seq)

    def kill(self) -> None:
        self.alive = False

    def revive(self) -> None:
        self.alive = True
