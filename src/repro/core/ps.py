"""Parameter-server storage: row-addressable sparse tables (arena-backed)
and dense banks, composed into master (training) and slave (serving) shards.

Master shards hold *training* state: parameter rows plus optimizer slots
(FTRL ``z,n``, Adam ``m,v``, ...). Slave shards hold *serving* state only:
the transformed inference weights — the paper's heterogeneous-parameter
split (§1.2.1).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.optim import Optimizer


class SparseTable:
    """Row-addressable table over a huge hashed ID space; only touched rows
    exist. Arena storage: a growable (capacity, dim) array + id→slot map,
    so batched gather/scatter are vectorized."""

    def __init__(self, dim: int, slot_names: tuple[str, ...] = (),
                 init_capacity: int = 1024, dtype=np.float32):
        self.dim = dim
        self.dtype = dtype
        self.slot_names = tuple(slot_names)
        self._slot_of: dict[int, int] = {}
        self._id_of: list[int] = []
        self._free: list[int] = []
        cap = init_capacity
        self._w = np.zeros((cap, dim), dtype=dtype)
        self._slots = {n: np.zeros((cap, dim), dtype=np.float32)
                       for n in self.slot_names}
        self.last_touch = np.zeros((cap,), dtype=np.int64)
        self.touch_count = np.zeros((cap,), dtype=np.int64)

    # -- capacity ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self._slot_of)

    def _grow(self, need: int) -> None:
        cap = self._w.shape[0]
        new_cap = max(need, cap * 2)
        def grow(a):
            out = np.zeros((new_cap,) + a.shape[1:], dtype=a.dtype)
            out[:cap] = a
            return out
        self._w = grow(self._w)
        self._slots = {n: grow(a) for n, a in self._slots.items()}
        self.last_touch = grow(self.last_touch)
        self.touch_count = grow(self.touch_count)

    def _ensure(self, ids: np.ndarray) -> np.ndarray:
        """Returns arena slots for ids, creating rows as needed."""
        slots = np.empty(len(ids), dtype=np.int64)
        for i, rid in enumerate(ids.tolist()):
            s = self._slot_of.get(rid)
            if s is None:
                if self._free:
                    s = self._free.pop()
                else:
                    s = len(self._id_of)
                    self._id_of.append(-1)
                    if s >= self._w.shape[0]:
                        self._grow(s + 1)
                    # (slot was appended; arena may already be large enough)
                self._slot_of[rid] = s
                if s >= len(self._id_of):
                    self._id_of.extend([-1] * (s + 1 - len(self._id_of)))
                self._id_of[s] = rid
                self._w[s] = 0.0
                for a in self._slots.values():
                    a[s] = 0.0
                self.last_touch[s] = 0
                self.touch_count[s] = 0
            slots[i] = s
        return slots

    def _lookup(self, ids: np.ndarray) -> np.ndarray:
        """Slots for existing ids; -1 where missing."""
        return np.array([self._slot_of.get(r, -1) for r in ids.tolist()],
                        dtype=np.int64)

    # -- access -------------------------------------------------------------
    def gather(self, ids: np.ndarray, *, create: bool = False):
        """Returns (w (n,dim), slots dict name->(n,dim)). Missing rows are
        zeros unless ``create``."""
        ids = np.asarray(ids, dtype=np.int64)
        if create:
            sl = self._ensure(ids)
            w = self._w[sl].copy()
            slots = {n: a[sl].copy() for n, a in self._slots.items()}
        else:
            sl = self._lookup(ids)
            ok = sl >= 0
            w = np.zeros((len(ids), self.dim), dtype=self.dtype)
            w[ok] = self._w[sl[ok]]
            slots = {}
            for n, a in self._slots.items():
                v = np.zeros((len(ids), self.dim), dtype=np.float32)
                v[ok] = a[sl[ok]]
                slots[n] = v
        return w, slots

    def scatter(self, ids: np.ndarray, w: np.ndarray,
                slots: Optional[dict] = None, *, step: int = 0) -> None:
        ids = np.asarray(ids, dtype=np.int64)
        sl = self._ensure(ids)
        self._w[sl] = w
        if slots:
            for n, v in slots.items():
                self._slots[n][sl] = v
        self.last_touch[sl] = step
        self.touch_count[sl] += 1

    def delete(self, ids: np.ndarray) -> int:
        ids = np.asarray(ids, dtype=np.int64)
        n = 0
        for rid in ids.tolist():
            s = self._slot_of.pop(rid, None)
            if s is not None:
                self._id_of[s] = -1
                self._free.append(s)
                n += 1
        return n

    def all_ids(self) -> np.ndarray:
        return np.fromiter(self._slot_of.keys(), dtype=np.int64,
                           count=len(self._slot_of))

    def nbytes(self) -> int:
        live = len(self)
        per_row = self._w.itemsize * self.dim * (1 + len(self._slots))
        return live * per_row

    # -- snapshot (checkpointing) -------------------------------------------
    def snapshot(self) -> dict:
        ids = self.all_ids()
        w, slots = self.gather(ids)
        sl = self._lookup(ids)
        return {"ids": ids, "w": w, "slots": slots,
                "last_touch": self.last_touch[sl].copy(),
                "touch_count": self.touch_count[sl].copy()}

    @classmethod
    def restore(cls, snap: dict, dim: int, slot_names: tuple[str, ...],
                dtype=np.float32) -> "SparseTable":
        t = cls(dim, slot_names, init_capacity=max(16, len(snap["ids"])),
                dtype=dtype)
        t.scatter(snap["ids"], snap["w"], snap["slots"])
        sl = t._lookup(snap["ids"])
        t.last_touch[sl] = snap["last_touch"]
        t.touch_count[sl] = snap["touch_count"]
        return t


@dataclass
class DenseBank:
    """Named dense tensors (DNN hidden layers etc.) with version counters."""

    tensors: dict[str, np.ndarray] = field(default_factory=dict)
    slots: dict[str, dict[str, np.ndarray]] = field(default_factory=dict)
    versions: dict[str, int] = field(default_factory=dict)

    def put(self, name: str, value: np.ndarray,
            slots: Optional[dict] = None) -> None:
        self.tensors[name] = value
        if slots is not None:
            self.slots[name] = slots
        self.versions[name] = self.versions.get(name, 0) + 1

    def snapshot(self) -> dict:
        return {
            "tensors": {k: v.copy() for k, v in self.tensors.items()},
            "slots": {k: {n: a.copy() for n, a in s.items()}
                      for k, s in self.slots.items()},
            "versions": dict(self.versions),
        }

    @classmethod
    def restore(cls, snap: dict) -> "DenseBank":
        return cls(tensors=dict(snap["tensors"]),
                   slots={k: dict(v) for k, v in snap["slots"].items()},
                   versions=dict(snap["versions"]))


class MasterShard:
    """Training-side PS shard: sparse groups with optimizer slots + a dense
    bank. Gradient pushes update rows through the optimizer and notify the
    collector (dirty IDs only — paper §4.1.1)."""

    def __init__(self, shard_id: int, groups: dict[str, int],
                 optimizer: Optimizer, collector=None):
        """groups: {group_name: row_dim}"""
        self.shard_id = shard_id
        self.optimizer = optimizer
        self.tables = {
            g: SparseTable(dim, tuple(sorted(
                optimizer.init_slots(np.zeros((dim,), np.float32)).keys())))
            for g, dim in groups.items()
        }
        self.dense = DenseBank()
        self.collector = collector
        self.step = 0
        self.alive = True

    def pull(self, group: str, ids: np.ndarray, *, create: bool = True):
        """Trainer pull: returns current *training* weights for ids."""
        assert self.alive, f"master shard {self.shard_id} is down"
        w, _ = self.tables[group].gather(ids, create=create)
        return w

    def push_grad(self, group: str, ids: np.ndarray, grads: np.ndarray,
                  *, step: Optional[int] = None) -> None:
        """Apply gradient rows through the optimizer; record dirty IDs."""
        assert self.alive, f"master shard {self.shard_id} is down"
        t = self.tables[group]
        st = self.step if step is None else step
        w, slots = t.gather(ids, create=True)
        import jax.numpy as jnp
        new_w, new_slots = self.optimizer.update(
            jnp.asarray(w), {k: jnp.asarray(v) for k, v in slots.items()},
            jnp.asarray(grads), st)
        t.scatter(ids, np.asarray(new_w),
                  {k: np.asarray(v) for k, v in new_slots.items()}, step=st)
        self.step = st + 1
        if self.collector is not None:
            self.collector.record(group, ids, "upsert")

    def push_dense(self, name: str, value: np.ndarray,
                   slots: Optional[dict] = None) -> None:
        assert self.alive
        self.dense.put(name, value, slots)
        if self.collector is not None:
            self.collector.record_dense(name)

    def delete_rows(self, group: str, ids: np.ndarray) -> None:
        """Feature-filter expiry: remove rows and emit delete records."""
        self.tables[group].delete(ids)
        if self.collector is not None:
            self.collector.record(group, ids, "delete")

    # -- fault tolerance ---------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "shard_id": self.shard_id,
            "step": self.step,
            "tables": {g: t.snapshot() for g, t in self.tables.items()},
            "dense": self.dense.snapshot(),
        }

    def load_snapshot(self, snap: dict, *, ids_filter=None) -> None:
        self.step = snap["step"]
        for g, tsnap in snap["tables"].items():
            t = self.tables[g]
            ids, w, slots = tsnap["ids"], tsnap["w"], tsnap["slots"]
            if ids_filter is not None:
                keep = ids_filter(ids)
                ids, w = ids[keep], w[keep]
                slots = {k: v[keep] for k, v in slots.items()}
            t.scatter(ids, w, slots)

    def kill(self) -> None:
        self.alive = False

    def clear(self) -> None:
        for g, t in list(self.tables.items()):
            self.tables[g] = SparseTable(t.dim, t.slot_names, dtype=t.dtype)
        self.dense = DenseBank()


class SlaveShard:
    """Serving-side PS shard: inference weights only, idempotent versioned
    application of stream records (last-writer-wins by ``seq``)."""

    def __init__(self, shard_id: int, groups: dict[str, int]):
        self.shard_id = shard_id
        self.tables = {g: SparseTable(dim) for g, dim in groups.items()}
        self.dense: dict[str, np.ndarray] = {}
        self.dense_versions: dict[str, int] = {}
        # (group, producer) -> last applied seq, for LWW idempotence
        self._applied_seq: dict[tuple[str, int], int] = {}
        self.alive = True
        self.applied_records = 0
        self.skipped_records = 0

    def apply(self, record) -> bool:
        """Apply one stream record; returns False if skipped (stale)."""
        assert self.alive, f"slave shard {self.shard_id} is down"
        key = (record.group, record.producer)
        last = self._applied_seq.get(key, -1)
        # strictly-older records are stale (LWW). Equal-seq records are
        # sibling chunks of the SAME flush covering disjoint IDs (or exact
        # redeliveries, which are idempotent full-value upserts) — apply.
        if record.seq < last:
            self.skipped_records += 1
            return False
        from repro.core.transform import decode_record
        if record.group.startswith("dense/"):
            name = record.group[len("dense/"):]
            ver = int(record.ids[0])
            if self.dense_versions.get(name, -1) < ver:
                self.dense[name] = decode_record(record)
                self.dense_versions[name] = ver
        elif record.op == "delete":
            self.tables[record.group].delete(record.ids)
        else:
            values = decode_record(record)
            self.tables[record.group].scatter(record.ids, values)
        self._applied_seq[key] = max(last, record.seq)
        self.applied_records += 1
        return True

    def lookup(self, group: str, ids: np.ndarray) -> np.ndarray:
        """Latency-path query: serve weights (missing rows -> zeros)."""
        assert self.alive, f"slave shard {self.shard_id} is down"
        w, _ = self.tables[group].gather(ids, create=False)
        return w

    # -- hot backup ----------------------------------------------------------
    def full_sync_from(self, other: "SlaveShard") -> None:
        """Bootstrap a fresh replica: full copy then streaming catch-up."""
        for g, t in other.tables.items():
            snap = t.snapshot()
            self.tables[g] = SparseTable.restore(
                snap, t.dim, (), dtype=t.dtype)
        self.dense = {k: v.copy() for k, v in other.dense.items()}
        self.dense_versions = dict(other.dense_versions)
        self._applied_seq = dict(other._applied_seq)

    def kill(self) -> None:
        self.alive = False

    def revive(self) -> None:
        self.alive = True
