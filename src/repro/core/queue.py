"""Partitioned durable log — the framework's stand-in for the paper's
external Kafka queue between master and slave parameter servers.

Semantics kept faithful to what the paper relies on:
  * per-partition append ordering;
  * consumer-managed offsets (so a checkpointed offset can replay);
  * at-least-once delivery (consumers may re-read; records are idempotent
    because WeiPS pushes full current values per ID, last-writer-wins by
    ``seq``);
  * partition-selective consumption (a slave subscribes only to its
    partitions — paper §4.1.4).

On a real deployment this interface fronts a Kafka client; everything above
it (gather/push/scatter, fault tolerance, downgrade) is transport-agnostic.
"""

from __future__ import annotations

import fcntl
import json
import os
import pickle
import struct
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional

import numpy as np


@dataclass
class Record:
    """One sync message: full current values for a set of IDs of one group.

    ``seq`` is a per-(producer shard, group) monotonic version used for
    last-writer-wins idempotent application on the slave. ``op`` is
    "upsert" or "delete" (feature-filter expiry produces deletes).
    """

    group: str
    op: str
    ids: np.ndarray                  # (n,) int64 row/expert/tensor ids
    payload: Any                     # transformed values (see transform.py)
    seq: int
    producer: int                    # master shard id
    meta: dict = field(default_factory=dict)
    _nbytes: Optional[int] = field(default=None, repr=False, compare=False)

    def nbytes(self) -> int:
        """Wire size estimate (bandwidth accounting for benchmarks).
        Memoized — both the pusher and the queue account every record, and
        records are immutable once produced. Codec payloads (dicts of
        arrays) are sized arithmetically; pickling them for accounting
        would copy the whole payload on the push hot path."""
        if self._nbytes is None:
            pay = 0
            try:
                if isinstance(self.payload, dict):
                    for v in self.payload.values():
                        pay += np.asarray(v).nbytes + 96   # ~pickle framing
                else:
                    pay = len(pickle.dumps(self.payload, protocol=4))
            except Exception:
                pay = 0
            self._nbytes = int(self.ids.nbytes + pay + 64)
        return self._nbytes


class PartitionedQueue:
    """In-memory partitioned log with per-partition offsets."""

    def __init__(self, num_partitions: int):
        assert num_partitions >= 1
        self.num_partitions = num_partitions
        self._logs: list[list[Record]] = [[] for _ in range(num_partitions)]
        self._lock = threading.Lock()
        self.produced_bytes = 0
        self.produced_records = 0

    # -- producer side ---------------------------------------------------
    def produce(self, partition: int, record: Record) -> int:
        """Appends; returns the offset of the new record."""
        with self._lock:
            log = self._logs[partition]
            log.append(record)
            self.produced_bytes += record.nbytes()
            self.produced_records += 1
            return len(log) - 1

    def produce_many(self, partition: int, records: Iterable[Record]) -> int:
        """Batched append (one lock acquisition per partition segment —
        the pusher's vectorized routing emits whole segments at once).
        Returns the next offset after the appended records."""
        with self._lock:
            log = self._logs[partition]
            for record in records:
                log.append(record)
                self.produced_bytes += record.nbytes()
                self.produced_records += 1
            return len(log)

    # -- consumer side ----------------------------------------------------
    def consume(self, partition: int, offset: int,
                max_records: Optional[int] = None) -> tuple[list[Record], int]:
        """Reads records from ``offset``; returns (records, next_offset)."""
        log = self._logs[partition]
        end = len(log)
        if max_records is not None:
            end = min(end, offset + max_records)
        return log[offset:end], end

    def latest_offset(self, partition: int) -> int:
        return len(self._logs[partition])

    def latest_offsets(self) -> dict[int, int]:
        return {p: len(log) for p, log in enumerate(self._logs)}

    def truncate_before(self, partition: int, offset: int) -> None:
        """Retention: drop records below offset (offsets stay absolute)."""
        # Keep absolute offsets simple for this simulation: mark, don't free.
        del partition, offset


class FileQueue:
    """File-backed partitioned log with the :class:`PartitionedQueue`
    interface — the transport of the multi-process cluster runtime.

    One append-only file per partition holds CRC-framed pickled records::

        frame := header(8B: <II little-endian (body_len, crc32(body))) body

    Durability model (what the chaos harness relies on):

      * Each frame is written with a single ``write(2)`` on an ``O_APPEND``
        fd, so concurrent producers (one Pusher per master process) never
        interleave bytes of a frame on a local filesystem.
      * A producer SIGKILLed mid-append leaves at most one torn frame at
        the tail. Readers validate length and CRC and silently stop at the
        first bad frame, so a torn tail is indistinguishable from "not yet
        produced" — exactly Kafka's unflushed-segment behaviour.
      * Frames live in the page cache after ``write`` returns, so they
        survive process death (the failure unit injected by the chaos
        harness) without fsync; only whole-machine crashes can lose them.

    Offsets are record indices, identical to :class:`PartitionedQueue`, so
    checkpointed Scatter offsets seek/replay unchanged. Every process
    (producer or consumer) holds its own ``FileQueue`` over the shared
    directory; readers discover frames appended by other processes by
    re-scanning the file tail on demand.
    """

    _HDR = struct.Struct("<II")

    def __init__(self, root: str, num_partitions: Optional[int] = None):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        meta_path = os.path.join(self.root, "meta.json")
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                existing = json.load(f)["num_partitions"]
            assert num_partitions in (None, existing), \
                f"queue at {root} has {existing} partitions"
            num_partitions = existing
        else:
            assert num_partitions is not None and num_partitions >= 1
            tmp = meta_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"num_partitions": num_partitions}, f)
            os.replace(tmp, meta_path)
        self.num_partitions = int(num_partitions)
        # Per-partition frame index: list of (file_pos, body_len) for every
        # valid frame scanned so far, plus the byte position scanning
        # reached. Rebuilt lazily per process; torn tails end the scan.
        self._index: list[list[tuple[int, int]]] = \
            [[] for _ in range(self.num_partitions)]
        self._scanned: list[int] = [0] * self.num_partitions
        self._wfds: list[Optional[int]] = [None] * self.num_partitions
        self._rfds: list[Optional[int]] = [None] * self.num_partitions
        self._lock = threading.Lock()
        self.produced_bytes = 0          # this process's contribution
        self.produced_records = 0

    def _path(self, partition: int) -> str:
        return os.path.join(self.root, f"part-{partition:05d}.log")

    def _wfd(self, partition: int) -> int:
        if self._wfds[partition] is None:
            fd = os.open(self._path(partition),
                         os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
            self._wfds[partition] = fd
            # Tail repair: a writer SIGKILLed mid-append leaves a torn
            # frame; frames appended after it would be unreachable (scans
            # stop at the first bad frame). Truncate the garbage under the
            # append lock — live writers hold it across their write, so a
            # valid in-flight frame can never be clipped.
            fcntl.flock(fd, fcntl.LOCK_EX)
            try:
                self._extend_index(partition)
                if os.fstat(fd).st_size > self._scanned[partition]:
                    os.ftruncate(fd, self._scanned[partition])
            finally:
                fcntl.flock(fd, fcntl.LOCK_UN)
        return self._wfds[partition]

    def _rfd(self, partition: int) -> int:
        if self._rfds[partition] is None:
            self._rfds[partition] = os.open(
                self._path(partition), os.O_RDONLY | os.O_CREAT, 0o644)
        return self._rfds[partition]

    def _extend_index(self, partition: int) -> None:
        """Scan frames appended (possibly by other processes) since the
        last scan. Stops at a short or CRC-failing frame — a torn tail."""
        fd = self._rfd(partition)
        size = os.fstat(fd).st_size
        pos = self._scanned[partition]
        index = self._index[partition]
        while pos + self._HDR.size <= size:
            hdr = os.pread(fd, self._HDR.size, pos)
            if len(hdr) < self._HDR.size:
                break
            body_len, crc = self._HDR.unpack(hdr)
            body_pos = pos + self._HDR.size
            if body_pos + body_len > size:
                break                                   # torn tail
            body = os.pread(fd, body_len, body_pos)
            if len(body) < body_len or zlib.crc32(body) != crc:
                break                                   # torn/corrupt tail
            index.append((body_pos, body_len))
            pos = body_pos + body_len
        self._scanned[partition] = pos

    # -- producer side ---------------------------------------------------
    def produce(self, partition: int, record: Record) -> int:
        return self.produce_many(partition, [record]) - 1

    def produce_many(self, partition: int, records: Iterable[Record]) -> int:
        """Appends one frame per record; returns the next offset (the
        record count observed in this process after the append)."""
        with self._lock:
            fd = self._wfd(partition)
            fcntl.flock(fd, fcntl.LOCK_EX)
            try:
                for record in records:
                    body = pickle.dumps(record, protocol=4)
                    os.write(fd, self._HDR.pack(len(body), zlib.crc32(body))
                             + body)
                    self.produced_bytes += record.nbytes()
                    self.produced_records += 1
            finally:
                fcntl.flock(fd, fcntl.LOCK_UN)
            self._extend_index(partition)
            return len(self._index[partition])

    # -- consumer side ----------------------------------------------------
    def consume(self, partition: int, offset: int,
                max_records: Optional[int] = None) -> tuple[list[Record], int]:
        with self._lock:
            self._extend_index(partition)
            index = self._index[partition]
            end = len(index)
            if max_records is not None:
                end = min(end, offset + max_records)
            fd = self._rfd(partition)
            out = [pickle.loads(os.pread(fd, length, pos))
                   for pos, length in index[offset:end]]
            # Never rewind a consumer that seeked past a tail not yet
            # visible to this process (recovering replicas do this).
            return out, end if out else max(end, offset)

    def latest_offset(self, partition: int) -> int:
        with self._lock:
            self._extend_index(partition)
            return len(self._index[partition])

    def latest_offsets(self) -> dict[int, int]:
        return {p: self.latest_offset(p) for p in range(self.num_partitions)}

    def truncate_before(self, partition: int, offset: int) -> None:
        """Retention: offsets stay absolute (same policy as the in-memory
        queue — mark, don't free)."""
        del partition, offset

    def close(self) -> None:
        with self._lock:
            for fds in (self._wfds, self._rfds):
                for i, fd in enumerate(fds):
                    if fd is not None:
                        os.close(fd)
                        fds[i] = None


class Consumer:
    """Offset-tracking consumer over a subset of partitions."""

    def __init__(self, queue: PartitionedQueue, partitions: Iterable[int],
                 offsets: Optional[dict[int, int]] = None):
        self.queue = queue
        self.partitions = sorted(set(partitions))
        self.offsets = {p: 0 for p in self.partitions}
        if offsets:
            self.offsets.update({p: offsets[p] for p in self.partitions
                                 if p in offsets})

    def poll(self, max_records: Optional[int] = None) -> list[Record]:
        out: list[Record] = []
        for p in self.partitions:
            recs, nxt = self.queue.consume(p, self.offsets[p], max_records)
            out.extend(recs)
            self.offsets[p] = nxt
        return out

    def lag(self) -> int:
        return sum(self.queue.latest_offset(p) - self.offsets[p]
                   for p in self.partitions)

    def seek(self, offsets: dict[int, int]) -> None:
        """Rewind/forward to recorded offsets (checkpoint replay)."""
        for p in self.partitions:
            if p in offsets:
                self.offsets[p] = offsets[p]
