"""Partitioned durable log — the framework's stand-in for the paper's
external Kafka queue between master and slave parameter servers.

Semantics kept faithful to what the paper relies on:
  * per-partition append ordering;
  * consumer-managed offsets (so a checkpointed offset can replay);
  * at-least-once delivery (consumers may re-read; records are idempotent
    because WeiPS pushes full current values per ID, last-writer-wins by
    ``seq``);
  * partition-selective consumption (a slave subscribes only to its
    partitions — paper §4.1.4).

On a real deployment this interface fronts a Kafka client; everything above
it (gather/push/scatter, fault tolerance, downgrade) is transport-agnostic.
"""

from __future__ import annotations

import pickle
import threading
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional

import numpy as np


@dataclass
class Record:
    """One sync message: full current values for a set of IDs of one group.

    ``seq`` is a per-(producer shard, group) monotonic version used for
    last-writer-wins idempotent application on the slave. ``op`` is
    "upsert" or "delete" (feature-filter expiry produces deletes).
    """

    group: str
    op: str
    ids: np.ndarray                  # (n,) int64 row/expert/tensor ids
    payload: Any                     # transformed values (see transform.py)
    seq: int
    producer: int                    # master shard id
    meta: dict = field(default_factory=dict)
    _nbytes: Optional[int] = field(default=None, repr=False, compare=False)

    def nbytes(self) -> int:
        """Wire size estimate (bandwidth accounting for benchmarks).
        Memoized — both the pusher and the queue account every record, and
        records are immutable once produced. Codec payloads (dicts of
        arrays) are sized arithmetically; pickling them for accounting
        would copy the whole payload on the push hot path."""
        if self._nbytes is None:
            pay = 0
            try:
                if isinstance(self.payload, dict):
                    for v in self.payload.values():
                        pay += np.asarray(v).nbytes + 96   # ~pickle framing
                else:
                    pay = len(pickle.dumps(self.payload, protocol=4))
            except Exception:
                pay = 0
            self._nbytes = int(self.ids.nbytes + pay + 64)
        return self._nbytes


class PartitionedQueue:
    """In-memory partitioned log with per-partition offsets."""

    def __init__(self, num_partitions: int):
        assert num_partitions >= 1
        self.num_partitions = num_partitions
        self._logs: list[list[Record]] = [[] for _ in range(num_partitions)]
        self._lock = threading.Lock()
        self.produced_bytes = 0
        self.produced_records = 0

    # -- producer side ---------------------------------------------------
    def produce(self, partition: int, record: Record) -> int:
        """Appends; returns the offset of the new record."""
        with self._lock:
            log = self._logs[partition]
            log.append(record)
            self.produced_bytes += record.nbytes()
            self.produced_records += 1
            return len(log) - 1

    def produce_many(self, partition: int, records: Iterable[Record]) -> int:
        """Batched append (one lock acquisition per partition segment —
        the pusher's vectorized routing emits whole segments at once).
        Returns the next offset after the appended records."""
        with self._lock:
            log = self._logs[partition]
            for record in records:
                log.append(record)
                self.produced_bytes += record.nbytes()
                self.produced_records += 1
            return len(log)

    # -- consumer side ----------------------------------------------------
    def consume(self, partition: int, offset: int,
                max_records: Optional[int] = None) -> tuple[list[Record], int]:
        """Reads records from ``offset``; returns (records, next_offset)."""
        log = self._logs[partition]
        end = len(log)
        if max_records is not None:
            end = min(end, offset + max_records)
        return log[offset:end], end

    def latest_offset(self, partition: int) -> int:
        return len(self._logs[partition])

    def latest_offsets(self) -> dict[int, int]:
        return {p: len(log) for p, log in enumerate(self._logs)}

    def truncate_before(self, partition: int, offset: int) -> None:
        """Retention: drop records below offset (offsets stay absolute)."""
        # Keep absolute offsets simple for this simulation: mark, don't free.
        del partition, offset


class Consumer:
    """Offset-tracking consumer over a subset of partitions."""

    def __init__(self, queue: PartitionedQueue, partitions: Iterable[int],
                 offsets: Optional[dict[int, int]] = None):
        self.queue = queue
        self.partitions = sorted(set(partitions))
        self.offsets = {p: 0 for p in self.partitions}
        if offsets:
            self.offsets.update({p: offsets[p] for p in self.partitions
                                 if p in offsets})

    def poll(self, max_records: Optional[int] = None) -> list[Record]:
        out: list[Record] = []
        for p in self.partitions:
            recs, nxt = self.queue.consume(p, self.offsets[p], max_records)
            out.extend(recs)
            self.offsets[p] = nxt
        return out

    def lag(self) -> int:
        return sum(self.queue.latest_offset(p) - self.offsets[p]
                   for p in self.partitions)

    def seek(self, offsets: dict[int, int]) -> None:
        """Rewind/forward to recorded offsets (checkpoint replay)."""
        for p in self.partitions:
            if p in offsets:
                self.offsets[p] = offsets[p]
