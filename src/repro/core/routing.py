"""Shard routing: ID → master shard / queue partition / slave shard.

The paper's *model routing* requirement (§4.1.4a): master and slave shard
counts differ (training is throughput-sharded, serving is latency/QPS-
sharded), and the same stream must serve both. We partition the queue by
**ID** (not by producer shard): with ``num_partitions`` a multiple of the
slave shard count, partition ``p`` only ever contains IDs owned by slave
shard ``p % num_slave`` — each slave consumes exactly its partitions, no
filtering waste (paper: "the slave can specify certain partitions for
consuming ... reducing bandwidth pressure").

The same plan drives checkpoint-reload migration across heterogeneous
clusters (paper §4.2.1d): ``reshard_plan`` maps every source shard's rows to
destination shards.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _mix(ids: np.ndarray) -> np.ndarray:
    """Cheap deterministic 64-bit mix so modulo sharding is balanced even
    for structured ID spaces (e.g. contiguous feature buckets)."""
    x = ids.astype(np.uint64, copy=True)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    return x


@dataclass(frozen=True)
class RoutingPlan:
    num_master: int
    num_slave: int
    num_partitions: int

    def __post_init__(self):
        assert self.num_master >= 1 and self.num_slave >= 1
        assert self.num_partitions % self.num_slave == 0, (
            "num_partitions must be a multiple of num_slave so each slave "
            "shard consumes exactly its own partitions")

    def master_shard(self, ids: np.ndarray) -> np.ndarray:
        return (_mix(np.asarray(ids)) % np.uint64(self.num_master)).astype(
            np.int64)

    def partition(self, ids: np.ndarray) -> np.ndarray:
        return (_mix(np.asarray(ids)) % np.uint64(self.num_partitions)).astype(
            np.int64)

    def slave_shard(self, ids: np.ndarray) -> np.ndarray:
        # congruent with partition(): id -> partition p has p % S == slave
        return (self.partition(ids) % self.num_slave).astype(np.int64)

    def partitions_for_slave(self, slave_id: int) -> list[int]:
        return [p for p in range(self.num_partitions)
                if p % self.num_slave == slave_id]

    def split_by_master(self, ids: np.ndarray) -> dict[int, np.ndarray]:
        owner = self.master_shard(ids)
        return {s: ids[owner == s] for s in range(self.num_master)
                if np.any(owner == s)}

    def split_by_partition(self, ids: np.ndarray) -> dict[int, np.ndarray]:
        part = self.partition(ids)
        return {p: ids[part == p] for p in np.unique(part)}


def owner_segments(owner: np.ndarray):
    """Yield (owner_id, index array) per destination with ONE argsort over
    the whole id set — the segment-routing primitive shared by the
    streaming pusher (ids → queue partitions), the recovery router
    (checkpoint rows → shards), and the serving pull path (request ids →
    slave shards / master shards). Callers apply the yielded indices to
    whatever columns they route."""
    key = owner
    if key.size and key.itemsize > 2 and 0 <= key[0] < 65536 \
            and int(key.max()) < 65536 and int(key.min()) >= 0:
        # shard/partition ids are tiny: radix-sorting uint16 keys is 2
        # byte-passes where int64 keys cost 8 — this argsort is the bulk
        # of segment routing on 64k-id cold pulls
        key = key.astype(np.uint16)
    order = np.argsort(key, kind="stable")
    sorted_owner = owner.take(order, mode="clip")
    seg = np.flatnonzero(np.diff(sorted_owner)) + 1
    starts = np.concatenate(([0], seg))
    ends = np.concatenate((seg, [len(owner)]))
    for s, e in zip(starts, ends):
        yield int(sorted_owner[s]), order[s:e]


def reshard_plan(ids: np.ndarray, src_shards: int,
                 dst_shards: int) -> dict[tuple[int, int], np.ndarray]:
    """Checkpoint migration: {(src, dst): ids} mapping for loading a
    checkpoint written with ``src_shards`` into a ``dst_shards`` cluster."""
    ids = np.asarray(ids)
    src = (_mix(ids) % np.uint64(src_shards)).astype(np.int64)
    dst = (_mix(ids) % np.uint64(dst_shards)).astype(np.int64)
    out: dict[tuple[int, int], np.ndarray] = {}
    for s in np.unique(src):
        mask_s = src == s
        for d in np.unique(dst[mask_s]):
            out[(int(s), int(d))] = ids[mask_s & (dst == d)]
    return out
