"""Scheduler (paper §3.3): stateless lifecycle + metadata management.

All durable state lives in the coordination registry (stand-in for
ZooKeeper/etcd): shard membership, routing plan, version registry, consumer
offsets. The scheduler object itself can be dropped and rebuilt from the
registry — mirroring the paper's "the scheduler component ... is stateless".
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional


class CoordinationRegistry:
    """ZooKeeper/etcd stand-in: versioned key-value store with CAS."""

    def __init__(self):
        self._data: dict[str, tuple[int, Any]] = {}

    def put(self, key: str, value: Any) -> int:
        ver = self._data.get(key, (0, None))[0] + 1
        self._data[key] = (ver, value)
        return ver

    def get(self, key: str, default=None) -> Any:
        return self._data.get(key, (0, default))[1]

    def cas(self, key: str, expected_version: int, value: Any) -> bool:
        cur = self._data.get(key, (0, None))[0]
        if cur != expected_version:
            return False
        self._data[key] = (cur + 1, value)
        return True

    def version(self, key: str) -> int:
        return self._data.get(key, (0, None))[0]

    def keys(self, prefix: str = "") -> list[str]:
        return sorted(k for k in self._data if k.startswith(prefix))


@dataclass
class ComponentInfo:
    role: str                  # trainer | predictor | master | slave
    shard_id: int
    replica_id: int = 0
    alive: bool = True
    started_at: float = 0.0


class Scheduler:
    """Lifecycle + metadata for the whole cluster."""

    def __init__(self, registry: Optional[CoordinationRegistry] = None):
        self.registry = registry or CoordinationRegistry()

    # -- membership ---------------------------------------------------------
    def register(self, info: ComponentInfo) -> str:
        key = f"members/{info.role}/{info.shard_id}/{info.replica_id}"
        self.registry.put(key, info)
        return key

    def mark_dead(self, role: str, shard_id: int, replica_id: int = 0):
        key = f"members/{role}/{shard_id}/{replica_id}"
        info = self.registry.get(key)
        if info is not None:
            info.alive = False
            self.registry.put(key, info)

    def members(self, role: str) -> list[ComponentInfo]:
        return [self.registry.get(k)
                for k in self.registry.keys(f"members/{role}/")]

    # -- model version metadata ----------------------------------------------
    def publish_version(self, model: str, version: int,
                        meta: Optional[dict] = None) -> None:
        self.registry.put(f"models/{model}/versions/{version}", meta or {})
        self.registry.put(f"models/{model}/current", version)

    def current_version(self, model: str) -> Optional[int]:
        return self.registry.get(f"models/{model}/current")

    def set_routing(self, model: str, plan) -> None:
        self.registry.put(f"models/{model}/routing", plan)

    def routing(self, model: str):
        return self.registry.get(f"models/{model}/routing")

    # -- serving scenarios ---------------------------------------------------
    def register_scenario(self, model: str, scenario: str,
                          meta: Optional[dict] = None) -> None:
        """Publish a serving scenario (a predict configuration reading a
        subset of the shared PS groups) into the registry — predictors
        discover scenario membership the same way shards discover
        routing, so the registry stays the single durable source."""
        self.registry.put(f"models/{model}/scenarios/{scenario}", meta or {})

    def scenarios(self, model: str) -> list[str]:
        prefix = f"models/{model}/scenarios/"
        return [k[len(prefix):] for k in self.registry.keys(prefix)]

    def scenario_meta(self, model: str, scenario: str) -> Optional[dict]:
        return self.registry.get(f"models/{model}/scenarios/{scenario}")

    # -- training scenarios --------------------------------------------------
    def register_train_scenario(self, model: str, scenario: str,
                                meta: Optional[dict] = None) -> None:
        """Publish a *training* scenario — the symmetric twin of
        ``register_scenario``: trainers discover which model variants are
        learning off the shared PS (and which groups they own) through
        the same durable registry predictors use."""
        self.registry.put(f"models/{model}/train_scenarios/{scenario}",
                          meta or {})

    def train_scenarios(self, model: str) -> list[str]:
        prefix = f"models/{model}/train_scenarios/"
        return [k[len(prefix):] for k in self.registry.keys(prefix)]

    def train_scenario_meta(self, model: str,
                            scenario: str) -> Optional[dict]:
        return self.registry.get(
            f"models/{model}/train_scenarios/{scenario}")
