"""Streaming synchronization (paper §4.1): collect → gather → push → scatter.

  Collector  — per master shard; captures dirty IDs + op type only (no
               values, no increments) into a lock-free-queue stand-in.
  Gatherer   — deduplicating aggregation window with the paper's three
               trigger modes: real-time, threshold-based, period-based.
               Dedup ratio is tracked (the paper observes ≥90 % repetition
               of updates within 10 s — benchmarks/sync_path.py reproduces
               this with Zipfian update streams).
  Pusher     — reads *current full values* for the gathered IDs (eventual
               consistency at ID granularity: never increments), applies the
               model transform (FTRL z,n→w, dtype cast, int8 quant),
               serializes, and produces to the ID-routed queue partition.
  Scatter    — per slave shard; consumes its partitions and applies records
               idempotently (LWW by seq). Its consumer offsets are embedded
               in every checkpoint and ``seek``-able, so recovery, replica
               bootstrap, and domino downgrade replay the stream exactly
               from the restored state (core/fault_tolerance.py).

The push and scatter stages are fully batched (no per-partition/per-chunk
Python): one gather + one encode per (group, op), vectorized argsort
routing to partitions, and one ownership filter + one coalesced scatter
per poll — see ``Pusher.push`` / ``Scatter.poll``. ``benchmarks/
sync_path.py`` measures this against the pre-refactor per-partition loop.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.obs import trace as obs_trace
from repro.core.ps import MasterShard, SlaveShard
from repro.core.queue import Consumer, PartitionedQueue, Record
from repro.core.routing import RoutingPlan
from repro.core.transform import Transform


class Collector:
    """Dirty-ID capture. The paper's lock-free multi-producer queue guards
    multi-threaded trainers; in the SPMD/JAX adaptation collection happens
    post-step on device-computed unique IDs, so a list suffices — the
    *semantics* kept are: IDs + op only, never values (§4.1.1)."""

    def __init__(self):
        self._events: list[tuple[str, np.ndarray, str]] = []
        self.collected_ids = 0

    def record(self, group: str, ids: np.ndarray, op: str = "upsert") -> None:
        ids = np.asarray(ids, dtype=np.int64)
        self._events.append((group, ids, op))
        self.collected_ids += len(ids)

    def record_dense(self, name: str) -> None:
        self._events.append((f"dense/{name}", np.zeros(1, np.int64), "upsert"))

    def drain(self) -> list[tuple[str, np.ndarray, str]]:
        out, self._events = self._events, []
        return out


@dataclass
class GatherStats:
    raw_ids: int = 0          # ids entering the window (with repetition)
    pushed_ids: int = 0       # unique ids actually pushed
    flushes: int = 0

    @property
    def dedup_ratio(self) -> float:
        """Fraction of raw updates absorbed by deduplication."""
        if self.raw_ids == 0:
            return 0.0
        return 1.0 - self.pushed_ids / self.raw_ids


class Gatherer:
    """Aggregation window with the three trigger modes (§4.1.2)."""

    def __init__(self, mode: str = "period", *, threshold: int = 4096,
                 period: float = 1.0):
        assert mode in ("realtime", "threshold", "period")
        self.mode = mode
        self.threshold = threshold
        self.period = period
        # window state: (group, op) -> list of per-offer unique id arrays.
        # Offers are O(batch log batch); the cross-offer merge happens once
        # at flush (amortized-linear, vs per-offer union1d's quadratic
        # re-merging of the whole window).
        self._pending: dict[tuple[str, str], list[np.ndarray]] = {}
        self._pending_count = 0      # pre-merge upper bound on unique ids
        self._last_flush = 0.0
        self.stats = GatherStats()

    def offer(self, events: list[tuple[str, np.ndarray, str]]) -> None:
        for group, ids, op in events:
            ids = np.asarray(ids, dtype=np.int64)
            self.stats.raw_ids += len(ids)
            u = np.unique(ids)
            self._pending.setdefault((group, op), []).append(u)
            # upper bound: cross-offer repeats are only collapsed at flush,
            # so threshold mode can fire slightly early — never late
            self._pending_count += len(u)

    def ready(self, now: float) -> bool:
        if self._pending_count == 0 and not self._pending:
            return False
        if self.mode == "realtime":
            return True
        if self.mode == "threshold":
            return self._pending_count >= self.threshold
        return (now - self._last_flush) >= self.period

    def flush(self, now: float) -> dict[tuple[str, str], np.ndarray]:
        out = {}
        for k, chunks in self._pending.items():
            merged = chunks[0] if len(chunks) == 1 else \
                np.unique(np.concatenate(chunks))
            if len(merged):
                out[k] = merged
        self._pending = {}
        self._pending_count = 0
        self._last_flush = now
        self.stats.pushed_ids += sum(len(v) for v in out.values())
        self.stats.flushes += 1
        return out


def _slice_payload(payload: dict, lo: int, hi: int, n: int) -> dict:
    """Row-slice every per-row array of an encoded payload (arrays whose
    leading dim is the row count ``n``); scalars/metadata pass through."""
    out = {}
    for k, v in payload.items():
        a = np.asarray(v)
        out[k] = a[lo:hi] if a.ndim >= 1 and a.shape[0] == n else v
    return out


class Pusher:
    """Master-side: full-current-value reads + transform + partitioned
    produce. ``seq`` is per (group, producer) monotonic.

    The sparse hot path is batched end-to-end: ONE ``table.gather`` and
    ONE ``transform.encode`` cover every id of a (group, op) flush — the
    encode amortizes JAX dispatch (FTRL z,n→w) and runs the codec kernel
    over the full row block — then ids are routed to partitions with a
    single argsort and the encoded payload is *sliced*, never re-encoded,
    per partition-chunk record."""

    def __init__(self, shard: MasterShard, queue: PartitionedQueue,
                 plan: RoutingPlan, transform: Transform,
                 max_ids_per_record: int = 65536):
        self.shard = shard
        self.queue = queue
        self.plan = plan
        self.transform = transform
        self.max_ids_per_record = max_ids_per_record
        self._seq: dict[str, int] = {}
        self.pushed_bytes = 0
        self.pushed_records = 0
        # trace metadata stamped into every record of the current flush
        # while a sync.push span is open (None when tracing is off, so
        # the disabled path produces byte-identical records)
        self._tmeta: Optional[dict] = None

    def _next_seq(self, group: str) -> int:
        s = self._seq.get(group, -1) + 1
        self._seq[group] = s
        return s

    def seqs(self) -> dict[str, int]:
        """Per-group sequence counters for the checkpoint cut. A restored
        pusher re-emits the SAME seq for a replayed flush, which is what
        lets slaves LWW-skip (or idempotently re-apply) replayed records
        instead of treating them as fresh writes."""
        return dict(self._seq)

    def restore_seqs(self, seqs: dict[str, int]) -> None:
        self._seq = dict(seqs)

    def push(self, gathered: dict[tuple[str, str], np.ndarray],
             now: float = 0.0) -> int:
        """Returns number of records produced."""
        tr = obs_trace.get_tracer()
        sp = None
        if tr.enabled and gathered:
            # one flush == one trace: every record produced below carries
            # this (trace, span, t_push), which crosses the FileQueue
            # inside the pickled frame and lets the consumer reconstruct
            # queue dwell + parent its apply under this span
            sp = tr.begin("sync.push", trace=tr.new_trace(),
                          producer=self.shard.shard_id,
                          groups=len(gathered))
            self._tmeta = {"trace": sp.trace, "span": sp.id,
                           "t_push": sp.t0}
        n_rec = 0
        try:
            for (group, op), ids in gathered.items():
                if group.startswith("dense/"):
                    n_rec += self._push_dense(group, op, now)
                else:
                    n_rec += self._push_sparse(group, op, ids, now)
        finally:
            if sp is not None:
                tr.end(sp)
                self._tmeta = None
        self.pushed_records += n_rec
        return n_rec

    def _push_dense(self, group: str, op: str, now: float) -> int:
        name = group[len("dense/"):]
        value = self.shard.dense.tensors.get(name)
        if value is None:
            return 0
        ver = self.shard.dense.versions[name]
        # copy: identity encode passes arrays through uncopied, and a
        # queued payload must never alias the live dense tensor
        payload = self.transform.encode(
            value.reshape(1, -1).copy(),
            self.shard.dense.slots.get(name, {}))
        meta = {"codec": self.transform.name, "t": now,
                "shape": value.shape}
        if self._tmeta is not None:
            meta.update(self._tmeta)
        rec = Record(group=group, op="upsert",
                     ids=np.array([ver], np.int64), payload=payload,
                     seq=self._next_seq(group),
                     producer=self.shard.shard_id, meta=meta)
        n = 0
        # dense tensors go to every slave: replicate to one partition per
        # slave shard
        for slave in range(self.plan.num_slave):
            p = self.plan.partitions_for_slave(slave)[0]
            self.queue.produce(p, rec)
            self.pushed_bytes += rec.nbytes()
            n += 1
        return n

    def _push_sparse(self, group: str, op: str, ids: np.ndarray,
                     now: float) -> int:
        if len(ids) == 0:
            return 0
        table = self.shard.tables[group]
        seq = self._next_seq(group)
        # vectorized routing: one argsort groups ids into contiguous
        # partition segments (vs. the pre-refactor num_partitions boolean
        # masks over the whole id set)
        part = self.plan.partition(ids)
        order = np.argsort(part, kind="stable")
        ids = ids.take(order, mode="clip")
        part = part.take(order, mode="clip")
        seg = np.flatnonzero(np.diff(part)) + 1      # segment boundaries
        starts = np.concatenate(([0], seg))
        ends = np.concatenate((seg, [len(ids)]))
        if op == "delete":
            payload = None
        else:
            # ONE batched gather, reading only the columns the transform
            # declares (FTRL codecs read (z, n) and skip w; plain codecs
            # read w and skip the slots), then ONE encode
            w, slots = table.gather(
                ids, want_w=self.transform.requires_w,
                slot_names=self.transform.required_slots)
            payload = self.transform.encode(w, slots)
        n = 0
        for s, e in zip(starts, ends):
            p = int(part[s])
            recs = []
            for i in range(s, e, self.max_ids_per_record):
                j = min(i + self.max_ids_per_record, e)
                # partition stamp: ids route to partitions
                # deterministically, so each partition is its own
                # ordered stream — slaves key LWW staleness per
                # (group, producer, partition), not globally (a
                # global key would mis-skip a partition's records
                # when a later flush touched only other partitions)
                meta = {"codec": self.transform.name, "t": now,
                        "partition": p}
                if self._tmeta is not None:
                    meta.update(self._tmeta)
                recs.append(Record(
                    group=group, op=op, ids=ids[i:j],
                    payload={} if payload is None
                    else _slice_payload(payload, i, j, len(ids)),
                    seq=seq, producer=self.shard.shard_id, meta=meta))
            self.queue.produce_many(p, recs)
            self.pushed_bytes += sum(r.nbytes() for r in recs)
            n += len(recs)
        return n


class Scatter:
    """Slave-side consumer: poll partitions, apply idempotently.

    A poll is batched: ownership of every sparse id in the poll is
    resolved with ONE vectorized routing pass, then the surviving records
    go through ``SlaveShard.apply_batch`` — one coalesced table scatter
    per group instead of a per-record apply loop."""

    def __init__(self, shard: SlaveShard, queue: PartitionedQueue,
                 plan: RoutingPlan,
                 offsets: Optional[dict[int, int]] = None):
        self.shard = shard
        self.plan = plan
        self.consumer = Consumer(queue, plan.partitions_for_slave(
            shard.shard_id), offsets)
        self.applied = 0
        self.last_record_time = 0.0
        # event→deployed staleness per applied record: the pusher stamps
        # meta["t"] at push time, the apply happens here, and the apply
        # runs SlaveShard.on_apply (serve-cache invalidation) inline — so
        # now - meta["t"] at this point IS push→scatter→cache-visible,
        # the SLO the ROADMAP's harness measures. Deferred import keeps
        # streaming.py free of a monitor dependency at module load.
        from repro.core.monitor import PercentileRing
        self.staleness = PercentileRing(1 << 12)
        # called with the polled records after the consumer advanced but
        # BEFORE any of them is applied — the crash window between fetch
        # and apply. The chaos harness kills here; a process dying at this
        # point re-polls the same records after restart (at-least-once),
        # and full-value upserts make the redelivery idempotent.
        self.pre_apply = None

    def poll(self, max_records: Optional[int] = None, *,
             now: Optional[float] = None) -> int:
        recs = self.consumer.poll(max_records)
        if not recs:
            return 0
        if self.pre_apply is not None:
            self.pre_apply(recs)
        # model routing: keep only ids owned by this slave shard — with
        # num_partitions % num_slave == 0 this filter is a no-op for
        # sparse groups (partition congruence), but guards dense
        # broadcast records and future re-partitioning. One vectorized
        # ownership pass covers the whole poll.
        sparse = [k for k, r in enumerate(recs)
                  if not r.group.startswith("dense/")]
        if sparse:
            owner = self.plan.slave_shard(
                np.concatenate([recs[k].ids for k in sparse]))
            keep_all = owner == self.shard.shard_id
            if not keep_all.all():
                off = 0
                for k in sparse:
                    r = recs[k]
                    keep = keep_all[off:off + len(r.ids)]
                    off += len(r.ids)
                    if not keep.all():
                        recs[k] = Record(
                            group=r.group, op=r.op, ids=r.ids[keep],
                            payload=_filter_payload(r.payload, keep),
                            seq=r.seq, producer=r.producer, meta=r.meta)
        tr = obs_trace.get_tracer()
        if tr.enabled:
            applied = self._apply_traced(tr, recs)
        else:
            applied = self.shard.apply_batch(recs)
        if applied:
            self.last_record_time = applied[-1].meta.get("t", 0.0)
            if now is not None:
                self.staleness.record(
                    [now - r.meta.get("t", now) for r in applied])
        self.applied += len(applied)
        return len(applied)

    def _apply_traced(self, tr, recs: list) -> list:
        """Trace-grouped apply: records stamped by one pusher flush (one
        trace id) apply together so the whole flush shows as one
        queue-dwell + apply pair under its sync.push parent. Regrouping
        preserves semantics: within a (group, producer, partition)
        stream records keep their relative order (dict groups are
        insertion-ordered), and cross-trace overlap resolves by seq
        (LWW) exactly as it would in arrival order."""
        by_trace: dict = {}
        for r in recs:
            by_trace.setdefault(r.meta.get("trace"), []).append(r)
        poll_t0 = tr.clock()
        applied: list = []
        for tid, group in by_trace.items():
            if tid is None:  # records produced before tracing turned on
                applied += self.shard.apply_batch(group)
                continue
            # queue dwell reconstructed consumer-side: produce stamp
            # (t_push, same CLOCK_MONOTONIC domain across processes on
            # Linux) → this poll
            qid = tr.record(
                "sync.queue", trace=tid,
                parent=group[0].meta.get("span", 0),
                t0=min(r.meta.get("t_push", poll_t0) for r in group),
                t1=poll_t0, records=len(group))
            # cache.invalidate spans fired by shard.on_apply nest here
            # via the tracer's implicit context
            with tr.span("sync.apply", trace=tid, parent=qid,
                         shard=self.shard.shard_id, records=len(group)):
                applied += self.shard.apply_batch(group)
        return applied

    def offsets(self) -> dict[int, int]:
        return dict(self.consumer.offsets)

    def lag(self) -> int:
        """Records produced to this shard's partitions not yet applied —
        the staleness signal the serving plane's lag-bounded replica
        selection compares (``ReplicaSet.pick(max_lag=...)``)."""
        return self.consumer.lag()

    def seek(self, offsets: dict[int, int]) -> None:
        """Rewind/forward this consumer to checkpointed queue offsets —
        the replay handle of the recovery and downgrade paths (records
        are full-value upserts, so replay is idempotent)."""
        self.consumer.seek(offsets)


def _filter_payload(payload: dict, keep: np.ndarray) -> dict:
    out = {}
    for k, v in payload.items():
        v = np.asarray(v)
        out[k] = v[keep] if v.ndim >= 1 and v.shape[0] == len(keep) else v
    return out


@dataclass
class SyncMetrics:
    sync_lag_seconds: float = 0.0
    records_in_flight: int = 0
    dedup_ratio: float = 0.0
    pushed_bytes: int = 0


class SyncPipeline:
    """Wires one master shard's collect→gather→push and all slave scatters.

    ``tick(now)`` advances the pipeline; with mode="realtime" every tick
    flushes, with "period" flushes happen every ``period`` sim-seconds —
    this is what the sync-latency benchmark sweeps."""

    def __init__(self, master: MasterShard, slaves: list[SlaveShard],
                 queue: PartitionedQueue, plan: RoutingPlan,
                 transform: Transform, gather_mode: str = "realtime",
                 threshold: int = 4096, period: float = 1.0):
        self.collector = Collector()
        master.collector = self.collector
        self.master = master
        self.gatherer = Gatherer(gather_mode, threshold=threshold,
                                 period=period)
        self.pusher = Pusher(master, queue, plan, transform)
        # consumer-side codec backend is each SlaveShard's own setting
        # (producer and consumer backends are independent — see
        # transform.py); the pipeline never overrides it
        self.scatters = [Scatter(s, queue, plan) for s in slaves]
        self.queue = queue

    def tick(self, now: float, *, scatter: bool = True) -> int:
        """collect+gather+maybe-push, then slave polls. Returns #records."""
        self.gatherer.offer(self.collector.drain())
        n = 0
        if self.gatherer.ready(now):
            n = self.pusher.push(self.gatherer.flush(now), now)
        if scatter:
            for sc in self.scatters:
                if sc.shard.alive:
                    sc.poll()
        return n

    def metrics(self, now: float) -> SyncMetrics:
        lag = max((now - sc.last_record_time) for sc in self.scatters) \
            if self.scatters else 0.0
        return SyncMetrics(
            sync_lag_seconds=lag,
            records_in_flight=sum(sc.consumer.lag() for sc in self.scatters),
            dedup_ratio=self.gatherer.stats.dedup_ratio,
            pushed_bytes=self.pusher.pushed_bytes,
        )
