"""ModelSyncEngine: the WeiPS streaming-sync mechanism applied to the
architecture zoo — second-level deployment of a training LM/MoE/SSM state
to a serving replica through the partitioned queue.

Granularity per parameter kind (DESIGN.md §4):
  * ``embed``             — token-ID rows (dirty = unique tokens seen in the
                            gather window; embedding grads are row-sparse);
  * MoE expert tensors    — (layer, repeat, expert) granularity, dirty =
                            experts actually routed-to in the window (from
                            ``expert_counts_per_layer``);
  * everything else       — tensor granularity with version counters
                            (every train step bumps versions; the gather
                            window dedups them — the paper's ≥90 %%
                            repetition effect).

Beyond-paper extension (§Perf): ``delta_threshold`` — the pusher keeps a
shadow of the last-pushed value and skips tensors/rows whose relative
change is below the threshold, with a periodic full refresh. This is a
bandwidth/staleness trade the paper's full-value-per-ID consistency
contract makes safe (skipped pushes are never *wrong*, only stale).

Backends: ``SyncConfig.codec_backend="pallas"`` routes the int8 codec's
quantize/dequantize through the ``delta_codec`` kernel
(``docs/KERNELS.md``) — bit-identical to the numpy mirror, so producer
and consumer may run different backends. The model states synced here
are dense jax pytrees, not PS tables, so the sparse fused path
(probe→gather→update→scatter, ``ClusterConfig.ps_backend``) does not
apply; rows enter the queue already device-materialized.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MOE, ModelConfig
from repro.core.queue import Consumer, PartitionedQueue, Record
from repro.core.streaming import Gatherer
from repro.core.transform import Transform, decode_record, make_transform

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _is_expert_leaf(cfg: ModelConfig, path: str, leaf) -> bool:
    """MoE expert tensors: segments/*/pos*/ffn/w_* with (R, E, ...) shape."""
    if cfg.num_experts == 0 or "/ffn/" not in path:
        return False
    name = path.rsplit("/", 1)[-1]
    return name in ("w_gate", "w_up", "w_down") and leaf.ndim >= 3 \
        and leaf.shape[1] == cfg.num_experts


@dataclass
class SyncConfig:
    num_partitions: int = 8
    num_slaves: int = 1
    gather_mode: str = "period"
    period: float = 1.0
    threshold: int = 1 << 20
    codec: str = "cast16"
    codec_backend: str = "numpy"      # numpy | pallas (delta_codec kernel)
    delta_threshold: float = 0.0      # 0 = push every dirty item
    full_refresh_every: int = 0       # flushes between forced full pushes
    embed_row_chunk: int = 65536
    # "window": dirty embed rows = tokens in the gather window (exact for
    # momentum-free optimizers: sgd/adagrad/ftrl/adafactor leave untouched
    # rows unchanged). "cumulative": Adam/Momentum keep decaying previously
    # touched rows every step, so every ever-touched row is dirty.
    embed_dirty: str = "auto"         # auto | window | cumulative


class ServeReplica:
    """Slave-side full-model state: applies stream records into a host
    param tree; ``device_params`` materializes it (possibly onto a serving
    mesh with different shardings — model routing for the dense plane)."""

    def __init__(self, cfg: ModelConfig, params_like: PyTree,
                 bootstrap: bool = True, codec_backend: str = "numpy"):
        """``bootstrap`` performs the paper's full synchronization (replica
        attach = checkpoint copy); streaming covers deltas thereafter."""
        self.cfg = cfg
        self.codec_backend = codec_backend
        leaves, self.treedef = jax.tree_util.tree_flatten_with_path(
            params_like)
        self.paths = [_path_str(p) for p, _ in leaves]
        self.host: dict[str, np.ndarray] = {
            path: (np.array(leaf, dtype=np.float32, copy=True) if bootstrap
                   else np.zeros(leaf.shape, np.float32))
            for path, (_, leaf) in zip(self.paths, leaves)}
        self._applied_seq: dict[tuple[str, int], int] = {}
        self.applied = 0
        self.versions: dict[str, int] = {}

    def apply(self, rec: Record) -> bool:
        key = (rec.group, rec.producer)
        if rec.seq < self._applied_seq.get(key, -1):    # strictly older only
            return False
        values = decode_record(rec, backend=self.codec_backend)
        kind = rec.meta["kind"]
        path = rec.meta["path"]
        if kind == "dense":
            ver = int(rec.ids[0])
            if self.versions.get(path, -1) < ver:
                self.host[path] = values.reshape(self.host[path].shape)
                self.versions[path] = ver
        elif kind == "rows":                      # embed rows
            self.host[path][rec.ids] = values
        elif kind == "experts":                   # ids = rep * E + expert
            arr = self.host[path]
            r_idx, e_idx = rec.ids // self.cfg.num_experts, \
                rec.ids % self.cfg.num_experts
            arr[r_idx, e_idx] = values.reshape(
                (len(rec.ids),) + arr.shape[2:])
        self._applied_seq[key] = rec.seq
        self.applied += 1
        return True

    def apply_batch(self, recs: list) -> int:
        """Batched application of a poll's worth of records: row-kind
        records are coalesced per path into ONE fancy-indexed write
        (concatenation preserves arrival order, so overlapping ids resolve
        last-writer-wins exactly like sequential ``apply``); dense/expert
        records keep the singleton path. Returns #records applied."""
        applied = 0
        rows_by_path: dict[str, tuple[list, list]] = {}
        for rec in recs:
            if rec.meta.get("kind") == "rows":
                key = (rec.group, rec.producer)
                if rec.seq < self._applied_seq.get(key, -1):
                    continue
                ids_l, val_l = rows_by_path.setdefault(
                    rec.meta["path"], ([], []))
                ids_l.append(rec.ids)
                val_l.append(decode_record(rec, backend=self.codec_backend))
                self._applied_seq[key] = rec.seq
                self.applied += 1
                applied += 1
            else:
                applied += int(self.apply(rec))
        for path, (ids_l, val_l) in rows_by_path.items():
            ids = np.concatenate(ids_l)
            vals = np.concatenate(val_l, axis=0)
            self.host[path][ids] = vals
        return applied

    def device_params(self, dtype: str = "bfloat16",
                      shardings: Optional[PyTree] = None) -> PyTree:
        dt = jnp.dtype(dtype)
        leaves = [jnp.asarray(self.host[p], dtype=dt) for p in self.paths]
        tree = jax.tree_util.tree_unflatten(self.treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree

    def staleness(self, train_params: PyTree) -> float:
        """Max relative L2 distance to the (transformed) training params —
        the eventual-consistency measure the tests assert goes to ~0."""
        worst = 0.0
        flat, _ = jax.tree_util.tree_flatten_with_path(train_params)
        for p, leaf in flat:
            path = _path_str(p)
            a = np.asarray(leaf, dtype=np.float32)
            b = self.host[path]
            denom = max(float(np.linalg.norm(a)), 1e-9)
            worst = max(worst, float(np.linalg.norm(a - b)) / denom)
        return worst


class ModelSyncEngine:
    """Master-side collect/gather/push + slave replicas, full-model scale."""

    _MOMENTUM_OPTS = ("adam", "momentum")

    def __init__(self, cfg: ModelConfig, params: PyTree,
                 sync: Optional[SyncConfig] = None, queue=None):
        """``queue`` injects an external transport with the
        ``PartitionedQueue`` interface (e.g. a durable ``FileQueue``
        shared across processes); by default the engine owns an
        in-memory queue, matching the single-process wiring."""
        self.cfg = cfg
        self.sync = sync or SyncConfig()
        s = self.sync
        self._embed_mode = s.embed_dirty
        if self._embed_mode == "auto":
            self._embed_mode = ("cumulative" if cfg.optimizer in
                                self._MOMENTUM_OPTS else "window")
        self._embed_touched: set[int] = set()
        # momentum optimizers keep updating previously-routed experts too
        self._expert_touched: dict[str, set[int]] = {}
        if queue is not None:
            assert queue.num_partitions == s.num_partitions, \
                "injected queue partition count must match SyncConfig"
        self.queue = queue if queue is not None else \
            PartitionedQueue(s.num_partitions)
        self.transform = make_transform(s.codec, backend=s.codec_backend)
        self.gatherer = Gatherer(s.gather_mode, threshold=s.threshold,
                                 period=s.period)
        leaves, self.treedef = jax.tree_util.tree_flatten_with_path(params)
        self.paths = [_path_str(p) for p, _ in leaves]
        self.kinds: dict[str, str] = {}
        for path, (_, leaf) in zip(self.paths, leaves):
            if path == "embed":
                # tied embeddings double as the LM head, whose CE gradient
                # is dense over the whole vocab -> tensor granularity.
                self.kinds[path] = "dense" if cfg.tie_embeddings else "rows"
            elif _is_expert_leaf(cfg, path, leaf):
                self.kinds[path] = "experts"
            else:
                self.kinds[path] = "dense"
        self._path_ids = {p: i for i, p in enumerate(self.paths)}
        self.versions = {p: 0 for p in self.paths}
        self._seq = -1
        self._shadow: dict[str, np.ndarray] = {}
        self._flushes = 0
        self.pushed_bytes = 0
        self.skipped_dense = 0
        self.replicas = [ServeReplica(cfg, params,
                                      codec_backend=s.codec_backend)
                         for _ in range(s.num_slaves)]
        self.consumers = [
            Consumer(self.queue, range(s.num_partitions))
            for _ in self.replicas]

    # -- collect -----------------------------------------------------------
    def collect_step(self, tokens: np.ndarray,
                     metrics: Optional[dict] = None) -> None:
        """Record dirty IDs after a train step: unique token rows, routed
        experts per layer, and version bumps for every dense tensor."""
        events = []
        uniq = np.unique(np.asarray(tokens).reshape(-1)).astype(np.int64)
        self._embed_touched.update(uniq.tolist())
        for path, kind in self.kinds.items():
            if kind == "rows":
                events.append((path, uniq, "upsert"))
            elif kind == "dense":
                self.versions[path] += 1
                events.append((f"dense::{path}", np.zeros(1, np.int64),
                               "upsert"))
        if metrics and "expert_counts_per_layer" in metrics and \
                self.cfg.num_experts:
            e = self.cfg.num_experts
            for si, seg_counts in enumerate(metrics["expert_counts_per_layer"]):
                for pos, counts in seg_counts.items():
                    c = np.asarray(counts)                  # (R, E)
                    reps, experts = np.nonzero(c > 0)
                    ids = reps.astype(np.int64) * e + experts
                    for name in ("w_gate", "w_up", "w_down"):
                        path = f"segments/{si}/{pos}/ffn/{name}"
                        if path in self.kinds and \
                                self.kinds[path] == "experts":
                            if self._embed_mode == "cumulative":
                                tset = self._expert_touched.setdefault(
                                    path, set())
                                tset.update(ids.tolist())
                            events.append((path, ids, "upsert"))
        self.gatherer.offer(events)

    # -- push ---------------------------------------------------------------
    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _changed_enough(self, path: str, value: np.ndarray) -> bool:
        thr = self.sync.delta_threshold
        if thr <= 0:
            return True
        if self.sync.full_refresh_every and \
                self._flushes % self.sync.full_refresh_every == 0:
            return True
        old = self._shadow.get(path)
        if old is None:
            return True
        num = float(np.linalg.norm(value - old))
        den = max(float(np.linalg.norm(old)), 1e-9)
        return (num / den) >= thr

    def tick(self, params: PyTree, now: float, *,
             scatter: bool = True) -> int:
        """Gather-window flush: read full current values for dirty IDs from
        the live training params, transform, produce; replicas consume."""
        n = 0
        if self.gatherer.ready(now):
            flat = dict(zip(self.paths, jax.tree_util.tree_leaves(params)))
            gathered = self.gatherer.flush(now)
            self._flushes += 1
            for (group, op), ids in gathered.items():
                path = group[len("dense::"):] if group.startswith("dense::") \
                    else group
                leaf = np.asarray(flat[path], dtype=np.float32)
                kind = self.kinds[path]
                if kind == "dense":
                    if not self._changed_enough(path, leaf):
                        self.skipped_dense += 1
                        continue
                    self._shadow[path] = leaf.copy()
                    # copy: queued payloads must not alias leaf (identity
                    # encode passes arrays through uncopied, and leaf can
                    # alias the caller's live params when they are numpy)
                    payload = self.transform.encode(
                        leaf.reshape(1, -1).copy(), {})
                    rec = Record(group=group, op=op,
                                 ids=np.array([self.versions[path]],
                                              np.int64),
                                 payload=payload, seq=self._next_seq(),
                                 producer=0,
                                 meta={"codec": self.transform.name,
                                       "kind": "dense", "path": path,
                                       "t": now})
                    part = self._path_ids[path] % self.queue.num_partitions
                    self.queue.produce(part, rec)
                    self.pushed_bytes += rec.nbytes()
                    n += 1
                elif kind == "rows":
                    if self._embed_mode == "cumulative":
                        ids = np.fromiter(self._embed_touched, dtype=np.int64,
                                          count=len(self._embed_touched))
                        ids.sort()
                    for i in range(0, len(ids), self.sync.embed_row_chunk):
                        chunk = ids[i:i + self.sync.embed_row_chunk]
                        vals = leaf[chunk]
                        payload = self.transform.encode(vals, {})
                        rec = Record(group=group, op=op, ids=chunk,
                                     payload=payload, seq=self._next_seq(),
                                     producer=0,
                                     meta={"codec": self.transform.name,
                                           "kind": "rows", "path": path,
                                           "t": now})
                        part = int(chunk[0]) % self.queue.num_partitions
                        self.queue.produce(part, rec)
                        self.pushed_bytes += rec.nbytes()
                        n += 1
                elif kind == "experts":
                    e = self.cfg.num_experts
                    if self._embed_mode == "cumulative" and \
                            path in self._expert_touched:
                        tset = self._expert_touched[path]
                        ids = np.fromiter(tset, dtype=np.int64,
                                          count=len(tset))
                        ids.sort()
                    vals = leaf[ids // e, ids % e]
                    vals2 = vals.reshape(len(ids), -1)
                    payload = self.transform.encode(vals2, {})
                    rec = Record(group=group, op=op, ids=ids,
                                 payload=payload, seq=self._next_seq(),
                                 producer=0,
                                 meta={"codec": self.transform.name,
                                       "kind": "experts", "path": path,
                                       "t": now})
                    part = self._path_ids[path] % self.queue.num_partitions
                    self.queue.produce(part, rec)
                    self.pushed_bytes += rec.nbytes()
                    n += 1
        if scatter:
            self.scatter()
        return n

    def scatter(self) -> int:
        n = 0
        for replica, consumer in zip(self.replicas, self.consumers):
            n += replica.apply_batch(list(consumer.poll()))
        return n

    def metrics(self) -> dict:
        return {
            "pushed_bytes": self.pushed_bytes,
            "queue_bytes": self.queue.produced_bytes,
            "dedup_ratio": self.gatherer.stats.dedup_ratio,
            "flushes": self._flushes,
            "skipped_dense": self.skipped_dense,
        }
