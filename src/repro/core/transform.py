"""Model transformation: train-state → serve-state (paper §4.1.4b).

The master's rows are (w, optimizer slots); the slave needs only inference
weights, possibly re-encoded. A ``Transform`` pairs an ``encode`` (runs on
the pusher, master side) with a ``decode`` (runs on the scatter, slave
side). Encodings are *plain data* (numpy arrays / bytes) so they survive
the queue; the codec is named in the record's metadata and resolved from
this registry on the consuming side.

Codecs:
  * identity    — serve weights as-is (fp32)
  * cast16      — fp16 cast (half bandwidth)
  * int8        — row-wise absmax int8 quantization (the Pallas
                  ``delta_codec`` kernel is the TPU version of this path)
  * ftrl        — the heterogeneous-parameter case: encode reads slots
                  (z, n) and ships the *derived* w
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.optim import FTRL, Optimizer


class Transform:
    name: str = "identity"

    def __init__(self, optimizer: Optional[Optimizer] = None):
        self.optimizer = optimizer

    def serve_values(self, w: np.ndarray, slots: dict) -> np.ndarray:
        """Derive inference weights from master state."""
        if self.optimizer is not None:
            import jax.numpy as jnp
            return np.asarray(self.optimizer.serve_weights(
                jnp.asarray(w), {k: jnp.asarray(v) for k, v in slots.items()}))
        return w

    def encode(self, w: np.ndarray, slots: dict) -> dict:
        return {"values": self.serve_values(w, slots).astype(np.float32)}

    @staticmethod
    def decode(payload: dict) -> np.ndarray:
        return payload["values"]

    def payload_bytes(self, payload: dict) -> int:
        return sum(np.asarray(v).nbytes for v in payload.values())


class Cast16Transform(Transform):
    name = "cast16"

    def encode(self, w, slots):
        return {"values16": self.serve_values(w, slots).astype(np.float16)}

    @staticmethod
    def decode(payload):
        return payload["values16"].astype(np.float32)


class Int8Transform(Transform):
    """Row-wise absmax int8: 4x bandwidth reduction on the push stage —
    the CPU mirror of kernels/delta_codec.py."""

    name = "int8"

    def encode(self, w, slots):
        v = self.serve_values(w, slots).astype(np.float32)
        scale = np.abs(v).max(axis=-1, keepdims=True) / 127.0
        scale = np.maximum(scale, 1e-12)
        q = np.clip(np.rint(v / scale), -127, 127).astype(np.int8)
        return {"q": q, "scale": scale.astype(np.float32)}

    @staticmethod
    def decode(payload):
        return payload["q"].astype(np.float32) * payload["scale"]


_TRANSFORMS: dict[str, type[Transform]] = {
    t.name: t for t in (Transform, Cast16Transform, Int8Transform)
}


def make_transform(codec: str, optimizer: Optional[Optimizer] = None
                   ) -> Transform:
    """codec in {identity, cast16, int8}. If the optimizer has serve-slot
    semantics (FTRL), ``serve_values`` derives w from them automatically."""
    cls = _TRANSFORMS[codec]
    needs_opt = optimizer is not None and (
        isinstance(optimizer, FTRL) or optimizer.serve_slot_names)
    return cls(optimizer if needs_opt else None)


def decode_record(record) -> np.ndarray:
    codec = record.meta.get("codec", "identity")
    return _TRANSFORMS[codec].decode(record.payload)
