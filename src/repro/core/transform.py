"""Model transformation: train-state → serve-state (paper §4.1.4b).

The master's rows are (w, optimizer slots); the slave needs only inference
weights, possibly re-encoded. A ``Transform`` pairs an ``encode`` (runs on
the pusher, master side) with a ``decode`` (runs on the scatter, slave
side). Encodings are *plain data* (numpy arrays / bytes) so they survive
the queue; the codec is named in the record's metadata and resolved from
this registry on the consuming side.

Codecs:
  * identity    — serve weights as-is (fp32)
  * cast16      — fp16 cast (half bandwidth)
  * int8        — row-wise absmax int8 quantization (the Pallas
                  ``delta_codec`` kernel is the TPU version of this path)
  * ftrl        — the heterogeneous-parameter case: encode reads slots
                  (z, n) and ships the *derived* w

Backends — mirroring the PS row engine's ``numpy|pallas`` switch:
  * ``numpy``   — CPU reference codecs (the fast path on CPU-only hosts);
  * ``pallas``  — the int8 path routes through the ``delta_codec`` Pallas
    kernel (``kernels.ops.quantize_rows``/``dequantize_rows``): interpret
    mode off-TPU (bit-matching the reference), Mosaic-compiled on TPU.
    Codecs without a kernel (identity, cast16) keep running the numpy
    engine end-to-end (``kernel_backed`` gates the routing) — never an
    error, and never a silent regression to eager-jnp — so cluster
    configs can flip one flag for the whole sync plane.

``encode`` is backend-routed per *instance* (the pusher owns a configured
``Transform``); ``decode`` is backend-routed per *call* (the scatter
resolves the codec class from record metadata and passes its own
backend), so producer and consumer backends are independent — exactly the
paper's heterogeneous training/serving cluster split.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.optim import FTRL, Optimizer

CODEC_BACKENDS = ("numpy", "pallas")

# Encode tile height on the numpy backend. A 65k-row flush at dim 64 is
# ~16 MB per array; the serve+codec arithmetic is many elementwise passes,
# so untiled it is DRAM-bandwidth-bound. 8k-row tiles (~2 MB) keep every
# pass in L2 — the same effect that made the pre-refactor per-chunk loop
# deceptively fast, kept here without its per-chunk dispatch overhead.
_ENCODE_BLOCK = 8192


class Transform:
    name: str = "identity"
    kernel_backed: bool = False     # has a Pallas codec kernel

    def __init__(self, optimizer: Optional[Optimizer] = None,
                 backend: str = "numpy"):
        assert backend in CODEC_BACKENDS, \
            f"backend must be one of {CODEC_BACKENDS}"
        self.optimizer = optimizer
        self.backend = backend

    @property
    def _device_path(self) -> bool:
        """True when encode should run on-device: backend=pallas AND this
        codec actually has a kernel. Kernel-less codecs stay on the numpy
        engine (CPU-native serve + cache blocking) regardless of the
        backend flag."""
        return self.backend == "pallas" and self.kernel_backed

    @property
    def requires_w(self) -> bool:
        """Whether encode reads the stored weights. With an optimizer
        attached, serve weights are derived from ``serve_slot_names``
        alone (the heterogeneous-parameter contract: the param argument
        supplies dtype/shape only), so the pusher can skip gathering w."""
        return self.optimizer is None

    @property
    def required_slots(self) -> tuple:
        """Slot columns encode reads — () for plain weight codecs."""
        return self.optimizer.serve_slot_names if self.optimizer else ()

    def _iter_serve(self, w: np.ndarray, slots: dict):
        """Yield (lo, hi, serve_values(block)) over cache-sized row tiles.
        Single block on the pallas backend (the device kernel wants the
        whole array), for small inputs, and when slot arrays are not
        row-aligned with ``w`` (the dense-tensor encode path)."""
        n = w.shape[0]
        if (self._device_path or n <= _ENCODE_BLOCK
                or any(np.asarray(v).shape[:1] != (n,)
                       for v in slots.values())):
            yield 0, n, self.serve_values(w, slots)
            return
        for lo in range(0, n, _ENCODE_BLOCK):
            hi = min(lo + _ENCODE_BLOCK, n)
            yield lo, hi, self.serve_values(
                w[lo:hi], {k: v[lo:hi] for k, v in slots.items()})

    def _assemble(self, w: np.ndarray, slots: dict, finalize) -> dict:
        """Shared blocked-encode skeleton: run ``finalize`` (the codec's
        per-block serve-values → payload-arrays step) over the serve
        tiles and assemble full payload arrays. Single-block inputs
        return the finalized block directly (no extra copy)."""
        n, out = w.shape[0], None
        for lo, hi, v in self._iter_serve(w, slots):
            part = finalize(v)
            if lo == 0 and hi == n:
                return part
            if out is None:
                out = {k: np.empty((n,) + a.shape[1:], a.dtype)
                       for k, a in part.items()}
            for k, a in part.items():
                out[k][lo:hi] = a
        return out

    def serve_values(self, w: np.ndarray, slots: dict) -> np.ndarray:
        """Derive inference weights from master state. Always host-side
        (``serve_weights_np`` — no per-flush jnp round trip): the backend
        switch covers the *codec* kernel only, so decoded weights stay
        bit-identical across backends (eager-jnp FTRL derivation differs
        from the numpy mirror by 1 ulp on some elements, which would leak
        through the quantizer)."""
        if self.optimizer is not None:
            return self.optimizer.serve_weights_np(w, slots)
        return w

    def encode(self, w: np.ndarray, slots: dict) -> dict:
        # copy=False: serve_values output is already private (gathered rows
        # are take-copies; derived weights are fresh arrays) — dense-path
        # callers copy before encode (see Pusher._push_dense)
        if self.optimizer is None:               # pure pass-through
            return {"values": w.astype(np.float32, copy=False)}
        return self._assemble(
            w, slots,
            lambda v: {"values": v.astype(np.float32, copy=False)})

    @staticmethod
    def decode(payload: dict, backend: str = "numpy") -> np.ndarray:
        return payload["values"]

    def payload_bytes(self, payload: dict) -> int:
        return sum(np.asarray(v).nbytes for v in payload.values())


class Cast16Transform(Transform):
    name = "cast16"

    def encode(self, w, slots):
        return self._assemble(
            w, slots, lambda v: {"values16": v.astype(np.float16)})

    @staticmethod
    def decode(payload, backend: str = "numpy"):
        return payload["values16"].astype(np.float32)


class Int8Transform(Transform):
    """Row-wise absmax int8: 4x bandwidth reduction on the push stage.
    ``backend="pallas"`` runs the actual ``kernels/delta_codec.py`` kernel;
    ``numpy`` is its CPU mirror (bit-compatible by construction — the
    kernel body is the same arithmetic)."""

    name = "int8"
    kernel_backed = True

    @staticmethod
    def _quantize_np(v: np.ndarray) -> dict:
        v = v.astype(np.float32, copy=False)
        # reciprocal multiply, matching the kernel (see delta_codec)
        s = np.maximum(np.abs(v).max(axis=-1, keepdims=True)
                       * np.float32(1.0 / 127.0), 1e-12)
        q = np.clip(np.rint(v / s), -127, 127).astype(np.int8)
        return {"q": q, "scale": s.astype(np.float32, copy=False)}

    def encode(self, w, slots):
        # guard on row count, not w.size: with an optimizer attached the
        # pusher passes a (n, 0) w placeholder (columns come from slots)
        if self._device_path and len(w):
            from repro.kernels import ops
            v = self.serve_values(w, slots).astype(np.float32, copy=False)
            q, scale = ops.quantize_rows(v)
            return {"q": np.asarray(q), "scale": np.asarray(scale)}
        return self._assemble(w, slots, self._quantize_np)

    @staticmethod
    def decode(payload, backend: str = "numpy"):
        q = payload["q"]
        if backend == "pallas" and q.size:
            from repro.kernels import ops
            return np.asarray(ops.dequantize_rows(q, payload["scale"]))
        return q.astype(np.float32) * payload["scale"]


_TRANSFORMS: dict[str, type[Transform]] = {
    t.name: t for t in (Transform, Cast16Transform, Int8Transform)
}


def make_transform(codec: str, optimizer: Optional[Optimizer] = None,
                   backend: str = "numpy") -> Transform:
    """codec in {identity, cast16, int8}. If the optimizer has serve-slot
    semantics (FTRL), ``serve_values`` derives w from them automatically.
    ``backend`` selects the codec engine (see module docstring)."""
    cls = _TRANSFORMS[codec]
    needs_opt = optimizer is not None and (
        isinstance(optimizer, FTRL) or optimizer.serve_slot_names)
    return cls(optimizer if needs_opt else None, backend=backend)


def decode_record(record, backend: str = "numpy") -> np.ndarray:
    """Consumer-side decode: codec resolved from ``record.meta["codec"]``
    (defaulting to identity for pre-codec records), backend chosen by the
    *consumer* — producer and consumer backends are independent."""
    codec = record.meta.get("codec", "identity")
    return _TRANSFORMS[codec].decode(record.payload, backend=backend)
