from repro.data.joiner import (ExposureEvent, FeedbackEvent, JoinedBatch,
                               JoinedSample, SampleJoiner)
from repro.data.streams import ClickStream, EventBatch, lm_batches

__all__ = ["ExposureEvent", "FeedbackEvent", "JoinedBatch", "JoinedSample",
           "SampleJoiner", "ClickStream", "EventBatch", "lm_batches"]
