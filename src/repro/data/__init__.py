from repro.data.joiner import ExposureEvent, FeedbackEvent, SampleJoiner
from repro.data.streams import ClickStream, lm_batches

__all__ = ["ExposureEvent", "FeedbackEvent", "SampleJoiner", "ClickStream",
           "lm_batches"]
