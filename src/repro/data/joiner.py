"""Real-time multi-stream sample joining (paper §1.1a / §1.2: the Flink
stage). Exposure events (impressions, carrying feature IDs) wait in a time
window for matching feedback events (clicks); on window expiry the joined
labeled sample is emitted — positive if feedback arrived, negative
otherwise. The window length is the paper's model-effect vs. timeliness
trade-off, swept by the data benchmark.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class ExposureEvent:
    t: float
    view_id: int
    feature_ids: tuple[int, ...]


@dataclass(frozen=True)
class FeedbackEvent:
    t: float
    view_id: int
    label: float = 1.0


@dataclass
class JoinedSample:
    t_emit: float
    view_id: int
    feature_ids: np.ndarray
    label: float
    join_delay: float      # emit time - exposure time (timeliness metric)


class SampleJoiner:
    """Event-time window join over exposure + feedback streams."""

    def __init__(self, window: float = 30.0):
        self.window = window
        self._pending: dict[int, ExposureEvent] = {}
        self._labels: dict[int, float] = {}
        self._expiry: list[tuple[float, int]] = []    # heap (deadline, view)
        self.late_feedback = 0                        # feedback after emit
        self.emitted = 0

    def offer_exposure(self, ev: ExposureEvent) -> None:
        self._pending[ev.view_id] = ev
        heapq.heappush(self._expiry, (ev.t + self.window, ev.view_id))

    def offer_feedback(self, ev: FeedbackEvent) -> None:
        if ev.view_id in self._pending:
            self._labels[ev.view_id] = ev.label
        else:
            self.late_feedback += 1

    def drain(self, now: float) -> list[JoinedSample]:
        """Emit every exposure whose window has closed."""
        out: list[JoinedSample] = []
        while self._expiry and self._expiry[0][0] <= now:
            deadline, vid = heapq.heappop(self._expiry)
            ev = self._pending.pop(vid, None)
            if ev is None:
                continue
            label = self._labels.pop(vid, 0.0)
            out.append(JoinedSample(
                t_emit=now, view_id=vid,
                feature_ids=np.asarray(ev.feature_ids, dtype=np.int64),
                label=label, join_delay=now - ev.t))
            self.emitted += 1
        return out

    @property
    def in_flight(self) -> int:
        return len(self._pending)
