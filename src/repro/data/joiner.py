"""Real-time multi-stream sample joining (paper §1.1a / §1.2: the Flink
stage). Exposure events (impressions, carrying feature IDs) wait in a time
window for matching feedback events (clicks); on window expiry the joined
labeled sample is emitted — positive if feedback arrived, negative
otherwise. The window length is the paper's model-effect vs. timeliness
trade-off, swept by ``benchmarks/train_path.py``.

The joiner is columnar and vectorized: exposures are offered as whole
batches (ids + feature matrices), expiry entries live in flat arrays that
one argsort sweep drains per ``drain_batch`` call, and the pending store
is an ``IdHashMap`` (view_id → row) over columnar feature/label arrays —
no per-event Python anywhere on the batch path. The seed per-event
dict+heap joiner is kept verbatim in ``benchmarks/train_path.py`` (the
baseline) and as the oracle of the sample-equivalence property suite
(``tests/test_join_props.py``): batch offers must emit the same samples,
labels, and (deadline, view_id) ordering as the per-event loop — stale
re-offer expiry entries included.

Two knobs beyond the seed semantics, both off by default:

* ``emit_on_feedback`` — positives emit the moment their feedback
  arrives instead of waiting for window expiry (Monolith's online-joiner
  fast path; maximum timeliness, negatives still wait the full window).
* ``neg_sample_rate`` — window-expiry negatives are down-sampled to this
  rate and the survivors carry a ``1/rate`` correction weight, so the
  weighted loss downstream stays unbiased (positives always keep
  weight 1).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


def _id_hashmap(capacity: int):
    # deferred: repro.core's package init imports the training plane,
    # which imports this module — a module-level core import here would
    # make `import repro.data` order-dependent (circular)
    from repro.core.hashmap import IdHashMap
    return IdHashMap(capacity)


def _percentile_ring(size: int):
    # deferred for the same circularity reason as _id_hashmap
    from repro.core.monitor import PercentileRing
    return PercentileRing(size)


@dataclass(frozen=True)
class ExposureEvent:
    t: float
    view_id: int
    feature_ids: tuple[int, ...]


@dataclass(frozen=True)
class FeedbackEvent:
    t: float
    view_id: int
    label: float = 1.0


@dataclass
class JoinedSample:
    t_emit: float
    view_id: int
    feature_ids: np.ndarray
    label: float
    join_delay: float      # emit time - exposure time (timeliness metric)
    weight: float = 1.0    # negative-downsampling correction weight


@dataclass
class JoinedBatch:
    """One drain's worth of joined samples, columnar."""

    t_emit: np.ndarray         # (n,) emission times
    view_ids: np.ndarray       # (n,) int64
    feature_ids: np.ndarray    # (n, F) int64
    labels: np.ndarray         # (n,) float32
    join_delay: np.ndarray     # (n,) float32
    weights: np.ndarray        # (n,) float32 downsampling correction

    def __len__(self) -> int:
        return len(self.view_ids)

    def samples(self) -> list[JoinedSample]:
        """Per-event view (compat with the seed joiner's drain())."""
        return [JoinedSample(
            t_emit=float(self.t_emit[i]), view_id=int(self.view_ids[i]),
            feature_ids=self.feature_ids[i].copy(),
            label=float(self.labels[i]),
            join_delay=float(self.join_delay[i]),
            weight=float(self.weights[i]))
            for i in range(len(self))]

    def slice(self, start: int, stop=None) -> "JoinedBatch":
        """Row-range view (numpy slices — no copies)."""
        s = np.s_[start:stop]
        return JoinedBatch(
            t_emit=self.t_emit[s], view_ids=self.view_ids[s],
            feature_ids=self.feature_ids[s], labels=self.labels[s],
            join_delay=self.join_delay[s], weights=self.weights[s])

    @staticmethod
    def empty(fields: int) -> "JoinedBatch":
        z = np.empty(0, np.float64)
        return JoinedBatch(
            t_emit=z, view_ids=np.empty(0, np.int64),
            feature_ids=np.empty((0, fields), np.int64),
            labels=np.empty(0, np.float32),
            join_delay=np.empty(0, np.float32),
            weights=np.empty(0, np.float32))

    @staticmethod
    def concat(batches: list["JoinedBatch"]) -> "JoinedBatch":
        if len(batches) == 1:
            return batches[0]
        return JoinedBatch(
            t_emit=np.concatenate([b.t_emit for b in batches]),
            view_ids=np.concatenate([b.view_ids for b in batches]),
            feature_ids=np.concatenate([b.feature_ids for b in batches]),
            labels=np.concatenate([b.labels for b in batches]),
            join_delay=np.concatenate([b.join_delay for b in batches]),
            weights=np.concatenate([b.weights for b in batches]))


_DELAY_RING = 1 << 14      # recent join delays kept for percentile metrics


class SampleJoiner:
    """Event-time window join over exposure + feedback streams, columnar.

    Expiry entries are append-only flat arrays (one per ``offer``), drained
    by a single mask + lexsort sweep — the vectorized equivalent of the
    seed's per-event heap, including its re-offer semantics: an entry from
    a previous offer of the same view_id stays live, so a re-offered
    exposure can emit at the earlier offer's deadline (exactly what the
    heap did)."""

    def __init__(self, window: float = 30.0, *,
                 emit_on_feedback: bool = False,
                 neg_sample_rate: float = 1.0,
                 seed: int = 0):
        assert 0.0 < neg_sample_rate <= 1.0
        self.window = window
        self.emit_on_feedback = emit_on_feedback
        self.neg_sample_rate = neg_sample_rate
        self._rng = np.random.default_rng(seed)
        # pending rows (columnar; _map: view_id -> row index)
        self._map = _id_hashmap(1024)
        cap = 1024
        self._vid = np.empty(cap, np.int64)
        self._t = np.empty(cap, np.float64)
        self._label = np.zeros(cap, np.float32)
        self._feat: Optional[np.ndarray] = None     # (cap, F), F from 1st offer
        self._live = np.zeros(cap, bool)
        self._rows = 0                 # high-water mark of the row arena
        self._dead = 0                 # rows freed by emit (compaction debt)
        # expiry entries: (deadline, view_id) per offer, append-only
        ecap = 2048
        self._ed = np.empty(ecap, np.float64)
        self._ev = np.empty(ecap, np.int64)
        self._ne = 0
        # counters (surfaced via metrics() → cluster sync_metrics)
        self.late_feedback = 0                        # feedback after emit
        self.emitted = 0
        self.fast_emits = 0            # emit-on-feedback fast-path samples
        self.negatives_dropped = 0     # shed by neg_sample_rate
        # recent join delays for percentile metrics — the shared ring the
        # serving scheduler and sync staleness meter also use
        self._delays = _percentile_ring(_DELAY_RING)

    # ------------------------------------------------------------------
    # storage
    # ------------------------------------------------------------------
    def _grow_rows(self, need: int, fields: int) -> None:
        cap = len(self._vid)
        if self._feat is None:
            self._feat = np.empty((cap, fields), np.int64)
        if need <= cap:
            return
        new_cap = max(need, cap * 2)

        def grow(a):
            out = np.empty((new_cap,) + a.shape[1:], a.dtype)
            out[:cap] = a
            return out

        self._vid = grow(self._vid)
        self._t = grow(self._t)
        self._feat = grow(self._feat)
        lbl = np.zeros(new_cap, np.float32)
        lbl[:cap] = self._label
        self._label = lbl
        live = np.zeros(new_cap, bool)
        live[:cap] = self._live
        self._live = live

    def _compact_rows(self) -> None:
        """Reclaim emitted rows once more than half the arena is dead —
        amortized O(1) per emitted sample."""
        keep = np.flatnonzero(self._live[:self._rows])
        n = len(keep)
        self._vid[:n] = self._vid[keep]
        self._t[:n] = self._t[keep]
        self._feat[:n] = self._feat[keep]
        self._label[:n] = self._label[keep]
        self._live[:n] = True
        self._live[n:self._rows] = False
        self._rows, self._dead = n, 0
        self._map = _id_hashmap(max(16, n * 4))
        if n:
            self._map.insert(self._vid[:n], np.arange(n, dtype=np.int64))

    def _append_entries(self, deadlines: np.ndarray,
                        vids: np.ndarray) -> None:
        n = len(vids)
        if self._ne + n > len(self._ed):
            new_cap = max(self._ne + n, len(self._ed) * 2)
            ed = np.empty(new_cap, np.float64)
            ev = np.empty(new_cap, np.int64)
            ed[:self._ne] = self._ed[:self._ne]
            ev[:self._ne] = self._ev[:self._ne]
            self._ed, self._ev = ed, ev
        self._ed[self._ne:self._ne + n] = deadlines
        self._ev[self._ne:self._ne + n] = vids
        self._ne += n

    # ------------------------------------------------------------------
    # batch API (the hot path)
    # ------------------------------------------------------------------
    def offer_exposures(self, t, view_ids: np.ndarray,
                        feature_ids: np.ndarray) -> None:
        """Offer a batch of exposures at time(s) ``t`` (scalar or (n,)).
        Later occurrences of a duplicate view_id (within the batch or
        across offers) overwrite the pending features/time — the seed's
        dict semantics — while every offer's expiry entry stays live."""
        view_ids = np.asarray(view_ids, np.int64)
        feature_ids = np.asarray(feature_ids, np.int64)
        n = len(view_ids)
        if n == 0:
            return
        ts = np.broadcast_to(np.asarray(t, np.float64), (n,))
        if self._feat is not None and feature_ids.shape[1] != \
                self._feat.shape[1]:
            raise ValueError("feature_ids width changed mid-stream")
        self._append_entries(ts + self.window, view_ids)

        # strictly monotonic vids (the streaming common case: view ids
        # are assigned sequentially) are unique without the O(n log n)
        # sort a full np.unique dup-check would pay
        if n > 1:
            d = np.diff(view_ids)
            maybe_dup = not ((d > 0).all() or (d < 0).all())
        else:
            maybe_dup = False
        if maybe_dup and len(np.unique(view_ids)) != n:
            # in-batch duplicates: sequential semantics = keep only the
            # LAST occurrence of each vid for the pending store (entries
            # above already cover every offer)
            _, first_of_last = np.unique(view_ids[::-1], return_index=True)
            last = np.zeros(n, bool)
            last[n - 1 - first_of_last] = True
            view_ids, ts = view_ids[last], ts[last]
            feature_ids = feature_ids[last]
            n = len(view_ids)

        sl, have = self._map.lookup_mask(view_ids)
        if have.any():
            rows = sl[have]
            self._t[rows] = ts[have]
            self._feat[rows] = feature_ids[have]
            # label survives a re-offer of a LIVE row (seed keeps its
            # labels dict untouched on duplicate offer_exposure)
        miss = ~have
        k = int(miss.sum())
        if k:
            self._grow_rows(self._rows + k, feature_ids.shape[1])
            rows = np.arange(self._rows, self._rows + k)
            self._rows += k
            self._vid[rows] = view_ids[miss]
            self._t[rows] = ts[miss]
            self._feat[rows] = feature_ids[miss]
            self._label[rows] = 0.0
            self._live[rows] = True
            # absent-by-probe above: skip put()'s second existence probe
            self._map.insert(view_ids[miss], rows)

    def offer_feedbacks(self, ts, view_ids: np.ndarray,
                        labels=None) -> Optional[JoinedBatch]:
        """Offer a batch of feedback events. Unmatched feedback counts as
        ``late_feedback`` (the view was already emitted — or never seen).
        With ``emit_on_feedback``, matched positives emit immediately and
        the returned ``JoinedBatch`` carries them (else ``None``)."""
        view_ids = np.asarray(view_ids, np.int64)
        n = len(view_ids)
        if n == 0:
            return None
        ts = np.broadcast_to(np.asarray(ts, np.float64), (n,))
        lbl = np.ones(n, np.float32) if labels is None else \
            np.broadcast_to(np.asarray(labels, np.float32), (n,))

        if self.emit_on_feedback:
            return self._feedback_fast_path(ts, view_ids, lbl)

        sl = self._map.lookup(view_ids)
        have = sl >= 0
        self.late_feedback += int((~have).sum())
        if have.any():
            # later duplicates win (sequential semantics): write in offer
            # order — np.unique keeps the LAST occurrence per row index
            rows, vals = sl[have], lbl[have]
            uniq_rows, last_idx = np.unique(rows[::-1], return_index=True)
            self._label[uniq_rows] = vals[::-1][last_idx]
        return None

    def _feedback_fast_path(self, ts, view_ids, lbl) -> Optional[JoinedBatch]:
        """Matched positive feedback emits NOW; only the first feedback
        per pending view emits (later ones find the row gone → late)."""
        sl = self._map.lookup(view_ids)
        have = sl >= 0
        if have.any():
            rows, vals, fts = sl[have], lbl[have], ts[have]
            # first feedback per row wins the emission
            uniq_rows, first_idx = np.unique(rows, return_index=True)
            dup = len(rows) - len(uniq_rows)
            self.late_feedback += int((~have).sum()) + dup
            rows, vals, fts = uniq_rows, vals[first_idx], fts[first_idx]
            pos = vals > 0
            if (~pos).any():        # negative feedback just labels the row
                self._label[rows[~pos]] = vals[~pos]
            rows, vals, fts = rows[pos], vals[pos], fts[pos]
            if len(rows):
                batch = self._emit_rows(rows, fts, vals,
                                        np.ones(len(rows), np.float32))
                self.fast_emits += len(rows)
                return batch
            return None
        self.late_feedback += len(view_ids)
        return None

    def drain_batch(self, now: float) -> JoinedBatch:
        """Emit every exposure whose window has closed, ordered by
        (deadline, view_id) — the seed heap's pop order. One mask over the
        entry arrays + one lexsort; window-expiry negatives go through the
        downsampler."""
        ne = self._ne
        if ne == 0 or not (self._ed[:ne] <= now).any():
            return JoinedBatch.empty(self._fields)
        expired = self._ed[:ne] <= now
        exp_d, exp_v = self._ed[:ne][expired], self._ev[:ne][expired]
        keep = ~expired
        k = int(keep.sum())
        self._ed[:k] = self._ed[:ne][keep]
        self._ev[:k] = self._ev[:ne][keep]
        self._ne = k

        # seed heap order: sort expired entries by (deadline, view_id);
        # the FIRST entry per still-pending vid emits, the rest skip
        order = np.lexsort((exp_v, exp_d))
        exp_v = exp_v[order]
        uniq_v, first = np.unique(exp_v, return_index=True)
        sl = self._map.lookup(uniq_v)
        live = sl >= 0
        if not live.any():
            return JoinedBatch.empty(self._fields)
        # emission order across vids = order of their first expired entry
        emit_order = np.argsort(first[live], kind="stable")
        rows = sl[live][emit_order]
        n = len(rows)
        t_emit = np.full(n, now, np.float64)
        labels = self._label[rows].copy()
        weights = np.ones(n, np.float32)
        if self.neg_sample_rate < 1.0:
            neg = labels <= 0
            drop = neg & (self._rng.random(n) >= self.neg_sample_rate)
            self.negatives_dropped += int(drop.sum())
            weights = np.where(neg, np.float32(1.0 / self.neg_sample_rate),
                               np.float32(1.0))
            sel = ~drop
            # dropped rows leave the pending store too (they expired) —
            # released TOGETHER with the emitted rows below: a partial
            # release here could trigger compaction and invalidate the
            # arena indices still held in ``rows``
            return self._emit_rows(rows[sel], t_emit[sel], labels[sel],
                                   weights[sel], release=rows)
        return self._emit_rows(rows, t_emit, labels, weights)

    def _emit_rows(self, rows: np.ndarray, t_emit: np.ndarray,
                   labels: np.ndarray, weights: np.ndarray,
                   release: Optional[np.ndarray] = None) -> JoinedBatch:
        """Copy out the emitted rows, then release ``release`` (defaults
        to ``rows``) in ONE pass — releasing may compact the arena, so
        every index consumer must run before it."""
        delay = (t_emit - self._t[rows]).astype(np.float32)
        batch = JoinedBatch(
            t_emit=np.asarray(t_emit, np.float64),
            view_ids=self._vid[rows].copy(),
            feature_ids=self._feat[rows].copy(),
            labels=np.asarray(labels, np.float32),
            join_delay=delay,
            weights=np.asarray(weights, np.float32))
        self.emitted += len(rows)
        self._record_delays(delay)
        self._release_rows(rows if release is None else release)
        return batch

    def _release_rows(self, rows: np.ndarray) -> None:
        if not len(rows):
            return
        self._map.delete(self._vid[rows])
        self._live[rows] = False
        self._dead += len(rows)
        if self._dead * 2 > self._rows:
            self._compact_rows()

    def _record_delays(self, delays: np.ndarray) -> None:
        self._delays.record(delays)

    # ------------------------------------------------------------------
    # per-event API (seed-compatible wrappers)
    # ------------------------------------------------------------------
    def offer_exposure(self, ev: ExposureEvent) -> None:
        self.offer_exposures(
            ev.t, np.array([ev.view_id], np.int64),
            np.asarray(ev.feature_ids, np.int64).reshape(1, -1))

    def offer_feedback(self, ev: FeedbackEvent) -> Optional[JoinedBatch]:
        return self.offer_feedbacks(
            ev.t, np.array([ev.view_id], np.int64),
            np.array([ev.label], np.float32))

    def drain(self, now: float) -> list[JoinedSample]:
        return self.drain_batch(now).samples()

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    @property
    def _fields(self) -> int:
        return self._feat.shape[1] if self._feat is not None else 0

    @property
    def in_flight(self) -> int:
        return len(self._map)

    def join_delay_percentiles(self, qs=(50, 99)) -> dict[str, float]:
        return self._delays.percentiles(qs)

    def metrics(self) -> dict:
        return {
            "emitted": self.emitted,
            "in_flight": self.in_flight,
            "late_feedback": self.late_feedback,
            "fast_emits": self.fast_emits,
            "negatives_dropped": self.negatives_dropped,
            "join_delay": self.join_delay_percentiles(),
        }

    def register_metrics(self, reg, prefix: str = "joiner") -> None:
        """Publish the joiner counters into a
        ``repro.obs.metrics.MetricsRegistry`` (same keys as
        ``metrics()``, under ``prefix``)."""
        reg.register(prefix, self.metrics)
