"""Synthetic stream generators.

ClickStream drives the online-learning path: Zipfian feature IDs (the
skew behind the paper's >=90 % update-repetition observation), a drifting
logistic ground truth (so domino-downgrade triggers are testable by
injecting distribution shifts), and exposure->feedback delays for the
joiner. ``lm_batches`` packs token streams for LM training examples.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

from repro.data.joiner import ExposureEvent, FeedbackEvent


@dataclass
class EventBatch:
    """One tick's worth of columnar stream events: every exposure at time
    ``t`` plus the (delayed) feedback rows its positives will produce —
    the unit ``TrainPipeline.ingest`` consumes."""

    t: float
    view_ids: np.ndarray       # (n,) int64
    feature_ids: np.ndarray    # (n, F) int64
    labels: np.ndarray         # (n,) ground-truth labels (for evaluation)
    fb_view_ids: np.ndarray    # (k,) positives' view ids
    fb_t: np.ndarray           # (k,) feedback arrival times

    def __len__(self) -> int:
        return len(self.view_ids)


@dataclass
class ClickStream:
    feature_space: int = 1 << 16
    fields: int = 16
    zipf_a: float = 1.3
    feedback_delay: float = 5.0
    drift_scale: float = 0.0          # ground-truth drift per emitted batch
    signal_scale: float = 0.4         # |true_w| magnitude (task separability)
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        self._true_w = self.rng.normal(
            size=self.feature_space) * self.signal_scale
        self._view = 0

    def corrupt(self, scale: float = 3.0) -> None:
        """Adversarial distribution shift: the ground truth flips sign (and
        sharpens), so everything the model has learned predicts confidently
        *wrong* — the metric collapse the domino downgrade must catch."""
        self._true_w = -self._true_w * scale

    def features(self, n: int) -> np.ndarray:
        ids = self.rng.zipf(self.zipf_a, size=(n, self.fields))
        return (ids % self.feature_space).astype(np.int64)

    def labels(self, ids: np.ndarray) -> np.ndarray:
        logits = self._true_w[ids].sum(axis=1)
        return (self.rng.random(len(ids)) <
                1.0 / (1.0 + np.exp(-logits))).astype(np.float32)

    def batch(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        if self.drift_scale:
            self._true_w += self.rng.normal(
                size=self.feature_space) * self.drift_scale
        ids = self.features(n)
        return ids, self.labels(ids)

    def events_batch(self, n: int, t: float) -> "EventBatch":
        """Columnar exposure + feedback events at time ``t`` — the
        vectorized joiner's native input (``SampleJoiner.offer_exposures``
        / ``offer_feedbacks``). Feedback rows exist only for positives,
        delayed by an exponential draw (the exposure→feedback gap the
        join window must cover)."""
        ids, y = self.batch(n)
        vids = np.arange(self._view, self._view + n, dtype=np.int64)
        self._view += n
        pos = np.flatnonzero(y > 0)
        delays = self.rng.exponential(self.feedback_delay, size=len(pos))
        return EventBatch(t=t, view_ids=vids, feature_ids=ids, labels=y,
                          fb_view_ids=vids[pos], fb_t=t + delays)

    def events(self, n: int, t: float) -> tuple[list[ExposureEvent],
                                                list[FeedbackEvent]]:
        """Per-event view of ``events_batch`` (legacy object API)."""
        b = self.events_batch(n, t)
        exposures = [ExposureEvent(t=t, view_id=int(v),
                                   feature_ids=tuple(f.tolist()))
                     for v, f in zip(b.view_ids, b.feature_ids)]
        feedbacks = [FeedbackEvent(t=float(ft), view_id=int(v))
                     for v, ft in zip(b.fb_view_ids, b.fb_t)]
        return exposures, feedbacks


def lm_batches(vocab_size: int, batch: int, seq_len: int, *,
               seed: int = 0, structured: bool = True) -> Iterator[np.ndarray]:
    """Endless packed token batches. ``structured`` mixes a Markov-ish
    bigram pattern into the stream so training loss visibly decreases."""
    rng = np.random.default_rng(seed)
    if structured:
        # sparse bigram table: each token has a few likely successors
        succ = rng.integers(0, vocab_size, size=(vocab_size, 4))
    while True:
        if structured:
            out = np.empty((batch, seq_len), dtype=np.int32)
            tok = rng.integers(0, vocab_size, size=batch)
            for t in range(seq_len):
                out[:, t] = tok
                follow = succ[tok, rng.integers(0, 4, size=batch)]
                rand = rng.integers(0, vocab_size, size=batch)
                use_follow = rng.random(batch) < 0.8
                tok = np.where(use_follow, follow, rand)
            yield out
        else:
            yield rng.integers(0, vocab_size, size=(batch, seq_len),
                               dtype=np.int32)
