"""Flash-decode: single-token GQA attention against a long KV cache — the
latency-critical slave/serving path (decode_32k / long_500k shapes).

Grid (batch, kv_head, kv_block), kv_block innermost; all m query heads of
one KV group ride in a single (m, d) VMEM tile, so the kernel is one
(m x d) x (d x block_k) matmul + online-softmax per block — the TPU
adaptation of GPU flash-decode (no warp reductions; the sequential grid
revisit IS the reduction). Valid-length masking handles ragged caches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int):
    b = pl.program_id(0)
    kb = pl.program_id(2)
    n_kb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(kb * block_k < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (m, d)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, :, 0].astype(jnp.float32)               # (bk, d)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kb == n_kb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, block_k: int = 512,
                     interpret: bool = False):
    """q (B, H, D) one token per sequence; k, v (B, S, G, D); lengths (B,)
    valid prefix lengths. Returns (B, H, D). S % block_k == 0."""
    b, h, d = q.shape
    s, g = k.shape[1], k.shape[2]
    assert h % g == 0 and s % block_k == 0
    m = h // g
    qg = q.reshape(b, g, m, d)
    grid = (b, g, s // block_k)
    kernel = functools.partial(_decode_kernel, scale=d ** -0.5,
                               block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, m, d),
                             lambda b_, g_, kb, len_ref: (b_, g_, 0, 0)),
                pl.BlockSpec((1, block_k, 1, d),
                             lambda b_, g_, kb, len_ref: (b_, kb, g_, 0)),
                pl.BlockSpec((1, block_k, 1, d),
                             lambda b_, g_, kb, len_ref: (b_, kb, g_, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, m, d),
                                   lambda b_, g_, kb, len_ref:
                                   (b_, g_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((m, 1), jnp.float32),
                pltpu.VMEM((m, 1), jnp.float32),
                pltpu.VMEM((m, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, g, m, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(b, h, d)
