"""Row-wise absmax int8 delta codec — the on-device serialize+compress
stage of the WeiPS pusher (paper §4.1.3), 4x wire-bandwidth reduction.

Quantize: one VMEM pass computes the per-row absmax scale and the int8
payload; dequantize is the scatter-side inverse. Row blocks of
(block_rows, D) keep the reduction in-register (D is last-dim/lane-major).

Two consumers share this kernel (both through ``kernels/ops.py``, with a
bit-identical numpy mirror in ``core/transform.py``): the streaming sync
codec (``Int8Transform``) and the checkpoint compressor
(``BackupPolicy.compress="int8"`` in ``core/fault_tolerance.py``), which
packs full/delta checkpoint row payloads with the same arithmetic so
compressed chain restores stay bit-equal to compressed full restores.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    # explicit f32-reciprocal multiply, not /127.0: XLA folds constant
    # divisions into reciprocal multiplies anyway, and writing it out
    # keeps kernel, ref.py oracle, and the transform's numpy mirror
    # bit-identical
    scale = jnp.maximum(jnp.abs(x).max(axis=-1, keepdims=True)
                        * (1.0 / 127.0), 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_rows(x: jax.Array, *, block_rows: int = 256,
                  interpret: bool = False):
    """x (B, D) -> (q int8 (B, D), scale f32 (B, 1))."""
    b, d = x.shape
    block_rows = min(block_rows, b)
    grid = (pl.cdiv(b, block_rows),)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, d), jnp.int8),
                   jax.ShapeDtypeStruct((b, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def dequantize_rows(q: jax.Array, scale: jax.Array, *,
                    block_rows: int = 256, interpret: bool = False):
    """(q int8 (B, D), scale (B, 1)) -> x f32 (B, D)."""
    b, d = q.shape
    block_rows = min(block_rows, b)
    grid = (pl.cdiv(b, block_rows),)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(q, scale)
