"""Pallas TPU kernels for the PS sparse row paths: embedding row gather
(slave lookup — the latency-critical serving path) and gradient row
scatter-add (master update path).

TPU adaptation: the gather is a scalar-prefetch-driven DMA pipeline — row
IDs are prefetched to SMEM, each grid step's BlockSpec index_map picks the
HBM row block to stream into VMEM. No gather instruction needed; the block
pipeline IS the gather (this is the idiomatic TPU embedding kernel, vs. the
GPU warp-per-row formulation).

Scatter-add relies on the TPU grid being sequential: revisiting the same
output row accumulates without races (on GPU this would need atomics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, table_block, out_block):
    # table_block: (block_rows, D) rows selected by index_map via ids
    out_block[...] = table_block[...]


def embedding_lookup(table: jax.Array, ids: jax.Array, *,
                     interpret: bool = False) -> jax.Array:
    """Batched row gather: ``out[i] = table[ids[i]]``.

    Args:
      table: (V, D) any float dtype — the arena (HBM-resident on TPU).
      ids:   (N,) integer (cast to int32; V must fit int32). Must be
             in-bounds — no clipping or masking happens here; PS callers
             resolve/clip slots first.
    Returns:
      (N, D) rows, same dtype as ``table``. Grid is one step per id; the
      BlockSpec index_map DMA-streams row ``ids[i]`` HBM→VMEM per step
      (scalar-prefetch gather — see module docstring).
    """
    n = ids.shape[0]
    v, d = table.shape
    grid = (n,)
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)


def _scatter_add_kernel(ids_ref, upd_block, table_in, table_out):
    """Requires ids SORTED (wrapper sorts): repeated IDs occupy consecutive
    grid steps, so the out block stays VMEM-resident and `+=` accumulates;
    the first visit of a row initializes it from the aliased table."""
    i = pl.program_id(0)
    prev = ids_ref[jnp.maximum(i - 1, 0)]
    is_first = jnp.logical_or(i == 0, ids_ref[i] != prev)

    @pl.when(is_first)
    def _init():
        table_out[...] = table_in[...] + upd_block[...].astype(
            table_in.dtype)

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        table_out[...] += upd_block[...].astype(table_out.dtype)


def embedding_scatter_add(table: jax.Array, ids: jax.Array,
                          updates: jax.Array, *,
                          interpret: bool = False) -> jax.Array:
    """Row scatter-add: ``table[ids[i]] += updates[i]`` with duplicate ids
    accumulating.

    Args:
      table:   (V, D) — aliased in/out (updated in place on device).
      ids:     (N,) integer, any order (sorted here so duplicates occupy
               consecutive grid steps — see kernel docstring).
      updates: (N, D), cast to ``table.dtype`` on accumulate.
    Returns:
      the (V, D) table with rows accumulated; untouched rows pass through
      the alias unchanged.
    """
    order = jnp.argsort(ids)
    ids = ids[order]
    updates = updates[order]
    n = ids.shape[0]
    v, d = table.shape
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),          # updates
            pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_add_kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((v, d), table.dtype),
        input_output_aliases={2: 0},      # alias table (ids=0, upd=1) -> out
        interpret=interpret,
    )(ids.astype(jnp.int32), updates, table)


def _scatter_set_kernel(ids_ref, upd_block, table_in, table_out):
    del ids_ref, table_in        # aliased table passes untouched rows through
    table_out[...] = upd_block[...].astype(table_out.dtype)


def embedding_scatter(table: jax.Array, ids: jax.Array,
                      updates: jax.Array, *,
                      interpret: bool = False) -> jax.Array:
    """Row scatter-SET: ``table[ids[i]] = updates[i]`` — the write half of
    the fused PS update path (new optimizer rows land back in the arena
    without a host round-trip).

    Args:
      table:   (V, D) — aliased in/out (updated in place on device).
      ids:     (N,) integer, UNIQUE (PS scatter paths dedupe first;
               duplicates would leave whichever grid step ran last, which
               is defined on TPU's sequential grid but not a contract).
      updates: (N, D), cast to ``table.dtype``.
    Returns:
      the (V, D) table with the addressed rows replaced; untouched rows
      pass through the alias unchanged. No sort needed — with unique ids
      every output block is visited at most once.
    """
    n = ids.shape[0]
    v, d = table.shape
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),          # updates
            pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_set_kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((v, d), table.dtype),
        input_output_aliases={2: 0},      # alias table (ids=0, upd=1) -> out
        interpret=interpret,
    )(ids.astype(jnp.int32), updates, table)
