"""Pallas TPU kernels for the PS sparse row paths: embedding row gather
(slave lookup — the latency-critical serving path) and gradient row
scatter-add (master update path).

TPU adaptation: the gather is a scalar-prefetch-driven DMA pipeline — row
IDs are prefetched to SMEM, each grid step's BlockSpec index_map picks the
HBM row block to stream into VMEM. No gather instruction needed; the block
pipeline IS the gather (this is the idiomatic TPU embedding kernel, vs. the
GPU warp-per-row formulation).

Scatter-add relies on the TPU grid being sequential: revisiting the same
output row accumulates without races (on GPU this would need atomics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, table_block, out_block):
    # table_block: (block_rows, D) rows selected by index_map via ids
    out_block[...] = table_block[...]


def embedding_lookup(table: jax.Array, ids: jax.Array, *,
                     interpret: bool = False) -> jax.Array:
    """table (V, D) any float dtype; ids (N,) int32 -> (N, D)."""
    n = ids.shape[0]
    v, d = table.shape
    grid = (n,)
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)


def _scatter_add_kernel(ids_ref, upd_block, table_in, table_out):
    """Requires ids SORTED (wrapper sorts): repeated IDs occupy consecutive
    grid steps, so the out block stays VMEM-resident and `+=` accumulates;
    the first visit of a row initializes it from the aliased table."""
    i = pl.program_id(0)
    prev = ids_ref[jnp.maximum(i - 1, 0)]
    is_first = jnp.logical_or(i == 0, ids_ref[i] != prev)

    @pl.when(is_first)
    def _init():
        table_out[...] = table_in[...] + upd_block[...].astype(
            table_in.dtype)

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        table_out[...] += upd_block[...].astype(table_out.dtype)


def embedding_scatter_add(table: jax.Array, ids: jax.Array,
                          updates: jax.Array, *,
                          interpret: bool = False) -> jax.Array:
    """table (V, D); ids (N,); updates (N, D) -> new table with rows +=.

    The table is aliased in/out (in-place on device). IDs are sorted here
    so repeated IDs land on consecutive grid steps (see kernel docstring).
    """
    order = jnp.argsort(ids)
    ids = ids[order]
    updates = updates[order]
    n = ids.shape[0]
    v, d = table.shape
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),          # updates
            pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_add_kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((v, d), table.dtype),
        input_output_aliases={2: 0},      # alias table (ids=0, upd=1) -> out
        interpret=interpret,
    )(ids.astype(jnp.int32), updates, table)
