"""Blocked online-softmax causal GQA attention (prefill/train plane).

TPU formulation: grid (batch, q_head, q_block, kv_block) with the kv_block
axis innermost — VMEM scratch (m, l, acc) persists across the sequential
kv sweep for one q block (the revisiting-grid pattern, not a GPU
warp-specialized kernel). Causal skipping via pl.when on whole blocks:
strictly-upper blocks do no work, the diagonal block masks elementwise.
Block shapes default to (128, head_dim) — MXU-aligned (multiples of 128 on
the matmul dims, head_dim is lane-major).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    n_kb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qb * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # whole-block causal skip: only run blocks on/below the diagonal
        pl.when((qb + 1) * block_q > kb * block_k)(_compute)
    else:
        _compute()

    @pl.when(kb == n_kb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q (B, H, S, D); k, v (B, G, T, D), H = G·m (GQA). Returns (B,H,S,D).

    S and T must be multiples of the block sizes (callers pad)."""
    b, h, s, d = q.shape
    g, t = k.shape[1], k.shape[2]
    assert h % g == 0 and s % block_q == 0 and t % block_k == 0
    grid = (b, h, s // block_q, t // block_k)
    kernel = functools.partial(_flash_kernel, scale=d ** -0.5,
                               block_q=block_q, block_k=block_k,
                               causal=causal)
    m_per_g = h // g
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qb, kb: (b_, h_, qb, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qb, kb: (b_, h_ // m_per_g, kb, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qb, kb: (b_, h_ // m_per_g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qb, kb: (b_, h_, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
