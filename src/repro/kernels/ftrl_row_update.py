"""Fused FTRL-proximal row update — the paper's flagship optimizer, fused
into one VMEM pass: given gathered rows (z, n) and gradient rows g, emits
(z', n', w') without materializing the ~10 elementwise intermediates XLA
would otherwise stream through HBM.

Layout: rows blocked (block_rows, D); D padded to the 128-lane register
width by the wrapper. All math fp32 (PS slot precision)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _w_from(z, n, *, alpha, beta, l1, l2):
    shrink = jnp.sign(z) * l1 - z
    denom = (beta + jnp.sqrt(n)) / alpha + l2
    return jnp.where(jnp.abs(z) > l1, shrink / denom, 0.0)


def _ftrl_kernel(z_ref, n_ref, g_ref, z_out, n_out, w_out, *,
                 alpha, beta, l1, l2):
    z = z_ref[...]
    n = n_ref[...]
    g = g_ref[...]
    w = _w_from(z, n, alpha=alpha, beta=beta, l1=l1, l2=l2)
    n_new = n + g * g
    sigma = (jnp.sqrt(n_new) - jnp.sqrt(n)) / alpha
    z_new = z + g - sigma * w
    z_out[...] = z_new
    n_out[...] = n_new
    w_out[...] = _w_from(z_new, n_new, alpha=alpha, beta=beta, l1=l1, l2=l2)


def ftrl_row_update(z: jax.Array, n: jax.Array, g: jax.Array, *,
                    alpha: float = 0.05, beta: float = 1.0, l1: float = 1.0,
                    l2: float = 1.0, block_rows: int = 256,
                    interpret: bool = False):
    """z, n, g: (B, D) fp32. Returns (z', n', w') each (B, D) fp32."""
    b, d = z.shape
    block_rows = min(block_rows, b)
    grid = (pl.cdiv(b, block_rows),)
    spec = pl.BlockSpec((block_rows, d), lambda i: (i, 0))
    kernel = functools.partial(_ftrl_kernel, alpha=alpha, beta=beta,
                               l1=l1, l2=l2)
    out = jax.ShapeDtypeStruct((b, d), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[out, out, out],
        interpret=interpret,
    )(z.astype(jnp.float32), n.astype(jnp.float32), g.astype(jnp.float32))
