"""Pallas hashmap-probe kernel — the device-resident half of
``core.hashmap.IdHashMap``.

The PS addressing core resolves minibatches of int64 feature ids to arena
slots with a Fibonacci-hash, windowed open-addressing probe. This kernel
runs the SAME probe against a device-resident copy of the map's slot-id
table, so the sparse hot path (probe → gather → update → scatter) never
bounces ids back to the host between stages. Semantics mirror
``IdHashMap._probe`` bit-for-bit: identical home slots, identical window
walk, identical EMPTY/TOMB handling — the host map stays the oracle (see
``tests/test_kernels.py``).

TPU adaptation — 32-bit limbs: TPUs (and jax without x64) have no native
int64 vector arithmetic, so the wrapper reinterprets both the key table
and the query ids as (lo, hi) uint32 limb pairs (a free ``.view`` on the
host). The Fibonacci multiply-shift needs only the TOP 32 bits of
``id * ⌊2^64/φ⌋ mod 2^64`` (capacities are ≤ 2^32, so the slot index
lives entirely in the upper limb), which a 32×32→hi32 ``mulhi`` plus two
wrapping multiplies reconstructs exactly. Key equality is a two-limb
compare; the sentinels split as EMPTY = (0, 0x80000000) and
TOMB = (1, 0x80000000).

Probe structure (identical to the host map):
  round 1   — one gather at the home slot for the whole batch; hits
              resolve, misses over an EMPTY home resolve as not-found;
  tail      — per round, a ``(m, WINDOW)`` gather of consecutive slots
              for every still-active id; a window hit resolves (first
              hit in the window wins, matching ``argmax`` order on the
              host), a window containing EMPTY resolves as not-found;
              survivors advance WINDOW slots. A ``lax.while_loop``
              carries (cur, pos, found, active) as dense masked vectors —
              no compaction, so shapes stay static for Mosaic.

Memory layout: the key-limb arrays are streamed whole into VMEM per grid
step (BlockSpec over the full table). That bounds the device-resident
map at VMEM capacity (~2M slots at 8 B/slot); beyond that the table
belongs in ANY/HBM memory space with windowed DMA — out of scope here,
noted in docs/KERNELS.md. Grid is over id blocks; slot gathers are
vector ``jnp.take(..., mode="clip")`` like the host path (indices are
in-bounds by construction, clip skips the bounds-check path).

``pos`` is garbage where ``found`` is False — same contract as the host
probe; callers mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_WINDOW = 8                         # must match core.hashmap._WINDOW

# ⌊2^64/φ⌋ split into uint32 limbs (lo, hi). Plain ints: jnp scalars
# created at module scope would be captured as constants by the kernel
# trace, which pallas rejects — materialize them inside the trace.
_FIB_LO = 0x7F4A7C15
_FIB_HI = 0x9E3779B9
_SENT_HI = 0x80000000               # EMPTY/TOMB upper limb


def _mulhi32(a, b):
    """High 32 bits of a 32×32-bit unsigned multiply, from 16-bit limbs
    (uint32 lane arithmetic only — every partial product and carry sum
    stays below 2^32)."""
    a0, a1 = a & jnp.uint32(0xFFFF), a >> jnp.uint32(16)
    b0, b1 = b & jnp.uint32(0xFFFF), b >> jnp.uint32(16)
    t = a1 * b0 + ((a0 * b0) >> jnp.uint32(16))
    t2 = a0 * b1 + (t & jnp.uint32(0xFFFF))
    return a1 * b1 + (t >> jnp.uint32(16)) + (t2 >> jnp.uint32(16))


def fib_home_u32(id_lo, id_hi, *, shift: int):
    """Home slots from uint32 id limbs — bit-equal to
    ``core.hashmap.home_slots`` on the reassembled int64 ids.

    The full product mod 2^64 is ``lo·FIB + ((hi·FIB_lo + lo·FIB_hi)
    << 32)``; slot indices are its bits [shift, 64) with shift ≥ 32, so
    only the upper limb ``mulhi(lo, FIB_lo) + hi·FIB_lo + lo·FIB_hi``
    (wrapping uint32 adds) is ever needed."""
    upper = (_mulhi32(id_lo, jnp.uint32(_FIB_LO))
             + id_hi * jnp.uint32(_FIB_LO) + id_lo * jnp.uint32(_FIB_HI))
    return (upper >> jnp.uint32(shift - 32)).astype(jnp.int32)


def _probe_kernel(klo_ref, khi_ref, ilo_ref, ihi_ref, pos_ref, found_ref, *,
                  shift, imask, max_rounds):
    klo = klo_ref[...]
    khi = khi_ref[...]
    ilo = ilo_ref[...]
    ihi = ihi_ref[...]
    # sentinel-valued queries can never be stored: mask to id 0 and force
    # not-found at the end (mirrors the host probe's `bad` handling)
    bad = (ihi == jnp.uint32(_SENT_HI)) & (ilo <= jnp.uint32(1))
    qlo = jnp.where(bad, jnp.uint32(0), ilo)
    qhi = jnp.where(bad, jnp.uint32(0), ihi)

    home = fib_home_u32(qlo, qhi, shift=shift)
    k_lo = jnp.take(klo, home, mode="clip")
    k_hi = jnp.take(khi, home, mode="clip")
    hit = (k_lo == qlo) & (k_hi == qhi)
    empty_home = (k_hi == jnp.uint32(_SENT_HI)) & (k_lo == jnp.uint32(0))

    win = jnp.arange(_WINDOW, dtype=jnp.int32)

    def round_body(state):
        r, cur, pos, found, active = state
        cand = (cur[:, None] + win[None, :]) & jnp.int32(imask)   # (n, W)
        kwlo = jnp.take(klo, cand, mode="clip")
        kwhi = jnp.take(khi, cand, mode="clip")
        hitw = (kwlo == qlo[:, None]) & (kwhi == qhi[:, None])
        ha = hitw.any(axis=1) & active
        first = jnp.argmax(hitw, axis=1)
        hpos = jnp.take_along_axis(cand, first[:, None], axis=1)[:, 0]
        pos = jnp.where(ha, hpos, pos)
        found = found | ha
        emptyw = ((kwhi == jnp.uint32(_SENT_HI))
                  & (kwlo == jnp.uint32(0))).any(axis=1)
        active = active & ~ha & ~emptyw
        return r + 1, (cur + jnp.int32(_WINDOW)) & jnp.int32(imask), \
            pos, found, active

    def round_cond(state):
        r, _, _, _, active = state
        return jnp.logical_and(r < max_rounds, active.any())

    init = (jnp.int32(0),
            (home + 1) & jnp.int32(imask),            # tail starts past home
            home,                                     # garbage where ~found
            hit,
            ~hit & ~empty_home)
    _, _, pos, found, _ = jax.lax.while_loop(round_cond, round_body, init)
    pos_ref[...] = pos
    found_ref[...] = found & ~bad


def hashmap_probe(keys_lo: jax.Array, keys_hi: jax.Array,
                  ids_lo: jax.Array, ids_hi: jax.Array, *,
                  shift: int, interpret: bool = False):
    """Probe a device-resident slot-id table.

    Args:
      keys_lo, keys_hi: (C,) uint32 — the map's key array as little-endian
        32-bit limbs; C a power of two (``IdHashMap`` capacities always
        are). EMPTY/TOMB sentinels included.
      ids_lo, ids_hi: (N,) uint32 — query ids, same limb split.
      shift: the map's Fibonacci shift (``64 - log2(C)``; 32 ≤ shift ≤ 60).

    Returns:
      (pos (N,) int32, found (N,) bool). ``pos`` is the key's table slot
      where ``found``; garbage otherwise. Bit-equal to
      ``IdHashMap._probe`` on the same state.
    """
    n = ids_lo.shape[0]
    cap = keys_lo.shape[0]
    kernel = functools.partial(
        _probe_kernel, shift=shift, imask=cap - 1,
        max_rounds=cap // _WINDOW + 2)
    kspec = pl.BlockSpec((cap,), lambda i: (0,))
    ispec = pl.BlockSpec((n,), lambda i: (0,))
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[kspec, kspec, ispec, ispec],
        out_specs=[ispec, ispec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)],
        interpret=interpret,
    )(keys_lo, keys_hi, ids_lo, ids_hi)
