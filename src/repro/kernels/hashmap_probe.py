"""Pallas hashmap-probe kernel — the device-resident half of
``core.hashmap.IdHashMap``.

The PS addressing core resolves minibatches of int64 feature ids to arena
slots with a Fibonacci-hash, windowed open-addressing probe. This kernel
runs the SAME probe against a device-resident copy of the map's slot-id
table, so the sparse hot path (probe → gather → update → scatter) never
bounces ids back to the host between stages. Semantics mirror
``IdHashMap._probe`` bit-for-bit: identical home slots, identical window
walk, identical EMPTY/TOMB handling — the host map stays the oracle (see
``tests/test_kernels.py``).

TPU adaptation — 32-bit limbs: TPUs (and jax without x64) have no native
int64 vector arithmetic, so the wrapper reinterprets both the key table
and the query ids as (lo, hi) uint32 limb pairs (a free ``.view`` on the
host). The Fibonacci multiply-shift needs only the TOP 32 bits of
``id * ⌊2^64/φ⌋ mod 2^64`` (capacities are ≤ 2^32, so the slot index
lives entirely in the upper limb), which a 32×32→hi32 ``mulhi`` plus two
wrapping multiplies reconstructs exactly. Key equality is a two-limb
compare; the sentinels split as EMPTY = (0, 0x80000000) and
TOMB = (1, 0x80000000).

Probe structure (identical to the host map):
  round 1   — one gather at the home slot for the whole batch; hits
              resolve, misses over an EMPTY home resolve as not-found;
  tail      — per round, a ``(m, WINDOW)`` gather of consecutive slots
              for every still-active id; a window hit resolves (first
              hit in the window wins, matching ``argmax`` order on the
              host), a window containing EMPTY resolves as not-found;
              survivors advance WINDOW slots. A ``lax.while_loop``
              carries (cur, pos, found, active) as dense masked vectors —
              no compaction, so shapes stay static for Mosaic.

Memory layout — two placements, one contract:

  * ``hashmap_probe`` (VMEM) streams the key-limb arrays whole into VMEM
    per grid step (BlockSpec over the full table). Cheapest for small
    maps, but bounds the device-resident map at VMEM capacity
    (~2M slots at 8 B/slot).
  * ``hashmap_probe_hbm`` keeps the key-limb table in the ``pltpu.ANY``
    memory space (HBM on real hardware) and DMAs fixed-size probe
    windows (``_DMA_WINDOW`` slots per id) into a double-buffered VMEM
    scratch with ``pltpu.make_async_copy`` — the copy for id chunk
    *i+1* is started before chunk *i* is probed, so the DMA latency
    hides behind the probe arithmetic. VMEM then holds only
    ``2 · chunk · window`` slots regardless of table size, so map
    capacity is bounded by HBM, not VMEM. The table is wrap-padded by
    one window (``wrap_pad_limbs``) so a window starting near the top
    never wraps mid-DMA.

``ops.hashmap_probe`` dispatches between them on capacity
(``VMEM_SLOT_BOUND``) unless the caller pins a placement. Both run the
identical probe: slot gathers are vector ``jnp.take(..., mode="clip")``
like the host path (indices are in-bounds by construction, clip skips
the bounds-check path) in the VMEM kernel, and masked window-local
compares in the HBM kernel.

``pos`` is garbage where ``found`` is False — same contract as the host
probe; callers mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_WINDOW = 8                         # must match core.hashmap._WINDOW

# -- HBM/windowed-DMA tuning ------------------------------------------------
# Slots fetched per DMA window. 256 uint32-limb slots = 2 KiB per limb
# array per id — long enough to amortize DMA issue cost and cover the
# overwhelming majority of probe chains (≤25 % load keeps cluster runs
# short) in ONE round, short enough that double-buffering stays tiny.
_DMA_WINDOW = 256
# Ids probed per grid step. 8 ids × 256 slots × 2 limbs × 2 buffers
# = 32 KiB VMEM scratch — the kernel's entire VMEM footprint.
_DMA_CHUNK = 8
# Capacity above which ops.hashmap_probe routes to the HBM kernel: the
# whole-table VMEM kernel needs cap × 8 B of VMEM, which stops fitting
# around 2M slots (16 MiB of VMEM for the key limbs alone).
VMEM_SLOT_BOUND = 1 << 21

# ⌊2^64/φ⌋ split into uint32 limbs (lo, hi). Plain ints: jnp scalars
# created at module scope would be captured as constants by the kernel
# trace, which pallas rejects — materialize them inside the trace.
_FIB_LO = 0x7F4A7C15
_FIB_HI = 0x9E3779B9
_SENT_HI = 0x80000000               # EMPTY/TOMB upper limb


def _mulhi32(a, b):
    """High 32 bits of a 32×32-bit unsigned multiply, from 16-bit limbs
    (uint32 lane arithmetic only — every partial product and carry sum
    stays below 2^32)."""
    a0, a1 = a & jnp.uint32(0xFFFF), a >> jnp.uint32(16)
    b0, b1 = b & jnp.uint32(0xFFFF), b >> jnp.uint32(16)
    t = a1 * b0 + ((a0 * b0) >> jnp.uint32(16))
    t2 = a0 * b1 + (t & jnp.uint32(0xFFFF))
    return a1 * b1 + (t >> jnp.uint32(16)) + (t2 >> jnp.uint32(16))


def fib_home_u32(id_lo, id_hi, *, shift: int):
    """Home slots from uint32 id limbs — bit-equal to
    ``core.hashmap.home_slots`` on the reassembled int64 ids.

    The full product mod 2^64 is ``lo·FIB + ((hi·FIB_lo + lo·FIB_hi)
    << 32)``; slot indices are its bits [shift, 64) with shift ≥ 32, so
    only the upper limb ``mulhi(lo, FIB_lo) + hi·FIB_lo + lo·FIB_hi``
    (wrapping uint32 adds) is ever needed."""
    upper = (_mulhi32(id_lo, jnp.uint32(_FIB_LO))
             + id_hi * jnp.uint32(_FIB_LO) + id_lo * jnp.uint32(_FIB_HI))
    return (upper >> jnp.uint32(shift - 32)).astype(jnp.int32)


def _probe_kernel(klo_ref, khi_ref, ilo_ref, ihi_ref, pos_ref, found_ref, *,
                  shift, imask, max_rounds):
    klo = klo_ref[...]
    khi = khi_ref[...]
    ilo = ilo_ref[...]
    ihi = ihi_ref[...]
    # sentinel-valued queries can never be stored: mask to id 0 and force
    # not-found at the end (mirrors the host probe's `bad` handling)
    bad = (ihi == jnp.uint32(_SENT_HI)) & (ilo <= jnp.uint32(1))
    qlo = jnp.where(bad, jnp.uint32(0), ilo)
    qhi = jnp.where(bad, jnp.uint32(0), ihi)

    home = fib_home_u32(qlo, qhi, shift=shift)
    k_lo = jnp.take(klo, home, mode="clip")
    k_hi = jnp.take(khi, home, mode="clip")
    hit = (k_lo == qlo) & (k_hi == qhi)
    empty_home = (k_hi == jnp.uint32(_SENT_HI)) & (k_lo == jnp.uint32(0))

    win = jnp.arange(_WINDOW, dtype=jnp.int32)

    def round_body(state):
        r, cur, pos, found, active = state
        cand = (cur[:, None] + win[None, :]) & jnp.int32(imask)   # (n, W)
        kwlo = jnp.take(klo, cand, mode="clip")
        kwhi = jnp.take(khi, cand, mode="clip")
        hitw = (kwlo == qlo[:, None]) & (kwhi == qhi[:, None])
        ha = hitw.any(axis=1) & active
        first = jnp.argmax(hitw, axis=1)
        hpos = jnp.take_along_axis(cand, first[:, None], axis=1)[:, 0]
        pos = jnp.where(ha, hpos, pos)
        found = found | ha
        emptyw = ((kwhi == jnp.uint32(_SENT_HI))
                  & (kwlo == jnp.uint32(0))).any(axis=1)
        active = active & ~ha & ~emptyw
        return r + 1, (cur + jnp.int32(_WINDOW)) & jnp.int32(imask), \
            pos, found, active

    def round_cond(state):
        r, _, _, _, active = state
        return jnp.logical_and(r < max_rounds, active.any())

    init = (jnp.int32(0),
            (home + 1) & jnp.int32(imask),            # tail starts past home
            home,                                     # garbage where ~found
            hit,
            ~hit & ~empty_home)
    _, _, pos, found, _ = jax.lax.while_loop(round_cond, round_body, init)
    pos_ref[...] = pos
    found_ref[...] = found & ~bad


def hashmap_probe(keys_lo: jax.Array, keys_hi: jax.Array,
                  ids_lo: jax.Array, ids_hi: jax.Array, *,
                  shift: int, interpret: bool = False):
    """Probe a device-resident slot-id table.

    Args:
      keys_lo, keys_hi: (C,) uint32 — the map's key array as little-endian
        32-bit limbs; C a power of two (``IdHashMap`` capacities always
        are). EMPTY/TOMB sentinels included.
      ids_lo, ids_hi: (N,) uint32 — query ids, same limb split.
      shift: the map's Fibonacci shift (``64 - log2(C)``; 32 ≤ shift ≤ 60).

    Returns:
      (pos (N,) int32, found (N,) bool). ``pos`` is the key's table slot
      where ``found``; garbage otherwise. Bit-equal to
      ``IdHashMap._probe`` on the same state.
    """
    n = ids_lo.shape[0]
    cap = keys_lo.shape[0]
    kernel = functools.partial(
        _probe_kernel, shift=shift, imask=cap - 1,
        max_rounds=cap // _WINDOW + 2)
    kspec = pl.BlockSpec((cap,), lambda i: (0,))
    ispec = pl.BlockSpec((n,), lambda i: (0,))
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[kspec, kspec, ispec, ispec],
        out_specs=[ispec, ispec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)],
        interpret=interpret,
    )(keys_lo, keys_hi, ids_lo, ids_hi)


# ---------------------------------------------------------------------------
# HBM-resident table: windowed DMA probe
# ---------------------------------------------------------------------------

def wrap_pad_limbs(keys_lo, keys_hi, *, cap: int, window: int = _DMA_WINDOW):
    """Wrap-pad exact-capacity key-limb arrays to ``cap + min(window, cap)``
    by appending the first window's worth of slots, so a DMA window
    starting anywhere in ``[0, cap)`` reads ``window`` CONTIGUOUS slots —
    the copy never wraps mid-transfer. Padded slot ``cap + t`` mirrors
    slot ``t``; window-local offsets are folded back with
    ``(start + offset) & (cap - 1)``. Works on host numpy and traced jax
    arrays alike (the device mirror pre-pads once per upload; the probe
    wrapper pads ad-hoc inputs on the fly)."""
    w = min(window, cap)
    cat = np if isinstance(keys_lo, np.ndarray) else jnp
    return (cat.concatenate([keys_lo, keys_lo[:w]]),
            cat.concatenate([keys_hi, keys_hi[:w]]))


def _dma_probe_kernel(cur_s, first_s, klo_hbm, khi_hbm, qlo_ref, qhi_ref,
                      pos_ref, found_ref, act_ref, cur_ref,
                      pos_out, found_out, act_out, cur_out,
                      buf_lo, buf_hi, sem, *, cap, window, chunk):
    """One probe pass over one id chunk: DMA ``window`` consecutive slots
    per id from the HBM key table into the double-buffered VMEM scratch
    (prefetching the NEXT chunk's windows first), then resolve as many
    host-probe rounds as the window covers."""
    i = pl.program_id(0)
    nsteps = pl.num_programs(0)

    def copies(slot, step):
        out = []
        for c in range(chunk):
            s = cur_s[step * chunk + c]
            out.append(pltpu.make_async_copy(
                klo_hbm.at[pl.ds(s, window)], buf_lo.at[slot, c],
                sem.at[slot, c, 0]))
            out.append(pltpu.make_async_copy(
                khi_hbm.at[pl.ds(s, window)], buf_hi.at[slot, c],
                sem.at[slot, c, 1]))
        return out

    @pl.when(i == 0)
    def _start_first():
        for cp in copies(0, 0):
            cp.start()

    @pl.when(i + 1 < nsteps)
    def _prefetch_next():                   # overlap: next chunk's DMA
        for cp in copies((i + 1) % 2, i + 1):   # flies while this chunk
            cp.start()                          # probes

    for cp in copies(i % 2, i):
        cp.wait()

    kw_lo = buf_lo[i % 2]                               # (chunk, window)
    kw_hi = buf_hi[i % 2]
    qlo = qlo_ref[...]
    qhi = qhi_ref[...]
    first = first_s[0] == 1

    # The window covers several host-probe rounds at once: on the first
    # pass, the home slot (offset 0) plus K full 8-slot windows starting
    # at offset 1; on continuation passes, K windows from offset 0. Host
    # semantics — rounds resolve strictly in order: the FIRST 8-slot
    # group containing a hit or an EMPTY slot decides, a hit anywhere in
    # that group beats an EMPTY in it.
    start = jnp.where(first, jnp.int32(1), jnp.int32(0))
    k_groups = jnp.where(first, jnp.int32((window - 1) // _WINDOW),
                         jnp.int32(window // _WINDOW))
    off = jax.lax.broadcasted_iota(jnp.int32, (chunk, window), 1)
    valid = (off >= start) & (off < start + _WINDOW * k_groups)
    grp = (off - start) // _WINDOW                      # garbage off-valid
    hitw = (kw_lo == qlo[:, None]) & (kw_hi == qhi[:, None]) & valid
    emptyw = ((kw_hi == jnp.uint32(_SENT_HI))
              & (kw_lo == jnp.uint32(0)) & valid)
    event = hitw | emptyw
    big = jnp.int32(window)                             # > any group index
    gmin = jnp.min(jnp.where(event, grp, big), axis=1)  # (chunk,)
    resolved_w = gmin < big
    hit_in = hitw & (grp == gmin[:, None])              # resolving group
    found_w = hit_in.any(axis=1)
    ploc = jnp.argmax(hit_in, axis=1)                   # first in-group hit

    # first pass: the home slot (offset 0) is checked BEFORE any window
    hit0 = (kw_lo[:, 0] == qlo) & (kw_hi[:, 0] == qhi)
    empty0 = ((kw_hi[:, 0] == jnp.uint32(_SENT_HI))
              & (kw_lo[:, 0] == jnp.uint32(0)))
    resolved = jnp.where(first, hit0 | empty0 | resolved_w, resolved_w)
    fnd = jnp.where(first, hit0 | (~hit0 & ~empty0 & found_w), found_w)
    ploc = jnp.where(first & hit0, jnp.int32(0), ploc)

    cur = cur_ref[...]
    act = act_ref[...]
    newly = act & resolved & fnd
    abspos = (cur + ploc) & jnp.int32(cap - 1)
    pos_out[...] = jnp.where(newly, abspos, pos_ref[...])
    found_out[...] = found_ref[...] | newly
    alive = act & ~resolved
    act_out[...] = alive
    adv = start + _WINDOW * k_groups
    cur_out[...] = jnp.where(alive, (cur + adv) & jnp.int32(cap - 1), cur)


def _dma_probe_pass(klo, khi, qlo, qhi, pos, found, active, cur, first, *,
                    cap, window, chunk, interpret):
    npad = cur.shape[0]
    grid = (npad // chunk,)
    kernel = functools.partial(_dma_probe_kernel, cap=cap, window=window,
                               chunk=chunk)
    cspec = pl.BlockSpec((chunk,), lambda i, cur_s, first_s: (i,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # DMA start offsets + round-1 flag
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),     # klo: stays in
                  pl.BlockSpec(memory_space=pltpu.ANY),     # khi: HBM
                  cspec, cspec, cspec, cspec, cspec, cspec],
        out_specs=[cspec, cspec, cspec, cspec],
        scratch_shapes=[pltpu.VMEM((2, chunk, window), jnp.uint32),
                        pltpu.VMEM((2, chunk, window), jnp.uint32),
                        pltpu.SemaphoreType.DMA((2, chunk, 2))],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((npad,), jnp.int32),
                   jax.ShapeDtypeStruct((npad,), jnp.bool_),
                   jax.ShapeDtypeStruct((npad,), jnp.bool_),
                   jax.ShapeDtypeStruct((npad,), jnp.int32)],
        interpret=interpret,
    )(cur, first, klo, khi, qlo, qhi, pos, found, active, cur)


def hashmap_probe_hbm(keys_lo: jax.Array, keys_hi: jax.Array,
                      ids_lo: jax.Array, ids_hi: jax.Array, *,
                      shift: int, interpret: bool = False,
                      window: int = _DMA_WINDOW, chunk: int = _DMA_CHUNK):
    """Probe a slot-id table that LIVES IN HBM (``pltpu.ANY``), windowed
    DMA per id — same contract and bit-identical results as
    ``hashmap_probe``, without the VMEM capacity bound.

    Args:
      keys_lo, keys_hi: (C,) or (C + min(window, C),) uint32 — the key
        limb arrays, either exact capacity (padded here on the fly) or
        already wrap-padded by ``wrap_pad_limbs`` (the device mirror
        uploads them pre-padded so steady-state calls pad nothing).
      ids_lo, ids_hi: (N,) uint32 query limbs.
      shift: the map's Fibonacci shift; capacity is ``2**(64 - shift)``
        (so the true capacity survives padding).
      window: slots DMA'd per id per pass (clamped to C).
      chunk: ids probed per grid step.

    Returns ``(pos (N,) int32, found (N,) bool)`` exactly like
    ``hashmap_probe``.
    """
    cap = 1 << (64 - int(shift))
    w = min(window, cap)
    n = ids_lo.shape[0]
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.bool_))
    if keys_lo.shape[0] == cap:
        keys_lo, keys_hi = wrap_pad_limbs(keys_lo, keys_hi, cap=cap,
                                          window=w)
    assert keys_lo.shape[0] == cap + w, \
        f"key table must be cap ({cap}) or wrap-padded (cap + {w})"

    npad = -(-n // chunk) * chunk
    zpad = npad - n
    qlo = jnp.concatenate([ids_lo, jnp.zeros((zpad,), jnp.uint32)])
    qhi = jnp.concatenate([ids_hi, jnp.zeros((zpad,), jnp.uint32)])
    # sentinel-valued queries can never be stored: probe id 0, force
    # not-found at the end (same as the VMEM kernel / host probe)
    bad = (qhi == jnp.uint32(_SENT_HI)) & (qlo <= jnp.uint32(1))
    qlo = jnp.where(bad, jnp.uint32(0), qlo)
    qhi = jnp.where(bad, jnp.uint32(0), qhi)
    home = fib_home_u32(qlo, qhi, shift=shift)
    active0 = jnp.concatenate([jnp.ones((n,), jnp.bool_),
                               jnp.zeros((zpad,), jnp.bool_)])
    max_rounds = cap // _WINDOW + 2

    def cond(state):
        r, _, _, _, active = state
        return jnp.logical_and(r < max_rounds, active.any())

    def body(state):
        r, cur, pos, found, active = state
        first = (r == 0).astype(jnp.int32).reshape(1)
        pos, found, active, cur = _dma_probe_pass(
            keys_lo, keys_hi, qlo, qhi, pos, found, active, cur, first,
            cap=cap, window=w, chunk=chunk, interpret=interpret)
        return r + 1, cur, pos, found, active

    init = (jnp.int32(0), home, home, jnp.zeros((npad,), jnp.bool_),
            active0)
    _, _, pos, found, _ = jax.lax.while_loop(cond, body, init)
    return pos[:n], (found & ~bad)[:n]
