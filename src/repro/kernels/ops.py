"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode — the kernel body
runs in Python for correctness validation; on TPU the same calls compile to
Mosaic. ``interpret`` resolves automatically from the backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels import decode_attention as _da
from repro.kernels import delta_codec as _dc
from repro.kernels import embedding_lookup as _el
from repro.kernels import flash_attention as _fa
from repro.kernels import ftrl_row_update as _ftrl
from repro.kernels import hashmap_probe as _hm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def int64_limbs(a: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split a host int64 array into (lo, hi) uint32 limb arrays — the id
    format the device probe consumes (jax runs with x64 disabled; see
    ``kernels/hashmap_probe.py``). A reinterpreting view + two strided
    copies; assumes a little-endian host (x86/ARM)."""
    v = np.ascontiguousarray(a, dtype=np.int64).view(np.uint32)
    v = v.reshape(-1, 2)
    return np.ascontiguousarray(v[:, 0]), np.ascontiguousarray(v[:, 1])


@functools.partial(jax.jit, static_argnames=())
def embedding_lookup(table, ids):
    return _el.embedding_lookup(table, ids, interpret=_interpret())


@jax.jit
def embedding_scatter_add(table, ids, updates):
    return _el.embedding_scatter_add(table, ids, updates,
                                     interpret=_interpret())


@jax.jit
def embedding_scatter(table, ids, updates):
    return _el.embedding_scatter(table, ids, updates,
                                 interpret=_interpret())


def _probe(keys_lo, keys_hi, ids_lo, ids_hi, *, shift, placement):
    """Placement-dispatched probe (traced): ``"vmem"`` streams the whole
    key table into VMEM per call (cheapest for small maps), ``"hbm"``
    keeps it in ANY/HBM and DMAs probe windows (no VMEM capacity bound),
    ``"auto"`` picks by capacity against ``VMEM_SLOT_BOUND``. The key
    arrays may be wrap-padded (HBM layout); the VMEM kernel slices the
    pad back off."""
    cap = 1 << (64 - int(shift))
    if placement == "auto":
        placement = "hbm" if cap > _hm.VMEM_SLOT_BOUND else "vmem"
    if placement == "hbm":
        return _hm.hashmap_probe_hbm(keys_lo, keys_hi, ids_lo, ids_hi,
                                     shift=shift, interpret=_interpret())
    assert placement == "vmem", f"unknown placement {placement!r}"
    return _hm.hashmap_probe(keys_lo[:cap], keys_hi[:cap], ids_lo, ids_hi,
                             shift=shift, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("shift", "placement"))
def hashmap_probe(keys_lo, keys_hi, ids_lo, ids_hi, *, shift,
                  placement="auto"):
    return _probe(keys_lo, keys_hi, ids_lo, ids_hi, shift=shift,
                  placement=placement)


@functools.partial(jax.jit, static_argnames=("shift", "placement"))
def fused_lookup(keys_lo, keys_hi, slot_of, arena, ids_lo, ids_hi, *,
                 shift, placement="auto"):
    """Fused probe→gather: serve-path lookup against a device-resident
    table mirror, one jit — no host hop between the probe and the row
    gather. ``slot_of`` is the map's value table (key-slot → arena slot,
    int32). Missing rows come back as zeros. Returns (rows (N, D), found
    (N,) bool, slot (N,) int32 — arena slots, 0 where not found): the
    found mask lets callers count cache misses straight off the device
    probe, the slots let them update host-side LRU stats, neither costs
    a host re-probe."""
    pos, found = _probe(keys_lo, keys_hi, ids_lo, ids_hi, shift=shift,
                        placement=placement)
    slot = jnp.where(found, jnp.take(slot_of, pos, mode="clip"), 0)
    rows = _el.embedding_lookup(arena, slot, interpret=_interpret())
    return (jnp.where(found[:, None], rows, jnp.zeros((), rows.dtype)),
            found, slot)


@functools.partial(jax.jit,
                   static_argnames=("shift", "alpha", "beta", "l1", "l2",
                                    "placement"),
                   donate_argnums=(3, 4, 5))
def fused_ftrl_apply(keys_lo, keys_hi, slot_of, z_arena, n_arena, w_arena,
                     ids_lo, ids_hi, grads, *, shift, alpha, beta, l1, l2,
                     placement="auto"):
    """The fused sparse training hot path, one jit end to end:
    probe → gather (z, n) → FTRL row update → scatter (z', n', w') back
    into the arenas. No stage output ever leaves the device.

    ``ids`` must be UNIQUE and PRESENT in the map (``MasterShard`` runs
    ``ensure`` before engaging the fused path; ``found`` is returned so
    the caller can assert that). The three arenas are donated — callers
    rebind them from the outputs (the device mirror keeps them resident
    across batches). Row outputs (z', n', w') are returned as well so the
    host-authoritative arrays can be updated without re-downloading whole
    arenas."""
    pos, found = _probe(keys_lo, keys_hi, ids_lo, ids_hi, shift=shift,
                        placement=placement)
    slot = jnp.where(found, jnp.take(slot_of, pos, mode="clip"), 0)
    z = _el.embedding_lookup(z_arena, slot, interpret=_interpret())
    n = _el.embedding_lookup(n_arena, slot, interpret=_interpret())
    z2, n2, w2 = _ftrl.ftrl_row_update(z, n, grads, alpha=alpha, beta=beta,
                                       l1=l1, l2=l2,
                                       interpret=_interpret())
    z_arena = _el.embedding_scatter(z_arena, slot, z2,
                                    interpret=_interpret())
    n_arena = _el.embedding_scatter(n_arena, slot, n2,
                                    interpret=_interpret())
    w_arena = _el.embedding_scatter(w_arena, slot, w2.astype(w_arena.dtype),
                                    interpret=_interpret())
    return z_arena, n_arena, w_arena, z2, n2, w2, found


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "l1", "l2"))
def ftrl_row_update(z, n, g, *, alpha=0.05, beta=1.0, l1=1.0, l2=1.0):
    return _ftrl.ftrl_row_update(z, n, g, alpha=alpha, beta=beta, l1=l1,
                                 l2=l2, interpret=_interpret())


@jax.jit
def quantize_rows(x):
    return _dc.quantize_rows(x, interpret=_interpret())


@jax.jit
def dequantize_rows(q, scale):
    return _dc.dequantize_rows(q, scale, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, lengths, *, block_k=512):
    return _da.decode_attention(q, k, v, lengths, block_k=block_k,
                                interpret=_interpret())
