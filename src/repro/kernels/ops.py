"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode — the kernel body
runs in Python for correctness validation; on TPU the same calls compile to
Mosaic. ``interpret`` resolves automatically from the backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import delta_codec as _dc
from repro.kernels import embedding_lookup as _el
from repro.kernels import flash_attention as _fa
from repro.kernels import ftrl_row_update as _ftrl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=())
def embedding_lookup(table, ids):
    return _el.embedding_lookup(table, ids, interpret=_interpret())


@jax.jit
def embedding_scatter_add(table, ids, updates):
    return _el.embedding_scatter_add(table, ids, updates,
                                     interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "l1", "l2"))
def ftrl_row_update(z, n, g, *, alpha=0.05, beta=1.0, l1=1.0, l2=1.0):
    return _ftrl.ftrl_row_update(z, n, g, alpha=alpha, beta=beta, l1=l1,
                                 l2=l2, interpret=_interpret())


@jax.jit
def quantize_rows(x):
    return _dc.quantize_rows(x, interpret=_interpret())


@jax.jit
def dequantize_rows(q, scale):
    return _dc.dequantize_rows(q, scale, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, lengths, *, block_k=512):
    return _da.decode_attention(q, k, v, lengths, block_k=block_k,
                                interpret=_interpret())
