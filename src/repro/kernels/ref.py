"""Pure-jnp oracles for every Pallas kernel — the ground truth the sweep
tests assert against (interpret-mode kernels must match these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """table (V, D); ids (N,) -> (N, D)."""
    return table[ids]


def embedding_scatter_add(table: jax.Array, ids: jax.Array,
                          updates: jax.Array) -> jax.Array:
    """table (V, D); ids (N,); updates (N, D) -> (V, D) with += rows."""
    return table.at[ids].add(updates.astype(table.dtype))


def embedding_scatter(table: jax.Array, ids: jax.Array,
                      updates: jax.Array) -> jax.Array:
    """table (V, D); ids (N,) UNIQUE; updates (N, D) -> (V, D) with rows
    replaced (set, not add). Duplicate ids are undefined — the PS scatter
    paths dedupe before calling."""
    return table.at[ids].set(updates.astype(table.dtype))


def hashmap_probe(keys_lo: jax.Array, keys_hi: jax.Array,
                  ids_lo: jax.Array, ids_hi: jax.Array, *, shift: int):
    """Oracle for the windowed open-addressing probe, via the full
    circular probe order (O(N·C) — test scale only).

    For each query, ranks every table slot by probe order from the id's
    home slot, then bins positions into probe windows (round 1 = the home
    slot alone, tail rounds = ``_WINDOW``-slot windows): a key is found
    iff its first match lands in a window no later than the first EMPTY
    slot's window (a hit anywhere in a window beats an EMPTY in the same
    window — the kernel checks hits before termination). The Fibonacci
    home computation is shared with the kernel (``fib_home_u32``), which
    the test suite pins against the host ``core.hashmap.home_slots``
    independently. Same limb layout and sentinel handling as the kernel;
    ``pos`` is garbage where ``found`` is False."""
    from repro.kernels.hashmap_probe import _WINDOW, fib_home_u32
    cap = keys_lo.shape[0]
    n = ids_lo.shape[0]
    sent_hi = jnp.uint32(0x80000000)
    bad = (ids_hi == sent_hi) & (ids_lo <= jnp.uint32(1))
    qlo = jnp.where(bad, jnp.uint32(0), ids_lo)
    qhi = jnp.where(bad, jnp.uint32(0), ids_hi)
    home = fib_home_u32(qlo, qhi, shift=shift)
    order = (home[:, None] + jnp.arange(cap, dtype=jnp.int32)) & (cap - 1)
    k_lo = keys_lo[order]
    k_hi = keys_hi[order]
    match = (k_lo == qlo[:, None]) & (k_hi == qhi[:, None])
    empty = (k_hi == sent_hi) & (k_lo == jnp.uint32(0))
    # probe-window index of each probe-order position
    widx = jnp.where(jnp.arange(cap) == 0, 0,
                     (jnp.arange(cap) - 1) // _WINDOW + 1)
    first_m = jnp.argmax(match, axis=1)            # first match position
    first_e = jnp.argmax(empty, axis=1)            # first EMPTY position
    m_w = widx[first_m]
    e_w = jnp.where(empty.any(axis=1), widx[first_e], cap + 1)
    found = match.any(axis=1) & (m_w <= e_w) & ~bad
    pos = order[jnp.arange(n), first_m]
    return pos, found


def ftrl_row_update(z, n, g, *, alpha: float, beta: float, l1: float,
                    l2: float):
    """FTRL-proximal row update. All inputs (B, D) fp32.
    Returns (z_new, n_new, w_new)."""
    w = jnp.where(jnp.abs(z) > l1,
                  (jnp.sign(z) * l1 - z) / ((beta + jnp.sqrt(n)) / alpha + l2),
                  0.0)
    n_new = n + g * g
    sigma = (jnp.sqrt(n_new) - jnp.sqrt(n)) / alpha
    z_new = z + g - sigma * w
    w_new = jnp.where(jnp.abs(z_new) > l1,
                      (jnp.sign(z_new) * l1 - z_new)
                      / ((beta + jnp.sqrt(n_new)) / alpha + l2),
                      0.0)
    return z_new, n_new, w_new


def quantize_rows(x: jax.Array):
    """Row-wise absmax int8: x (B, D) -> (q int8 (B, D), scale f32 (B, 1)).
    Reciprocal multiply (not /127.0) to stay bit-identical with the
    kernel under XLA's constant-division folding."""
    scale = jnp.maximum(jnp.abs(x).max(axis=-1, keepdims=True)
                        * (1.0 / 127.0), 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of ``quantize_rows``: int8 codes (B, D) × per-row scale
    (B, 1) -> float32 rows. Bit-identical to the kernel path (one cast,
    one multiply — no fused-reciprocal divergence)."""
    return q.astype(jnp.float32) * scale


def flash_attention(q, k, v, *, causal: bool = True):
    """Reference attention. q (B, H, S, D); k, v (B, G, S, D) with
    H = G * group_size (GQA). fp32 softmax."""
    b, h, s, d = q.shape
    g = k.shape[1]
    m = h // g
    qg = q.reshape(b, g, m, s, d)
    scores = jnp.einsum("bgmsd,bgtd->bgmst", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[2]), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgmst,bgtd->bgmsd", p.astype(v.dtype), v)
    return out.reshape(b, h, s, d)


def decode_attention(q, k, v, lengths):
    """Single-token decode. q (B, H, D); k, v (B, S, G, D);
    lengths (B,) valid cache lengths. fp32 softmax. -> (B, H, D)."""
    b, h, d = q.shape
    g = k.shape[2]
    m = h // g
    qg = q.reshape(b, g, m, d)
    scores = jnp.einsum("bgmd,bsgd->bgms", qg, k,
                        preferred_element_type=jnp.float32) * d ** -0.5
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgms,bsgd->bgmd", p.astype(v.dtype), v)
    return out.reshape(b, h, d)
