"""Pure-jnp oracles for every Pallas kernel — the ground truth the sweep
tests assert against (interpret-mode kernels must match these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """table (V, D); ids (N,) -> (N, D)."""
    return table[ids]


def embedding_scatter_add(table: jax.Array, ids: jax.Array,
                          updates: jax.Array) -> jax.Array:
    """table (V, D); ids (N,); updates (N, D) -> (V, D) with += rows."""
    return table.at[ids].add(updates.astype(table.dtype))


def ftrl_row_update(z, n, g, *, alpha: float, beta: float, l1: float,
                    l2: float):
    """FTRL-proximal row update. All inputs (B, D) fp32.
    Returns (z_new, n_new, w_new)."""
    w = jnp.where(jnp.abs(z) > l1,
                  (jnp.sign(z) * l1 - z) / ((beta + jnp.sqrt(n)) / alpha + l2),
                  0.0)
    n_new = n + g * g
    sigma = (jnp.sqrt(n_new) - jnp.sqrt(n)) / alpha
    z_new = z + g - sigma * w
    w_new = jnp.where(jnp.abs(z_new) > l1,
                      (jnp.sign(z_new) * l1 - z_new)
                      / ((beta + jnp.sqrt(n_new)) / alpha + l2),
                      0.0)
    return z_new, n_new, w_new


def quantize_rows(x: jax.Array):
    """Row-wise absmax int8: x (B, D) -> (q int8 (B, D), scale f32 (B, 1)).
    Reciprocal multiply (not /127.0) to stay bit-identical with the
    kernel under XLA's constant-division folding."""
    scale = jnp.maximum(jnp.abs(x).max(axis=-1, keepdims=True)
                        * (1.0 / 127.0), 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def flash_attention(q, k, v, *, causal: bool = True):
    """Reference attention. q (B, H, S, D); k, v (B, G, S, D) with
    H = G * group_size (GQA). fp32 softmax."""
    b, h, s, d = q.shape
    g = k.shape[1]
    m = h // g
    qg = q.reshape(b, g, m, s, d)
    scores = jnp.einsum("bgmsd,bgtd->bgmst", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[2]), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgmst,bgtd->bgmsd", p.astype(v.dtype), v)
    return out.reshape(b, h, s, d)


def decode_attention(q, k, v, lengths):
    """Single-token decode. q (B, H, D); k, v (B, S, G, D);
    lengths (B,) valid cache lengths. fp32 softmax. -> (B, H, D)."""
    b, h, d = q.shape
    g = k.shape[2]
    m = h // g
    qg = q.reshape(b, g, m, d)
    scores = jnp.einsum("bgmd,bsgd->bgms", qg, k,
                        preferred_element_type=jnp.float32) * d ** -0.5
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgms,bsgd->bgmd", p.astype(v.dtype), v)
    return out.reshape(b, h, d)
