"""Deterministic fault injection for the multi-process cluster runtime.

A :class:`FaultPlan` is a seeded, fully serializable list of
:class:`FaultEvent`s — each names a target process (``master-0``,
``slave-1.0``), an instrumented code point, the driver step at which it
arms, and what happens there:

  * ``kill``  — the worker SIGKILLs *itself* (``os.kill(getpid(),
    SIGKILL)``) at the instrumented point: no cleanup, no flush, the
    closest a test can get to power loss for one process;
  * ``delay`` — the worker sleeps ``value`` seconds at the point
    (transport stall);
  * ``drop``  — a slave's poll returns without fetching (a dropped fetch
    response); the queue's consumer offsets don't move, so the next poll
    redelivers — the at-least-once window;
  * ``skew``  — the worker's sync clock runs ``value`` seconds ahead when
    stamping records, skewing the sync-lag metric downstream consumers
    compute from record timestamps.

Instrumented points (see ``launch/worker.py``):

  ========== ======= ====================================================
  point      role    crash window it exposes
  ========== ======= ====================================================
  mid_train  master  optimizer state mutated, ack never sent
  mid_flush  master  SOME partitions carry the flush's records, some don't
  mid_ckpt   master  part file half-written, manifest never committed
  pre_apply  slave   consumer offsets advanced in memory, records unapplied
  ========== ======= ====================================================

Determinism: events fire on exact (target, point, step) matches driven by
the supervisor's logical step counter — never wall clock — so a failing
seed replays exactly. The supervisor consumes each event when it observes
the death it caused and re-arms workers with only the *unfired* remainder
on respawn, so a kill does not re-fire while the recovered cluster replays
the very step that died.
"""

from __future__ import annotations

import json
import os
import random
import signal
import time
from dataclasses import asdict, dataclass, field
from typing import Optional

KILL_POINTS = ("mid_train", "mid_flush", "mid_ckpt", "pre_apply")
MASTER_POINTS = ("mid_train", "mid_flush", "mid_ckpt")
SLAVE_POINTS = ("pre_apply",)


@dataclass(frozen=True)
class FaultEvent:
    target: str               # ProcSlot.name, e.g. "master-0", "slave-1.0"
    point: str                # one of KILL_POINTS
    step: int                 # driver step at which the event fires
    kind: str = "kill"        # kill | delay | drop | skew
    value: float = 0.0        # delay/skew seconds (unused for kill/drop)

    def matches(self, target: str, point: str, step: int) -> bool:
        return (self.target == target and self.point == point
                and self.step == step)


@dataclass
class FaultPlan:
    """Seeded schedule of fault events, stable under (de)serialization."""

    seed: int
    events: list[FaultEvent] = field(default_factory=list)

    @classmethod
    def generate(cls, seed: int, *, steps: int,
                 masters: list[str], slaves: list[str],
                 kills: int = 2, delays: int = 1, drops: int = 1,
                 skews: int = 0, skew: float = 5.0,
                 delay: float = 0.05) -> "FaultPlan":
        """Draw a deterministic plan: ``kills`` process kills spread over
        master points and slave pre_apply, plus transport delays/drops and
        clock skews. Same (seed, shape) args -> identical plan, on any
        host. Kill steps avoid step 0 (the bootstrap checkpoint) and the
        final step (so every run has a post-recovery tail to assert on)."""
        rng = random.Random(seed)
        events: list[FaultEvent] = []
        step_lo, step_hi = 1, max(1, steps - 2)
        for _ in range(kills):
            if slaves and rng.random() < 0.3:
                events.append(FaultEvent(rng.choice(slaves), "pre_apply",
                                         rng.randint(step_lo, step_hi)))
            else:
                events.append(FaultEvent(rng.choice(masters),
                                         rng.choice(list(MASTER_POINTS)),
                                         rng.randint(step_lo, step_hi)))
        for _ in range(delays):
            who = rng.choice(masters + slaves)
            pt = "pre_apply" if who in slaves else "mid_flush"
            events.append(FaultEvent(who, pt,
                                     rng.randint(step_lo, step_hi),
                                     kind="delay", value=delay))
        for _ in range(drops):
            if slaves:
                events.append(FaultEvent(rng.choice(slaves), "pre_apply",
                                         rng.randint(step_lo, step_hi),
                                         kind="drop"))
        for _ in range(skews):
            events.append(FaultEvent(rng.choice(masters), "mid_flush",
                                     rng.randint(step_lo, step_hi),
                                     kind="skew", value=skew))
        events.sort(key=lambda e: (e.step, e.target, e.point, e.kind))
        return cls(seed=seed, events=events)

    # -- (de)serialization (supervisor <-> workers, CI repro) ------------
    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "events": [asdict(e) for e in self.events]},
                          indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls(seed=d["seed"],
                   events=[FaultEvent(**e) for e in d["events"]])

    def for_target(self, target: str) -> list[FaultEvent]:
        return [e for e in self.events if e.target == target]

    def kills(self) -> list[FaultEvent]:
        return [e for e in self.events if e.kind == "kill"]


class FaultHooks:
    """Worker-side executor of the events armed for one process. The
    worker calls ``check(point, step)`` at every instrumented point; a
    matching event fires its effect. ``kill`` never returns."""

    def __init__(self, target: str,
                 events: Optional[list[FaultEvent]] = None):
        self.target = target
        self.events = list(events or [])
        self.fired: list[FaultEvent] = []
        self.skew = 0.0           # cumulative clock skew (seconds)
        # observability hook: called with each event just BEFORE its
        # effect executes. A kill destroys the process (and any
        # in-memory trace ring) instantly, so this is the only moment a
        # fault annotation / pre-kill span dump can be recorded —
        # launch/worker.py wires it to the tracer.
        self.on_fire = None

    def arm(self, events: list[FaultEvent]) -> None:
        self.events = list(events)

    def pending(self, point: str, step: int,
                kind: Optional[str] = None) -> Optional[FaultEvent]:
        for e in self.events:
            if e.matches(self.target, point, step) and \
                    (kind is None or e.kind == kind):
                return e
        return None

    def check(self, point: str, step: int) -> bool:
        """Fire every armed event matching (point, step). Returns True
        when a ``drop`` fired (the caller skips its fetch). A ``kill``
        SIGKILLs this process — no return, no cleanup."""
        dropped = False
        for e in list(self.events):
            if not e.matches(self.target, point, step):
                continue
            self.events.remove(e)
            self.fired.append(e)
            if self.on_fire is not None:
                self.on_fire(e)
            if e.kind == "delay":
                time.sleep(e.value)
            elif e.kind == "skew":
                self.skew += e.value
            elif e.kind == "drop":
                dropped = True
            elif e.kind == "kill":
                os.kill(os.getpid(), signal.SIGKILL)
        return dropped

    def now(self, now: float) -> float:
        """The worker's (possibly skewed) view of the sync clock."""
        return now + self.skew
