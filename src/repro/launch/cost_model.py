"""Scan-corrected cost model.

XLA's ``cost_analysis`` counts a ``while`` (scan) body ONCE regardless of
trip count (verified empirically on the CPU backend). Layer stacks here run
under ``lax.scan`` over segments, so the main program's cost analysis under-
counts by a factor of ~depth. Correction: lower each segment *body*
standalone (same shardings, same remat+vjp structure the main program
differentiates through), take its compiled cost, and add
``(repeats - 1) x body_cost`` per segment — every term (FLOPs, bytes,
collective operand bytes) is scan-corrected the same way.

Also provides the analytical per-device memory estimate used for the
"fits 16 GB HBM" criterion: the CPU backend's ``temp_size_in_bytes`` is a
no-liveness-reuse upper bound (sum of all buffers), not a peak — both are
reported in the artifacts.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import MOE, NONE, ModelConfig, Segment
from repro.configs.shapes import InputShape
from repro.launch.hlo_analysis import parse_collectives
from repro.models import model as model_lib
from repro.models.sharding import MeshInfo, cache_pspecs, param_pspecs


@dataclass
class StepCost:
    flops_per_device: float
    bytes_per_device: float
    collective_operand_bytes_per_device: float
    collective_counts: dict

    def scaled(self, k: float) -> "StepCost":
        return StepCost(self.flops_per_device * k, self.bytes_per_device * k,
                        self.collective_operand_bytes_per_device * k,
                        {kk: v * k for kk, v in self.collective_counts.items()})

    def __add__(self, o: "StepCost") -> "StepCost":
        cc = dict(self.collective_counts)
        for k, v in o.collective_counts.items():
            cc[k] = cc.get(k, 0) + v
        return StepCost(self.flops_per_device + o.flops_per_device,
                        self.bytes_per_device + o.bytes_per_device,
                        self.collective_operand_bytes_per_device
                        + o.collective_operand_bytes_per_device, cc)


def _cost_of(compiled) -> StepCost:
    ca = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    return StepCost(float(ca.get("flops", 0.0)),
                    float(ca.get("bytes accessed", 0.0)),
                    float(coll.total_operand_bytes), dict(coll.counts))


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _seg_param_specs(cfg: ModelConfig, seg: Segment, m: MeshInfo,
                     abstract_layer) -> dict:
    """Param ShapeDtypeStructs for ONE scan slice of a segment (the spec
    functions emit the tp2d serve layout themselves; the pure-tp serve
    layout strips the FSDP data axis here, mirroring param_pspecs)."""
    from repro.models.sharding import (_FFN_SPECS, _MIXER_SPECS, DATA,
                                       _strip_axis)
    out = {}
    for i, spec in enumerate(seg.pattern):
        layer = {"mixer": _MIXER_SPECS[spec.mixer](cfg, m)}
        if spec.ffn != NONE:
            layer["ffn"] = _FFN_SPECS[spec.ffn](cfg, m)
        out[f"pos{i}"] = layer
    if not m.opts.fsdp and m.opts.serve_layout == "tp":
        out = _strip_axis(out, DATA)
    shapes = abstract_layer
    return jax.tree.map(
        lambda sds, sp: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(m.mesh, sp)),
        shapes, out, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _abstract_body_params(cfg: ModelConfig, seg: Segment):
    """Shapes of one layer-pattern slice (no leading repeats axis)."""
    def build(key):
        return {f"pos{i}": model_lib._init_layer(key, spec, cfg)
                for i, spec in enumerate(seg.pattern)}
    return jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))


def _batch_axes(m: MeshInfo):
    return m.batch_axes if len(m.batch_axes) > 1 else m.batch_axes[0]


def segment_body_cost(cfg: ModelConfig, seg: Segment, m: MeshInfo,
                      shape: InputShape, *, kind: str,
                      encoder: bool = False) -> StepCost:
    """Compiled cost of one scan iteration of this segment."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    bax = _batch_axes(m) if b >= m.data else None
    lp = _seg_param_specs(cfg, seg, m, _abstract_body_params(cfg, seg))

    if kind in ("train", "prefill"):
        x = _sds((b, s, cfg.d_model), dt, m.mesh, P(bax, None, None))
        positions = _sds((b, s), jnp.int32, m.mesh, P(bax, None))
        enc = None
        if any(sp.mixer == "xattn" for sp in seg.pattern):
            enc = _sds((b, cfg.encoder_len, cfg.d_model), dt, m.mesh,
                       P(bax, None, None))

        def inner(x, lp, positions, enc):
            for i, sp in enumerate(seg.pattern):
                p = lp[f"pos{i}"]
                x = x + model_lib._apply_mixer(sp, p["mixer"], x, cfg,
                                               positions, enc)
                dx, _, _ = model_lib._apply_ffn(sp, p.get("ffn", {}), x, cfg)
                x = x + dx
            return x

        if kind == "prefill":
            def fn(x, lp, positions, enc):
                return inner(x, lp, positions, enc)
        else:
            def fn(x, lp, positions, enc):
                f = jax.checkpoint(inner) if cfg.remat else inner
                def scalar(x_, lp_):
                    return jnp.sum(f(x_, lp_, positions, enc)
                                   .astype(jnp.float32))
                val, grads = jax.value_and_grad(scalar, argnums=(0, 1))(x, lp)
                return val, grads

        with jax.set_mesh(m.mesh):
            compiled = jax.jit(fn).lower(x, lp, positions, enc).compile()
        return _cost_of(compiled)

    # decode: one token through one scan slice, with cache update
    x = _sds((b, 1, cfg.d_model), dt, m.mesh, P(bax, None, None))
    pos = _sds((b,), jnp.int32, m.mesh, P(bax))
    cache_full = model_lib.init_cache(cfg, b, s, dtype=jnp.bfloat16,
                                      abstract=True)
    cspecs_full = cache_pspecs(cfg, m, b)
    # one segment's slice, leading repeats axis dropped
    seg_idx = list(cfg.segments).index(seg)
    cache_seg = cache_full["segments"][seg_idx]
    cspec_seg = cspecs_full["segments"][seg_idx]
    def drop_lead(sds, sp):
        return jax.ShapeDtypeStruct(sds.shape[1:], sds.dtype,
                                    sharding=NamedSharding(
                                        m.mesh, P(*sp[1:])))
    cache = jax.tree.map(drop_lead, cache_seg, cspec_seg,
                         is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct))

    def fn(x, lp, cache, pos):
        new_cache = {}
        for i, sp in enumerate(seg.pattern):
            p = lp[f"pos{i}"]
            dx, nc = model_lib._decode_mixer(sp, p["mixer"], x, pos,
                                             cache[f"pos{i}"], cfg)
            x = x + dx
            dxf, _, _ = model_lib._apply_ffn(sp, p.get("ffn", {}), x, cfg)
            x = x + dxf
            new_cache[f"pos{i}"] = nc
        return x, new_cache

    with jax.set_mesh(m.mesh):
        compiled = jax.jit(fn).lower(x, lp, cache, pos).compile()
    return _cost_of(compiled)


def corrected_cost(main_compiled, cfg: ModelConfig, m: MeshInfo,
                   shape: InputShape) -> tuple[StepCost, dict]:
    """main-program cost + (repeats-1) x body cost per segment."""
    total = _cost_of(main_compiled)
    detail = {"main": total.__dict__.copy(), "segments": []}
    seg_sets = [(cfg.segments, False)]
    if cfg.encoder_segments and shape.kind in ("train", "prefill"):
        seg_sets.append((cfg.encoder_segments, True))
    for segments, is_enc in seg_sets:
        for seg in segments:
            if seg.repeats <= 1:
                continue
            body = segment_body_cost(cfg, seg, m, shape,
                                     kind=shape.kind, encoder=is_enc)
            detail["segments"].append(
                {"repeats": seg.repeats, "encoder": is_enc,
                 **{k: v for k, v in body.__dict__.items()
                    if k != "collective_counts"}})
            total = total + body.scaled(seg.repeats - 1)
    return total, detail


def analytic_hbm_bytes(cfg: ModelConfig, shape: InputShape,
                       m: MeshInfo, arg_bytes_per_device: int) -> float:
    """TPU-faithful HBM traffic estimate (assumes elementwise fusion; the
    CPU backend's 'bytes accessed' counts every unfused op and overstates
    TPU traffic by ~5-20x). Components: weight reads (fwd + remat recompute
    + bwd), grad+optimizer r/w, boundary activation materializations, KV
    cache reads, logits. Reported alongside the XLA number; the roofline's
    memory term uses this estimate."""
    n_dev = m.mesh.devices.size
    dt = jnp.dtype(cfg.dtype).itemsize
    pc = cfg.param_counts()
    p_loc = pc["active"] * dt / n_dev            # active weights/device/step
    b_loc = max(1.0, shape.global_batch /
                (m.data * m.axes.get("pod", 1)))
    s = shape.seq_len
    specs = cfg.layer_specs()
    n_layers = max(1, len(specs))

    if shape.kind == "decode":
        kv_layers = sum(1 for sp in specs if sp.mixer == "attn")
        local_layers = sum(1 for sp in specs if sp.mixer == "local")
        kv_shards = (m.data * m.model if shape.global_batch < m.data
                     else m.model)
        kv_loc = s / max(1, kv_shards)
        cache_read = (kv_layers * 2 * b_loc * kv_loc
                      + local_layers * 2 * b_loc * min(cfg.window_size or s, s)
                      ) * cfg.num_kv_heads * cfg.head_dim * dt
        ssm_layers = sum(1 for sp in specs if sp.mixer == "mamba")
        ssm_state = ssm_layers * b_loc * cfg.ssm_num_heads * \
            cfg.ssm_head_dim * max(cfg.ssm_state, 1) * 4 * 2 / max(1, m.model)
        weights = p_loc                           # one read per token step
        return weights + cache_read + ssm_state

    # train / prefill
    remat_factor = 2 if (shape.kind == "train" and cfg.remat) else 1
    w_reads = remat_factor + (1 if shape.kind == "train" else 0)
    weights = p_loc * w_reads
    if shape.kind == "train":
        slots_per_param = {"adam": 8, "momentum": 4, "adagrad": 4,
                           "ftrl": 8, "adafactor": 0.1, "sgd": 0}
        weights += (pc["total"] / n_dev) * (
            dt * 2                                 # grad write+read
            + slots_per_param.get(cfg.optimizer, 8)  # slot r/w (f32)
            + dt)                                  # param write
    # boundary activations: ~8 materialized (d_model)-wide tensors per layer
    act = n_layers * b_loc * s * cfg.d_model * dt * 8
    if shape.kind == "train":
        act *= 2.5                                 # bwd re-reads + dgrads
    logits = b_loc * s * (cfg.vocab_size / max(1, m.model)) * (dt + 4)
    if shape.kind == "train":
        logits *= 2
    return weights + act + logits


# ---------------------------------------------------------------------------
# Analytical per-device memory estimate (the "fits 16 GB" criterion)
# ---------------------------------------------------------------------------


def activation_estimate(cfg: ModelConfig, shape: InputShape,
                        m: MeshInfo) -> dict:
    """Peak activation bytes/device with remat: saved scan carries + one
    layer's working set + the logits block. Coarse but liveness-aware (the
    CPU backend temp number is not)."""
    n_layers = max(1, sum(s.num_layers for s in cfg.segments))
    dt = jnp.dtype(cfg.dtype).itemsize
    if shape.kind == "decode":
        b_loc = max(1, shape.global_batch // m.data)
        kv_loc = shape.seq_len // max(
            1, (m.data * m.model if shape.global_batch < m.data else m.model))
        kv_layers = sum(1 for sp in cfg.layer_specs() if sp.mixer == "attn")
        cache = kv_layers * 2 * b_loc * kv_loc * cfg.num_kv_heads * \
            cfg.head_dim * dt
        return {"cache_bytes": cache, "working_set": b_loc * cfg.d_model * dt
                * 8, "carries": 0, "logits": b_loc * cfg.vocab_size // max(
                    1, m.model) * 4}
    b_loc = max(1, shape.global_batch // (m.data *
                                          m.axes.get("pod", 1)))
    s = shape.seq_len
    carry = n_layers * b_loc * s * cfg.d_model * dt
    # one layer working set: qkv + attention chunk scores + mlp hidden
    h_loc = max(1, cfg.num_heads // m.model)
    chunk = min(s, 1024)
    scores = b_loc * h_loc * s * chunk * 4
    acc = b_loc * h_loc * s * cfg.head_dim * 4
    mlp = b_loc * s * max(1, cfg.d_ff // m.model) * dt * 2
    logits = b_loc * s * max(1, cfg.vocab_size // m.model) * (dt + 4)
    mult = 3 if shape.kind == "train" else 1     # grads of working set
    return {"carries": carry, "working_set": (scores + acc + mlp) * mult,
            "logits": logits * (2 if shape.kind == "train" else 1),
            "total": carry + (scores + acc + mlp) * mult + logits}
