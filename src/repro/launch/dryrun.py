import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and record roofline
terms. No device allocation — inputs are ShapeDtypeStructs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k [--multi-pod] [--out benchmarks/artifacts]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config  # noqa: E402
from repro.launch.hlo_analysis import parse_collectives             # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.launch.specs import (abstract_params, abstract_train_state,  # noqa: E402
                                input_specs)
from repro.models.sharding import MeshInfo                          # noqa: E402
from repro.serving import make_prefill_step, make_serve_step        # noqa: E402
from repro.training import make_train_step                          # noqa: E402


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               opt: bool = False):
    """Returns (lowered, cfg, shape, mesh_info). ``opt`` enables the
    beyond-paper layout optimizations from §Perf (vocab-TP logits,
    group-local MoE dispatch)."""
    import dataclasses

    from repro.models.sharding import ShardingOptions

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        raise SkipPair(why)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # serve layout by memory fit: pure TP-16 when weights/16 leave room for
    # the (int8) cache under 16 GB HBM, else 2D 256-way weights (§Perf).
    tp_weight_bytes = cfg.param_counts()["total"] * 2 / 16
    opts = ShardingOptions(
        embed_mode="tp" if opt else "fsdp",
        # serving plane: weight-stationary TP, no per-token FSDP gathers
        fsdp=not (opt and shape.kind == "decode"),
        serve_layout="tp" if tp_weight_bytes <= 12e9 else "tp2d",
    )
    m = MeshInfo(mesh, opts)
    if opt:
        changes = {}
        if cfg.num_experts and shape.kind == "train":
            changes["moe_dispatch_groups"] = m.data
        if shape.kind in ("train", "prefill") and \
                not m.div(cfg.num_heads, "model"):
            changes["context_parallel_attn"] = True
        if shape.kind == "train":
            changes["loss_chunk"] = 512
        if changes:
            cfg = dataclasses.replace(cfg, **changes)
    # int8 KV cache on the serving plane (memory fit for 90B-class decode)
    specs = input_specs(cfg, shape, m,
                        kv_quant=opt and shape.kind == "decode")

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            state = abstract_train_state(cfg, m)
            step = make_train_step(cfg, jit=False)
            lowered = jax.jit(step).lower(state, specs["batch"])
        elif shape.kind == "prefill":
            params = abstract_params(cfg, m)
            step = make_prefill_step(cfg, jit=False)
            lowered = jax.jit(step).lower(params, specs["batch"])
        else:  # decode
            params = abstract_params(cfg, m)
            step = make_serve_step(cfg, jit=False)
            lowered = jax.jit(step).lower(params, specs["cache"],
                                          specs["tokens"], specs["pos"])
    return lowered, cfg, shape, m


class SkipPair(Exception):
    pass


def model_flops(cfg, shape) -> float:
    """MFU convention: 6·N_active·tokens (train), 2·N_active·tokens
    (inference); attention score FLOPs not counted."""
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch        # decode: 1 token/seq


def analyze(lowered, compiled, cfg, shape, m, *, compile_s: float) -> dict:
    from repro.launch.cost_model import (activation_estimate,
                                         analytic_hbm_bytes, corrected_cost)

    n_dev = m.mesh.devices.size
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll_raw = parse_collectives(hlo)

    # cost_analysis reports the per-device SPMD program and counts scan
    # bodies once; corrected_cost adds (repeats-1) x per-segment body cost.
    cost, cost_detail = corrected_cost(compiled, cfg, m, shape)
    flops_global = cost.flops_per_device * n_dev
    bytes_global = cost.bytes_per_device * n_dev
    coll_bytes_dev = cost.collective_operand_bytes_per_device

    compute_s = flops_global / (n_dev * PEAK_FLOPS_BF16)
    # memory term: analytic (fusion-aware) estimate; the XLA no-fusion
    # number is recorded alongside as an upper bound.
    bytes_est = analytic_hbm_bytes(cfg, shape, m,
                                   mem.argument_size_in_bytes)
    memory_s = bytes_est / HBM_BW
    memory_s_xla = cost.bytes_per_device / HBM_BW
    collective_s = coll_bytes_dev / ICI_LINK_BW   # per-device link traffic

    mf = model_flops(cfg, shape)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        "devices": n_dev,
        "compile_seconds": compile_s,
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            # CPU-backend buffer accounting: sum of all buffers, no
            # liveness reuse — an upper bound, NOT a peak (see cost_model).
            "temp_bytes_upper_bound": mem.temp_size_in_bytes,
            "activation_estimate": activation_estimate(cfg, shape, m),
        },
        "cost": {
            "flops_per_device": cost.flops_per_device,
            "flops_global": flops_global,
            "bytes_per_device": cost.bytes_per_device,
            "bytes_global": bytes_global,
            "scan_correction": cost_detail,
        },
        "collectives": {
            **coll_raw.as_dict(),
            "scan_corrected_operand_bytes": coll_bytes_dev,
            "scan_corrected_counts": cost.collective_counts,
        },
        "roofline": {
            **terms,
            "memory_s_xla_upper_bound": memory_s_xla,
            "hbm_bytes_est_per_device": bytes_est,
            "dominant": dominant,
            "model_flops": mf,
            "useful_flops_ratio": mf / flops_global if flops_global else 0.0,
        },
    }


def run_pair(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, verbose: bool = True, opt: bool = False) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    t0 = time.time()
    try:
        lowered, cfg, shape, m = lower_pair(arch, shape_name,
                                            multi_pod=multi_pod, opt=opt)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        result = analyze(lowered, compiled, cfg, shape, m,
                         compile_s=t_compile - t_lower)
        result.update({"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                       "status": "ok", "lower_seconds": t_lower - t0})
        if verbose:
            print(f"== {tag} ==")
            print(compiled.memory_analysis())
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
    except SkipPair as e:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "status": "skip", "reason": str(e)}
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
            json.dump(result, f, indent=2, default=str)
    status = result["status"]
    extra = ""
    if status == "ok":
        r = result["roofline"]
        extra = (f" dominant={r['dominant']} compute={r['compute_s']:.4f}s"
                 f" memory={r['memory_s']:.4f}s"
                 f" collective={r['collective_s']:.4f}s"
                 f" useful={r['useful_flops_ratio']:.2f}")
    elif status == "error":
        extra = " " + result["error"][:200]
    elif status == "skip":
        extra = " " + result["reason"][:80]
    print(f"[{status}] {tag}{extra}", flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on this mesh")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper layout optimizations (see §Perf)")
    ap.add_argument("--out", default="benchmarks/artifacts/baseline")
    args = ap.parse_args()

    if args.all:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                run_pair(arch, shape_name, multi_pod=args.multi_pod,
                         out_dir=args.out, opt=args.opt)
        return
    assert args.arch and args.shape, "--arch/--shape or --all"
    run_pair(args.arch, args.shape, multi_pod=args.multi_pod,
             out_dir=args.out, opt=args.opt)


if __name__ == "__main__":
    main()
