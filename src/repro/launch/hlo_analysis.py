"""Roofline-term extraction from the compiled SPMD executable.

``cost_analysis`` gives HLO FLOPs and bytes; collective traffic is NOT in
cost_analysis, so we parse the post-partitioning HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (per spec; bytes are per-device program traffic).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# instruction definition: %name = dtype[dims]{layout} opcode(args)
_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\(([^)]*)\)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([^\]]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[4,128]{1,0}' or tuple '(f32[2], s32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)      # op -> #instructions
    operand_bytes: dict = field(default_factory=dict)
    result_bytes: dict = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    def as_dict(self) -> dict:
        return {"counts": dict(self.counts),
                "operand_bytes": dict(self.operand_bytes),
                "result_bytes": dict(self.result_bytes),
                "total_operand_bytes": self.total_operand_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    # first pass: map every defined value name -> its shape string
    shapes: dict[str, str] = {}
    for m in _DEF_RE.finditer(hlo_text):
        shapes[m.group(1)] = m.group(2)

    stats = CollectiveStats()
    for m in _DEF_RE.finditer(hlo_text):
        name, result_shape, opcode, args = m.groups()
        base = opcode.removesuffix("-start").removesuffix("-done")
        if base not in COLLECTIVE_OPS:
            continue
        if opcode.endswith("-done"):
            continue                       # avoid double count of async pairs
        stats.counts[base] = stats.counts.get(base, 0) + 1
        ob = 0
        for arg in args.split(","):
            arg = arg.strip().lstrip("%")
            # args may be 'bf16[2,4] %name' or just '%name'
            arg_name = arg.split(" ")[-1].lstrip("%")
            if arg_name in shapes:
                ob += _shape_bytes(shapes[arg_name])
            else:
                ob += _shape_bytes(arg)
        stats.operand_bytes[base] = stats.operand_bytes.get(base, 0) + ob
        stats.result_bytes[base] = (stats.result_bytes.get(base, 0)
                                    + _shape_bytes(result_shape))
    return stats


def count_hlo_ops(hlo_text: str, opcodes: tuple[str, ...]) -> dict[str, int]:
    """Counts of specific opcodes (e.g. 'fusion', 'while', 'dot') — used by
    the perf loop to spot remat recompute and layout churn."""
    out: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        op = m.group(3)
        if op in opcodes:
            out[op] = out.get(op, 0) + 1
    return out
