"""Production meshes. Functions, not module-level constants — importing
this module never touches jax device state (device count is locked at
first backend init; the dry-run sets XLA_FLAGS before importing jax)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.
    The ``pod`` axis joins batch/data sharding only (pure DP across pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_LINK_BW = 50e9                # bytes/s per link


# ---------------------------------------------------------------------------
# process placement (multi-process cluster runtime, launch/runtime.py)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ProcSlot:
    """One logical position in the process grid: a master shard or one
    replica of a slave shard. ``replica`` is None for masters (masters are
    cold-backed by checkpoints, not replicated)."""

    role: str                 # "master" | "slave"
    shard_id: int
    replica: Optional[int] = None

    @property
    def name(self) -> str:
        if self.role == "master":
            return f"master-{self.shard_id}"
        return f"slave-{self.shard_id}.{self.replica}"


@dataclass(frozen=True)
class ProcessMesh:
    """The process-grid analogue of the device mesh: masters along one
    axis, (slave shard x replica) along the other two. The runtime spawns
    one OS process per slot; elastic replica add/remove appends or drops
    slots on the replica axis only (shard axes are fixed by the routing
    plan's partition congruence)."""

    num_master: int
    num_slave: int
    num_replicas: int

    def masters(self) -> list[ProcSlot]:
        return [ProcSlot("master", m) for m in range(self.num_master)]

    def slaves(self) -> list[ProcSlot]:
        return [ProcSlot("slave", s, r) for s in range(self.num_slave)
                for r in range(self.num_replicas)]

    def slots(self) -> list[ProcSlot]:
        return self.masters() + self.slaves()


def make_process_mesh(num_master: int, num_slave: int,
                      num_replicas: int = 1) -> ProcessMesh:
    assert num_master >= 1 and num_slave >= 1 and num_replicas >= 1
    return ProcessMesh(num_master, num_slave, num_replicas)
