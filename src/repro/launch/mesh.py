"""Production meshes. Functions, not module-level constants — importing
this module never touches jax device state (device count is locked at
first backend init; the dry-run sets XLA_FLAGS before importing jax)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.
    The ``pod`` axis joins batch/data sharding only (pure DP across pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_LINK_BW = 50e9                # bytes/s per link
