"""Multi-process WeiPS cluster runtime: a supervisor that launches one OS
process per master/slave shard (``launch/worker.py``) over the placement
from ``launch/mesh.py`` + ``launch/specs.py``, drives a deterministic
training loop over RPC, and supervises faults — detect death, restore
from the manifest-committed checkpoint chain, seek scatters to checkpoint
queue offsets, replay, and fire domino downgrade off the streaming
evaluator.

Determinism contract (what makes the chaos tests reproducible):

  * the supervisor drives every worker serially — one RPC in flight at a
    time, so there is no request interleaving to race;
  * training batches are a pure function of ``(cfg.seed, step)``
    (``ClusterRuntime._batch``), so rewinding the step clock and
    replaying regenerates the *identical* gradient stream;
  * a restored ``Pusher`` re-emits the same per-group seqs for replayed
    flushes, so slaves LWW-skip (or idempotently re-apply) replayed
    records — post-recovery table state is bit-equal to a fault-free run;
  * fault events fire on exact (target, point, step) coordinates and the
    supervisor re-arms only *unfired* events on respawn, so a kill does
    not re-fire while the recovered cluster replays the step that died.

Supervisor state machine (see docs/FAULT_TOLERANCE.md):

    RUNNING --WorkerDied--> DETECT (reap dead procs, consume their kills)
            --> RESTORE (respawn; restore ALL masters from the latest
                committed manifest; bootstrap dead slaves from the
                materialized chain + seek to checkpoint queue offsets)
            --> CATCHUP (rewind the step clock to the manifest cut and
                replay; evaluator/checkpoint/downgrade are muted for
                already-observed steps) --> RUNNING
"""

from __future__ import annotations

import json
import os
import pickle
import subprocess
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.downgrade import (DominoDowngrade, SmoothedThresholdTrigger,
                                  VersionManager)
from repro.core.fault_tolerance import fold_chain
from repro.core.monitor import StreamingEvaluator
from repro.core.queue import FileQueue
from repro.core.routing import RoutingPlan
from repro.launch.chaos import FaultPlan
from repro.launch.mesh import ProcSlot, make_process_mesh
from repro.launch.specs import ProcSpec, plan_cluster_procs, proc_spec_for
from repro.launch.transport import RpcClient, WorkerDied
from repro.obs import perfetto
from repro.obs import trace as obs_trace


@dataclass
class RuntimeConfig:
    """Shape + schedule of one multi-process cluster run."""

    root: str                          # runtime dir (queue/ckpt/sock/logs)
    num_master: int = 2
    num_slave: int = 2
    num_replicas: int = 1
    num_partitions: int = 4
    groups: dict = field(default_factory=lambda: {"emb": 1})
    optimizer: str = "ftrl"
    optimizer_kwargs: dict = field(default_factory=dict)
    codec: str = "identity"
    seed: int = 0
    batch_size: int = 32
    vocab: int = 512                   # sparse id space
    feats_per_sample: int = 8
    ckpt_every: int = 5                # steps between checkpoint cuts
    full_every: int = 3                # every Nth checkpoint is full
    trigger_threshold: float = 10.0    # smoothed logloss downgrade trigger
    trigger_window: int = 5
    trigger_min_points: int = 3
    downgrade_cooldown: float = 5.0    # sim-seconds (= steps)
    connect_timeout: float = 120.0     # workers pay the jax import
    trace: bool = False                # span tracing in every process
    trace_capacity: int = 1 << 15      # per-process span ring size
    serve_cache_rows: int = 1 << 16    # slave serve cache (0 disables)


@dataclass
class Manifest:
    """One committed checkpoint version: per-shard part files + the queue
    cut. Duck-types ``Checkpoint`` where ``VersionManager`` needs it
    (``metrics`` for best-metric picks); the commit is the atomic rename
    of the manifest JSON — part files without a manifest are invisible,
    which is exactly what keeps a kill mid-checkpoint harmless."""

    version: int
    kind: str                          # "full" | "delta"
    base: Optional[int]                # previous version (delta chains)
    step: int                          # driver step to resume from
    queue_offsets: dict                # partition -> produced offset at cut
    parts: dict                        # shard_id -> part file name
    metrics: dict = field(default_factory=dict)


class ManifestStore:
    """Checkpoint-chain storage for the multi-process runtime. Part files
    are written by the master workers (tmp + atomic rename); the
    supervisor commits the version by atomically renaming the manifest
    JSON into place. Duck-types ``CheckpointStore`` for the core
    ``VersionManager``/``DominoDowngrade`` (``versions()``/``load()``)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _manifest_path(self, version: int) -> str:
        return os.path.join(self.root, f"v{version}.json")

    def part_path(self, version: int, shard_id: int) -> str:
        return os.path.join(self.root, f"v{version}-shard{shard_id}.pkl")

    def versions(self) -> list[int]:
        out = []
        for f in os.listdir(self.root):
            if f.startswith("v") and f.endswith(".json"):
                try:
                    out.append(int(f[1:-5]))
                except ValueError:
                    continue
        return sorted(out)

    def latest(self) -> Optional[int]:
        vs = self.versions()
        return vs[-1] if vs else None

    def load(self, version: int) -> Manifest:
        with open(self._manifest_path(version)) as f:
            d = json.load(f)
        return Manifest(
            version=d["version"], kind=d["kind"], base=d["base"],
            step=d["step"],
            queue_offsets={int(k): int(v)
                           for k, v in d["queue_offsets"].items()},
            parts={int(k): v for k, v in d["parts"].items()},
            metrics=d.get("metrics", {}))

    def commit(self, man: Manifest) -> None:
        path = self._manifest_path(man.version)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": man.version, "kind": man.kind,
                       "base": man.base, "step": man.step,
                       "queue_offsets": man.queue_offsets,
                       "parts": man.parts, "metrics": man.metrics},
                      f, indent=2, sort_keys=True)
        os.replace(tmp, path)

    def chain(self, version: int) -> list[Manifest]:
        """Manifests oldest-first from the nearest full up to ``version``."""
        chain = [self.load(version)]
        while chain[-1].kind != "full":
            assert chain[-1].base is not None, \
                f"delta v{chain[-1].version} has no base"
            chain.append(self.load(chain[-1].base))
        chain.reverse()
        return chain

    def materialize(self, version: int):
        """Fold the chain into full-equivalent per-shard snapshots plus
        the pusher seqs at the tip cut. Returns ``(snaps, seqs)`` with
        ``snaps[shard_id]`` in ``MasterShard.load_snapshot`` format."""
        links, seqs = [], {}
        for man in self.chain(version):
            link = {}
            for sid, fname in man.parts.items():
                with open(os.path.join(self.root, fname), "rb") as f:
                    part = pickle.load(f)
                link[sid] = part["snap"]
                seqs[sid] = part["pusher_seqs"]   # tip link wins
            links.append(link)
        return fold_chain(links), seqs


class ClusterRuntime:
    """Launcher + supervisor for the process-per-shard WeiPS cluster."""

    def __init__(self, cfg: RuntimeConfig,
                 plan: Optional[FaultPlan] = None):
        self.cfg = cfg
        self.plan = plan or FaultPlan(seed=cfg.seed, events=[])
        os.makedirs(cfg.root, exist_ok=True)
        for sub in ("queue", "ckpt", "sock", "logs"):
            os.makedirs(os.path.join(cfg.root, sub), exist_ok=True)
        with open(os.path.join(cfg.root, "runtime.json"), "w") as f:
            json.dump({"num_master": cfg.num_master,
                       "num_slave": cfg.num_slave,
                       "num_partitions": cfg.num_partitions,
                       "groups": cfg.groups, "optimizer": cfg.optimizer,
                       "optimizer_kwargs": cfg.optimizer_kwargs,
                       "codec": cfg.codec, "gather_mode": "realtime",
                       "trace": cfg.trace,
                       "trace_capacity": cfg.trace_capacity,
                       "serve_cache_rows": cfg.serve_cache_rows},
                      f, indent=2, sort_keys=True)
        with open(os.path.join(cfg.root, "fault_plan.json"), "w") as f:
            f.write(self.plan.to_json())
        self.routing = RoutingPlan(cfg.num_master, cfg.num_slave,
                                   cfg.num_partitions)
        # creating the supervisor's queue handle first writes meta.json,
        # which the workers' handles validate against
        self.queue = FileQueue(os.path.join(cfg.root, "queue"),
                               cfg.num_partitions)
        self.pmesh = make_process_mesh(cfg.num_master, cfg.num_slave,
                                       cfg.num_replicas)
        self.specs: dict[str, ProcSpec] = {
            s.name: s for s in plan_cluster_procs(self.pmesh, cfg.root)}
        self.procs: dict[str, subprocess.Popen] = {}
        self.clients: dict[str, RpcClient] = {}
        self.store = ManifestStore(os.path.join(cfg.root, "ckpt"))
        self.versions = VersionManager(self.store)
        self.evaluator = StreamingEvaluator(window=cfg.trigger_window * 4)
        self.downgrader = DominoDowngrade(
            SmoothedThresholdTrigger(
                metric="logloss", threshold=cfg.trigger_threshold,
                window=cfg.trigger_window, direction="above",
                min_points=cfg.trigger_min_points),
            self.versions, self._hot_switch,
            cooldown=cfg.downgrade_cooldown)
        self.step = 0
        self.recoveries = 0
        self._fired: set = set()          # supervisor-consumed FaultEvents
        self._replaying_until = 0         # steps < this replay (muted)
        self._force_full = False
        # the regression target the labels are drawn from — fixed per
        # seed, so the model actually learns and logloss moves
        rng = np.random.default_rng(cfg.seed)
        self._w_true = rng.normal(0.0, 0.5, size=cfg.vocab)
        self._log_f = open(os.path.join(cfg.root, "logs", "supervisor.log"),
                           "a", buffering=1)
        os.makedirs(os.path.join(cfg.root, "trace"), exist_ok=True)
        if cfg.trace:
            obs_trace.configure(enabled=True, process="supervisor",
                                capacity=cfg.trace_capacity)

    # -- logging ---------------------------------------------------------
    def _log(self, msg: str) -> None:
        self._log_f.write(f"[step {self.step}] {msg}\n")

    # -- process lifecycle -----------------------------------------------
    def _spawn(self, spec: ProcSpec) -> None:
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        log = open(spec.log_path, "ab", buffering=0)
        self.procs[spec.name] = subprocess.Popen(
            spec.argv, stdout=log, stderr=subprocess.STDOUT, env=env)
        log.close()
        c = RpcClient(spec.socket, connect_timeout=self.cfg.connect_timeout)
        self.clients[spec.name] = c

    def _connect(self, name: str) -> None:
        self.clients[name].connect()
        self.clients[name].call("ping")
        self._arm(name)

    def _arm(self, name: str) -> None:
        """Arm the plan's events for one worker, minus those the
        supervisor already saw fire — the no-refire-during-replay rule."""
        from dataclasses import asdict
        events = [asdict(e) for e in self.plan.for_target(name)
                  if e not in self._fired]
        self.clients[name].call("arm", events=events)

    def master_names(self) -> list[str]:
        return [s.name for s in self.pmesh.masters()]

    def slave_names(self) -> list[str]:
        return [n for n in self.specs if n.startswith("slave-")]

    def start(self) -> None:
        """Spawn + connect the whole grid (parallel spawn, serial connect
        — the jax import dominates startup and overlaps across workers),
        then cut the bootstrap checkpoint v1 at step 0 so recovery always
        has a restore point."""
        for spec in self.specs.values():
            self._spawn(spec)
        for name in self.specs:
            self._connect(name)
        self._log(f"cluster up: {sorted(self.procs)}")
        self.checkpoint(force_full=True)

    def shutdown(self) -> None:
        for name, c in self.clients.items():
            try:
                c.call("shutdown")
            except (WorkerDied, RuntimeError):
                pass
            c.close()
        for name, p in self.procs.items():
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
        self.queue.close()
        self._log_f.close()

    # -- deterministic data plane ----------------------------------------
    def _batch(self, step: int):
        """Pure function of (seed, step): feature ids + labels drawn from
        the fixed linear teacher, so replay regenerates identical data
        and the learned logloss trends down (the downgrade trigger's
        signal)."""
        c = self.cfg
        rng = np.random.default_rng(c.seed * 1_000_003 + step)
        ids = rng.integers(0, c.vocab, size=(c.batch_size,
                                             c.feats_per_sample))
        logit = self._w_true[ids].sum(axis=1)
        y = (logit > 0.0).astype(np.float32)
        return ids.astype(np.int64), y

    def _pull_w(self, flat_ids: np.ndarray) -> np.ndarray:
        w = np.zeros(len(flat_ids), np.float32)
        owner = self.routing.master_shard(flat_ids)
        for m, name in enumerate(self.master_names()):
            mask = owner == m
            if mask.any():
                rows = self.clients[name].call(
                    "pull", group="emb", ids=flat_ids[mask])
                w[mask] = np.asarray(rows, np.float32).reshape(-1)
        return w

    def step_once(self) -> dict:
        """One supervisor-driven training step: pull → predict → observe →
        apply → flush → scatter-poll → maybe checkpoint → maybe downgrade.
        Raises ``WorkerDied`` when a fault event kills a worker mid-step —
        the caller (``run_to``) routes that into ``recover``."""
        c, step = self.cfg, self.step
        now = float(step)
        tr = obs_trace.get_tracer()
        t_step = tr.clock() if tr.enabled else 0.0
        replaying = step < self._replaying_until
        ids, y = self._batch(step)
        flat = ids.reshape(-1)
        w = self._pull_w(flat)
        logits = w.reshape(ids.shape).sum(axis=1)
        p = 1.0 / (1.0 + np.exp(-logits))
        if not replaying:
            self.evaluator.observe(t=now, step=step, y=y, p=p)
        grads = np.repeat(p - y, c.feats_per_sample).astype(np.float32)
        owner = self.routing.master_shard(flat)
        for m, name in enumerate(self.master_names()):
            mask = owner == m
            if mask.any():
                self.clients[name].call(
                    "apply", group="emb", ids=flat[mask],
                    grads=grads[mask][:, None], step=step)
        pushed = 0
        for name in self.master_names():
            pushed += self.clients[name].call("flush", step=step, now=now)
        applied = 0
        for name in self.slave_names():
            applied += self.clients[name].call("poll", step=step, now=now)
        self.step = step + 1
        if not replaying and self.step % c.ckpt_every == 0:
            self.checkpoint()
        if self.step >= self._replaying_until:
            v = self.downgrader.maybe_downgrade(now, self.evaluator)
            if v is not None:
                self._log(f"domino downgrade -> v{v}")
        if tr.enabled:
            tr.record("driver.step", t0=t_step, t1=tr.clock(), step=step,
                      pushed=pushed, applied=applied)
        return {"step": step, "pushed": pushed, "applied": applied,
                "p": p}

    def run_to(self, step: int) -> None:
        """Drive the cluster to ``step``, recovering from every injected
        death along the way. This loop IS the supervisor state machine:
        RUNNING (step_once) → DETECT/RESTORE/CATCHUP (recover) →
        RUNNING."""
        while self.step < step:
            try:
                self.step_once()
            except WorkerDied as e:
                self._log(f"worker death detected: {e}")
                self.recover()

    # -- checkpointing ----------------------------------------------------
    def _next_version(self) -> int:
        latest = self.store.latest()
        return 1 if latest is None else latest + 1

    def checkpoint(self, force_full: bool = False) -> int:
        """Cut a distributed checkpoint: every master writes its part
        (tmp + atomic rename), then the supervisor commits the manifest.
        The queue cut is the produced offsets at this instant — every
        record a restored state has already folded in sits below it."""
        tr = obs_trace.get_tracer()
        t_ckpt = tr.clock() if tr.enabled else 0.0
        v = self._next_version()
        latest = self.store.latest()
        kind = "full" if (force_full or self._force_full or latest is None
                          or len(self.store.versions()) % self.cfg.full_every
                          == 0) else "delta"
        parts, kinds = {}, []
        for m, name in enumerate(self.master_names()):
            path = self.store.part_path(v, m)
            res = self.clients[name].call(
                "checkpoint_part", version=v, kind=kind, path=path,
                step=self.step)
            kinds.append(res["kind"])
            parts[m] = os.path.basename(path)
        kind = "full" if all(k == "full" for k in kinds) else "delta"
        metrics = {}
        if self.evaluator.history:
            metrics["logloss"] = float(self.evaluator.smoothed("logloss"))
        man = Manifest(version=v, kind=kind,
                       base=latest if kind == "delta" else None,
                       step=self.step,
                       queue_offsets=self.queue.latest_offsets(),
                       parts=parts, metrics=metrics)
        self.store.commit(man)
        self.versions.current_version = v
        self._force_full = False
        if tr.enabled:
            tr.record("ckpt.commit", t0=t_ckpt, t1=tr.clock(), version=v,
                      kind=kind, step=self.step)
        self._log(f"checkpoint v{v} ({kind}) committed at step {self.step}")
        return v

    # -- fault recovery ----------------------------------------------------
    def _dead(self) -> list[str]:
        return [n for n, p in self.procs.items() if p.poll() is not None]

    def recover(self) -> None:
        """DETECT → RESTORE → CATCHUP. Respawn every dead process,
        restore ALL masters from the latest committed manifest (the
        trajectory-preserving cut), bootstrap dead slaves from the
        materialized chain + checkpoint queue offsets, rewind the step
        clock and let ``run_to`` replay the gap deterministically."""
        self.recoveries += 1
        tr = obs_trace.get_tracer()
        if tr.enabled:
            tr.instant("fault.detected", step=self.step)
        t_rec = tr.clock() if tr.enabled else 0.0
        # the socket EOF can beat the SIGKILLed child's exit becoming
        # visible to waitpid — give the reap a moment
        deadline = time.monotonic() + 10.0
        dead = self._dead()
        while not dead and time.monotonic() < deadline:
            time.sleep(0.02)
            dead = self._dead()
        assert dead, "recover() called with no dead workers"
        for name in dead:
            # consume this worker's already-fired events (anything armed
            # at or before the current step) so respawn does not re-fire
            # them during replay
            for e in self.plan.for_target(name):
                if e.step <= self.step:
                    self._fired.add(e)
            self.clients[name].close()
            self.procs[name].wait()
            self._log(f"respawning {name}")
            self._spawn(self.specs[name])
        for name in dead:
            self._connect(name)
        v = self.store.latest()
        assert v is not None, "no committed checkpoint to recover from"
        man = self.store.load(v)
        snaps, seqs = self.store.materialize(v)
        for m, name in enumerate(self.master_names()):
            self.clients[name].call(
                "restore", snap=snaps[m], pusher_seqs=seqs.get(m, {}),
                step=man.step)
        for name in dead:
            if name.startswith("slave-"):
                self._bootstrap_slave(name, man, snaps)
        self._replaying_until = max(self._replaying_until, self.step)
        self._log(f"restored from v{v}; rewinding step "
                  f"{self.step} -> {man.step} (replay)")
        if tr.enabled:
            tr.record("recover", t0=t_rec, t1=tr.clock(), version=v,
                      rewind_to=man.step, workers=",".join(sorted(dead)))
        self.step = man.step
        self._force_full = True

    def _bootstrap_slave(self, name: str, man: Manifest,
                         snaps: dict) -> None:
        """Serve-state bootstrap for a fresh/reborn replica: install the
        checkpoint's serve rows for the ids this shard owns, seek its
        scatter to the checkpoint's queue offsets, then poll — the live
        stream replays everything after the cut on top (full-value
        upserts, so racing the stream is safe)."""
        shard_id = int(name.split("-", 1)[1].split(".")[0])
        c = self.clients[name]
        for snap in snaps.values():
            for g, rows in snap["tables"].items():
                ids = np.asarray(rows["ids"], np.int64)
                if not len(ids):
                    continue
                keep = self.routing.slave_shard(ids) == shard_id
                if keep.any():
                    # FTRL stores the derived serve weight in w (same
                    # _np_weights the push transform runs), so the
                    # checkpoint's w column IS the serve value
                    c.call("load_group", group=g, ids=ids[keep],
                           values=np.asarray(rows["w"])[keep])
        c.call("seek", offsets=man.queue_offsets)
        c.call("poll", step=-1)        # catch-up; step -1 matches no event

    # -- domino downgrade --------------------------------------------------
    def _hot_switch(self, man: Manifest) -> None:
        """Downgrade switch_fn: reload every slave replica's serve state
        from the target version's chain and seek scatters to its queue
        offsets — the serving plane hops back to the stable version while
        masters keep training."""
        snaps, _seqs = self.store.materialize(man.version)
        for name in self.slave_names():
            self.clients[name].call("clear")
            self._bootstrap_slave(name, man, snaps)
        self._log(f"hot switch to v{man.version} complete")

    # -- elastic replicas --------------------------------------------------
    def add_replica(self, shard_id: int) -> str:
        """Add one slave replica at runtime: spawn, bootstrap from the
        latest committed checkpoint, catch up from the stream."""
        existing = [int(n.split(".")[1]) for n in self.slave_names()
                    if n.startswith(f"slave-{shard_id}.")]
        replica = max(existing) + 1 if existing else 0
        slot = ProcSlot("slave", shard_id, replica)
        spec = proc_spec_for(slot, self.cfg.root)
        self.specs[spec.name] = spec
        self._spawn(spec)
        self._connect(spec.name)
        v = self.store.latest()
        if v is not None:
            man = self.store.load(v)
            snaps, _ = self.store.materialize(v)
            self._bootstrap_slave(spec.name, man, snaps)
        self._log(f"replica {spec.name} joined")
        return spec.name

    def remove_replica(self, name: str) -> None:
        """Drain one slave replica out of the grid."""
        assert name.startswith("slave-"), name
        c = self.clients.pop(name)
        try:
            c.call("shutdown")
        except (WorkerDied, RuntimeError):
            pass
        c.close()
        p = self.procs.pop(name)
        try:
            p.wait(timeout=10)
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait()
        del self.specs[name]
        self._log(f"replica {name} removed")

    # -- observability -----------------------------------------------------
    def cluster_metrics(self) -> dict:
        """Supervisor-side aggregation: every worker's ``metrics`` RPC
        (each a ``MetricsRegistry.tree()``) keyed by name, plus sums the
        dashboards want. A dead worker is skipped, not fatal — metrics
        must stay readable mid-fault."""
        workers: dict = {}
        for name, c in self.clients.items():
            try:
                workers[name] = c.call("metrics")
            except (WorkerDied, RuntimeError, OSError):
                workers[name] = None
        live = {n: m for n, m in workers.items() if m is not None}
        agg = {
            "pushed_records": sum(m.get("pushed_records", 0)
                                  for m in live.values()),
            "pushed_bytes": sum(m.get("pushed_bytes", 0)
                                for m in live.values()),
            "applied": sum(m.get("applied", 0) for m in live.values()),
            "skipped": sum(m.get("skipped", 0) for m in live.values()),
            "staleness_p99": max(
                (m["staleness"].get("p99", 0.0) or 0.0
                 for m in live.values() if "staleness" in m),
                default=0.0),
        }
        return {"step": self.step, "recoveries": self.recoveries,
                "workers": workers, "aggregate": agg}

    def export_trace(self, path: str) -> int:
        """Merge the supervisor's spans, every live worker's ring
        (``trace_dump`` RPC), and the pre-kill dump files killed workers
        left under ``<root>/trace/`` into one Perfetto JSON at ``path``.
        Returns the number of exported events."""
        lists = [obs_trace.get_tracer().export()]
        for name, c in self.clients.items():
            try:
                lists.append(c.call("trace_dump"))
            except (WorkerDied, RuntimeError, OSError):
                pass
        dump_dir = os.path.join(self.cfg.root, "trace")
        for f in sorted(os.listdir(dump_dir)):
            if not f.endswith(".json"):
                continue
            try:
                with open(os.path.join(dump_dir, f)) as fh:
                    lists.append(json.load(fh))
            except (OSError, ValueError):
                continue
        spans = perfetto.merge_spans(*lists)
        return perfetto.write_trace(path, spans)

    # -- state inspection (tests) ------------------------------------------
    def master_state(self, group: str = "emb") -> dict:
        return {n: self.clients[n].call("table_state", group=group)
                for n in self.master_names()}

    def slave_state(self, group: str = "emb") -> dict:
        return {n: self.clients[n].call("table_state", group=group)
                for n in self.slave_names()}

    def __enter__(self) -> "ClusterRuntime":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
