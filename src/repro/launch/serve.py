"""Serving launcher: batched greedy decode with WeiPS hot weight updates
applied between steps (second-level deployment while serving).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --steps 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import init_params, precompute_cross_cache
from repro.serving.predictor import ServeDriver


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--hot-swap-every", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    driver = ServeDriver(cfg=cfg, params=params, batch=args.batch,
                         max_len=args.max_len, cache_dtype=jnp.float32)
    if cfg.has_encoder_context:
        enc = jax.random.normal(
            key, (args.batch, cfg.encoder_len, cfg.d_model))
        driver.cache = precompute_cross_cache(params, cfg, driver.cache, enc)

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    lat = []
    for i in range(args.steps):
        t0 = time.time()
        tok = driver.step(tok)
        lat.append(time.time() - t0)
        if args.hot_swap_every and (i + 1) % args.hot_swap_every == 0:
            # simulate a streamed weight update arriving mid-decode
            key, sub = jax.random.split(key)
            new_params = jax.tree.map(
                lambda p: p + 0.001 * jax.random.normal(
                    sub, p.shape, p.dtype).astype(p.dtype)
                if p.ndim >= 2 else p, params)
            driver.hot_swap(new_params)
            print(f"step {i}: hot-swapped serve weights (lat so far "
                  f"p50={np.median(lat)*1e3:.1f}ms)")
    gen = np.stack(driver.generated, axis=1)
    print(f"generated shape={gen.shape}; "
          f"decode p50={np.median(lat)*1e3:.1f}ms p99={np.quantile(lat, .99)*1e3:.1f}ms")


if __name__ == "__main__":
    main()
