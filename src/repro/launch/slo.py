"""Closed-loop SLO load harness — the ROADMAP "production-shape SLO"
item, and the first driver that exercises the serve and train planes
*concurrently* against one shared PS.

Every tick of the loop interleaves the full production shape:

    1. deploy   — poll every scatter consumer (updates pushed during the
                  previous tick become cache-visible; event→deployed
                  staleness = poll_now − push-stamped ``meta["t"]``)
    2. offer    — seeded Zipf predict requests per scenario are admitted
                  into the predict scheduler (``submit``), where the
                  admission policy may depth-shed the oldest tickets
    3. serve    — ``flush(budget=...)`` executes up to the service
                  budget; offered load beyond it stays queued, so
                  overload shows up as queue depth → latency → sheds
                  instead of being hidden by an unbounded drain
    4. train    — stream events ingest into the sample joiner; matured
                  feedback joins; full buckets train and push gradients
    5. push     — the sync plane batches the tick's updates into the
                  queue (their scatter waits for the NEXT tick's deploy
                  step, which is what makes staleness non-trivial)

Offered load is expressed as a multiplier of the per-tick service
budget: 0.5x is an underloaded plane (p50 == p99 == service time), 2x+
is sustained overload where the depth bound must convert queue growth
into counted sheds and a *bounded* p99 — the graceful-degradation claim
the benchmark (``benchmarks/e2e_slo.py``) sweeps and the deterministic
tests (``tests/test_slo_harness.py``) replay with a ``ManualClock``.

The table is pre-seeded to ``cfg.rows`` serve rows (≥1M in the full
benchmark) so the Zipf head hits a realistic id cardinality, and two
scenarios (the FM store + an LR head sharing its ``w`` group) serve and
train at the same time — multi-scenario contention on one PS, not a
single-model microbenchmark.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

from repro.configs.weips_ctr import FM_FTRL, LR_FTRL
from repro.core.cluster import ClusterConfig, WeiPSCluster
from repro.core.monitor import PercentileRing
from repro.data.streams import ClickStream


@dataclass
class SLOConfig:
    """Knobs for one harness instance (see docs/BENCHMARKS.md)."""

    rows: int = 1 << 20             # pre-seeded serve-table id space
    fields: int = 8                 # feature fields per example
    zipf_a: float = 1.2             # request/traffic skew
    req_batch: int = 128            # examples per predict request
    budget: int = 2048              # serve budget per scenario per tick
    train_events: int = 512         # stream events ingested per tick
    warmup_ticks: int = 4
    measure_ticks: int = 16
    max_pending: Optional[int] = None   # admission depth bound (examples)
    deadline: Optional[float] = None    # admission deadline (seconds)
    feedback_delay: float = 0.005   # exposure→feedback gap (seconds) —
    #                                 sub-tick so clicks mature in wall time
    join_window: float = 0.05       # sample-join window (seconds)
    num_master: int = 2
    num_slave: int = 2
    num_replicas: int = 2
    lr_head: bool = True            # second scenario (LR on the FM store)
    seed: int = 0


class SLOHarness:
    """One cluster + N scenarios + seeded traffic, driven tick by tick.

    ``clock`` defaults to wall time (``time.perf_counter``); inject a
    :class:`~repro.core.monitor.ManualClock` plus ``tick_dt`` and the
    whole loop — admission stamps, deadline sheds, latency percentiles,
    staleness — replays in exact simulated seconds.
    """

    def __init__(self, cfg: Optional[SLOConfig] = None, *,
                 clock=None, tick_dt: Optional[float] = None):
        self.cfg = cfg or SLOConfig()
        c = self.cfg
        self.clock = clock or time.perf_counter
        self.tick_dt = tick_dt
        # size the model configs to the harness's traffic shape (the
        # presets assume 32 fields / 4M-id space)
        fm = replace(FM_FTRL, fields=c.fields, feature_space=c.rows)
        lr = replace(LR_FTRL, fields=c.fields, feature_space=c.rows)
        self.cluster = WeiPSCluster(fm, ClusterConfig(
            num_master=c.num_master, num_slave=c.num_slave,
            num_replicas=c.num_replicas, join_window=c.join_window,
            serve_max_pending=c.max_pending, serve_deadline=c.deadline,
            seed=c.seed), clock=clock)
        # scenario roster: the FM store itself + an LR head refining the
        # store's own "w" group (serve AND train concurrently)
        self.serve_names = [fm.name]
        # emit-on-feedback: positives train as their click matures (the
        # paper's timeliness point) — without it a wall-clock run this
        # short would never see the join window expire
        self.train_pipes = [(fm.name, self.cluster.make_train_pipeline(
            emit_on_feedback=True))]
        if c.lr_head:
            self.cluster.add_scenario(lr)
            lr_scn = self.cluster.add_train_scenario(lr,
                                                     share_groups=True)
            self.serve_names.append(lr.name)
            self.train_pipes.append(
                (lr_scn.name,
                 self.cluster.make_train_pipeline(
                     lr_scn.name, emit_on_feedback=True)))
        # seeded, independent traffic sources per role
        self.serve_streams = {
            name: ClickStream(feature_space=c.rows, fields=c.fields,
                              zipf_a=c.zipf_a, seed=c.seed + 101 + i)
            for i, name in enumerate(self.serve_names)}
        self.train_streams = {
            name: ClickStream(feature_space=c.rows, fields=c.fields,
                              zipf_a=c.zipf_a,
                              feedback_delay=c.feedback_delay,
                              seed=c.seed + 201 + i)
            for i, (name, _) in enumerate(self.train_pipes)}
        self._preseed()
        self.train_batches = 0

    # ------------------------------------------------------------------
    # setup
    # ------------------------------------------------------------------
    def _preseed(self) -> None:
        """Install ``cfg.rows`` serve rows on every slave replica (all
        replicas of a shard get identical values — they are supposed to
        be copies) so predicts hit a populated table from tick 0 instead
        of measuring an empty-store cold start."""
        c = self.cfg
        rng = np.random.default_rng(c.seed + 7)
        ids = np.arange(c.rows, dtype=np.int64)
        owner = self.cluster.plan.slave_shard(ids)
        for sid, rs in enumerate(self.cluster.replica_sets):
            owned = ids[owner == sid]
            if not len(owned):
                continue
            for g, dim in self.cluster.groups.items():
                vals = rng.normal(scale=0.05,
                                  size=(len(owned), dim)).astype(np.float32)
                for shard in rs.replicas:
                    shard.tables[g].scatter(owned, vals)

    # ------------------------------------------------------------------
    # drive
    # ------------------------------------------------------------------
    def _advance(self) -> None:
        if self.tick_dt is not None and hasattr(self.clock, "advance"):
            self.clock.advance(self.tick_dt)

    def requests_per_tick(self, multiplier: float) -> int:
        """Offered requests per scenario per tick for a budget multiple."""
        c = self.cfg
        return max(1, int(round(multiplier * c.budget / c.req_batch)))

    def tick(self, multiplier: float = 1.0) -> dict:
        """One closed-loop tick (deploy → offer → serve → train → push).
        Returns the tick's flush results per scenario (``None`` slots are
        shed tickets)."""
        c = self.cfg
        now = self.clock()
        for sc in self.cluster.scatters:            # 1. deploy
            if sc.shard.alive:
                sc.poll(now=now)
        n_req = self.requests_per_tick(multiplier)
        for name, stream in self.serve_streams.items():   # 2. offer
            for _ in range(n_req):
                self.cluster.serving.submit(stream.features(c.req_batch),
                                            scenario=name)
        flushed = {}
        for name in self.serve_names:               # 3. serve
            flushed[name] = self.cluster.serving.flush(name,
                                                       budget=c.budget)
        for name, pipe in self.train_pipes:         # 4. train
            pipe.ingest(self.train_streams[name].events_batch(
                c.train_events, self.clock()))
            self.train_batches += len(pipe.tick(self.clock()))
        self.cluster.sync_tick(self.clock(), scatter=False)   # 5. push
        self._advance()
        return flushed

    # ------------------------------------------------------------------
    # measurement
    # ------------------------------------------------------------------
    def _schedulers(self):
        return [self.cluster.serving.scenario(n).scheduler
                for n in self.serve_names]

    def reset_window(self) -> None:
        """Start a measurement window: clear latency + staleness rings
        and advance every cache's window mark (lifetime counters and
        model state are untouched)."""
        for sched in self._schedulers():
            sched.latency.reset()
        for sc in self.cluster.scatters:
            sc.staleness.reset()
        self.cluster.serving.window_metrics()

    def run_point(self, multiplier: float) -> dict:
        """Warmup, then measure one offered-load point."""
        c = self.cfg
        for _ in range(c.warmup_ticks):
            self.tick(multiplier)
        self.reset_window()
        adm0 = self._adm_totals()
        t0 = time.perf_counter()
        clk0 = self.clock()
        for _ in range(c.measure_ticks):
            self.tick(multiplier)
        wall = time.perf_counter() - t0
        clk = self.clock() - clk0
        adm = {k: v - adm0[k] for k, v in self._adm_totals().items()}
        stale = PercentileRing.merged_percentiles(
            [sc.staleness for sc in self.cluster.scatters
             if sc.shard.alive], (50, 99))
        lat = PercentileRing.merged_percentiles(
            [s.latency for s in self._schedulers()], (50, 99))
        elapsed = wall if self.tick_dt is None else clk
        return {
            "multiplier": multiplier,
            "ticks": c.measure_ticks,
            "requests_per_tick": self.requests_per_tick(multiplier)
            * len(self.serve_names),
            "latency_s": lat,
            "staleness_s": stale,
            "admission": adm,
            "pending_examples": sum(s.pending_examples
                                    for s in self._schedulers()),
            "predict_throughput_eps":
                adm["executed_examples"] / max(elapsed, 1e-9),
            "caches": self.cluster.serving.window_metrics(),
        }

    def _adm_totals(self) -> dict:
        return dict(self.cluster.serving.metrics()["admission"])

    def sweep(self, multipliers=(0.5, 1.0, 2.0, 4.0)) -> list[dict]:
        return [self.run_point(m) for m in multipliers]

    def metrics(self) -> dict:
        out = self.cluster.sync_metrics(self.clock())
        out["train_batches"] = self.train_batches
        out["train_examples"] = sum(
            s["examples"] for s in
            out["training"]["scenarios"].values())
        return out

    def export_trace(self, path: str) -> int:
        """Write the process tracer's span ring (the harness runs every
        plane in-process) as Perfetto JSON. Returns the event count —
        0 means the tracer was never ``configure``d on."""
        from repro.obs import perfetto
        from repro.obs import trace as obs_trace
        return perfetto.write_trace(path, obs_trace.get_tracer().export())
