"""Abstract input specs for the dry-run: ShapeDtypeStructs with attached
NamedShardings for every (architecture x input shape) combination — the
shannon/kernels pattern: weak-type-correct, shardable, no allocation."""

from __future__ import annotations

import os
import sys
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_lib  # noqa: F401 (ProcSlot annotation)

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import init_cache, init_params
from repro.models.sharding import (MeshInfo, batch_pspecs, cache_pspecs,
                                   param_pspecs)
from repro.optim import Optimizer, get_optimizer
from repro.training.trainer import TrainState

PyTree = Any


def _with_shardings(abstract: PyTree, pspecs: PyTree,
                    mesh: jax.sharding.Mesh) -> PyTree:
    def attach(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(attach, abstract, pspecs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_params(cfg: ModelConfig, m: MeshInfo) -> PyTree:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return _with_shardings(shapes, param_pspecs(cfg, m), m.mesh)


def _slot_spec(param_spec: P, param_sds, slot_sds) -> P:
    """Match optimizer-slot sharding to its parameter's sharding."""
    if slot_sds.shape == param_sds.shape:
        return param_spec
    if slot_sds.shape == param_sds.shape[:-1]:               # adafactor vr
        return P(*param_spec[:-1]) if len(param_spec) else P()
    if slot_sds.shape == param_sds.shape[:-2] + param_sds.shape[-1:]:
        return P(*(tuple(param_spec[:-2]) + tuple(param_spec[-1:])))
    return P(*([None] * len(slot_sds.shape)))


def abstract_train_state(cfg: ModelConfig, m: MeshInfo,
                         optimizer: Optional[Optimizer] = None) -> PyTree:
    opt = optimizer or get_optimizer(cfg.optimizer)
    p_shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    s_shapes = jax.eval_shape(opt.init_slots_tree, p_shapes)
    pspecs = param_pspecs(cfg, m)

    def slot_specs(param_spec, param_sds, slots):
        return {name: _slot_spec(param_spec, param_sds, sds)
                for name, sds in slots.items()}

    sspecs = jax.tree.map(
        slot_specs, pspecs, p_shapes, s_shapes,
        is_leaf=lambda x: isinstance(x, P))
    params = _with_shardings(p_shapes, pspecs, m.mesh)
    slots = _with_shardings(s_shapes, sspecs, m.mesh)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(m.mesh, P()))
    return TrainState(params=params, slots=slots, step=step)


def abstract_cache(cfg: ModelConfig, m: MeshInfo, batch: int,
                   seq_len: int, kv_quant: bool = False) -> PyTree:
    shapes = init_cache(cfg, batch, seq_len, dtype=jnp.bfloat16,
                        abstract=True, kv_quant=kv_quant)
    return _with_shardings(shapes, cache_pspecs(cfg, m, batch, kv_quant),
                           m.mesh)


# ---------------------------------------------------------------------------
# process launch specs (multi-process cluster runtime, launch/runtime.py)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ProcSpec:
    """Everything needed to launch (or relaunch) one worker process: the
    grid slot it fills, its RPC socket path, and the exact argv. Respawn
    after a SIGKILL reuses the same spec — the socket path is stable per
    slot, so the supervisor reconnects without renegotiation."""

    slot: "mesh_lib.ProcSlot"
    root: str                         # runtime directory (queue/ckpt/sock)
    argv: tuple[str, ...]
    socket: str
    log_path: str

    @property
    def name(self) -> str:
        return self.slot.name


def proc_spec_for(slot, root: str) -> ProcSpec:
    """Launch spec for one grid slot. Workers run the package entry
    ``python -m repro.launch.worker`` against the shared runtime dir;
    role/shard/replica arrive as argv so the worker imports only the
    numpy PS/queue layer it needs."""
    socket = os.path.join(root, "sock", f"{slot.name}.sock")
    log_path = os.path.join(root, "logs", f"{slot.name}.log")
    argv = (sys.executable, "-m", "repro.launch.worker",
            "--role", slot.role, "--shard", str(slot.shard_id),
            "--replica", str(-1 if slot.replica is None else slot.replica),
            "--root", root, "--socket", socket)
    return ProcSpec(slot=slot, root=root, argv=argv, socket=socket,
                    log_path=log_path)


def plan_cluster_procs(pmesh, root: str) -> list[ProcSpec]:
    """Placement for a whole cluster: one spec per ``ProcessMesh`` slot
    (masters first, then slave replicas)."""
    return [proc_spec_for(slot, root) for slot in pmesh.slots()]


def input_specs(cfg: ModelConfig, shape: InputShape,
                m: MeshInfo, kv_quant: bool = False) -> dict[str, PyTree]:
    """Step arguments (beyond model state) for this input shape."""
    b = shape.global_batch
    bspecs = batch_pspecs(cfg, m, shape.kind, b)

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(m.mesh, spec))

    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((b, shape.seq_len), jnp.int32,
                               bspecs["tokens"])}
        if cfg.has_encoder_context:
            batch["enc_context"] = sds(
                (b, cfg.encoder_len, cfg.d_model), jnp.bfloat16,
                bspecs["enc_context"])
        return {"batch": batch}
    # decode: one new token against a seq_len cache
    return {
        "tokens": sds((b, 1), jnp.int32, bspecs["tokens"]),
        "pos": sds((b,), jnp.int32, bspecs["pos"]),
        "cache": abstract_cache(cfg, m, b, shape.seq_len,
                                kv_quant=kv_quant),
    }
