"""Training launcher: drives train_step + the WeiPS ModelSyncEngine on the
local mesh (CPU here; pass --mesh data,model on real hardware).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --batch 8 --seq 128 --sync-period 1.0
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core.sync_engine import ModelSyncEngine, SyncConfig
from repro.data import lm_batches
from repro.training import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--sync-period", type=float, default=1.0)
    ap.add_argument("--codec", default="cast16",
                    choices=("identity", "cast16", "int8"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, d_model=args.d_model,
                      layers_per_segment=args.layers)
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model} "
          f"params={cfg.param_counts()['total']/1e6:.1f}M")

    state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    step_fn = make_train_step(cfg)
    engine = ModelSyncEngine(cfg, state.params, SyncConfig(
        gather_mode="period", period=args.sync_period, codec=args.codec))

    batches = lm_batches(cfg.vocab_size, args.batch, args.seq,
                         seed=args.seed)
    t0 = time.time()
    for i in range(args.steps):
        tokens = jnp.asarray(next(batches))
        batch = {"tokens": tokens}
        if cfg.has_encoder_context:
            batch["enc_context"] = jnp.zeros(
                (args.batch, cfg.encoder_len, cfg.d_model), jnp.float32)
        state, metrics = step_fn(state, batch)
        host_metrics = {}
        if "expert_counts_per_layer" in metrics:
            host_metrics["expert_counts_per_layer"] = jax.tree.map(
                np.asarray, metrics["expert_counts_per_layer"])
        engine.collect_step(np.asarray(tokens), host_metrics)
        engine.tick(state.params, now=time.time() - t0)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"wall={time.time()-t0:.1f}s")
    engine.tick(state.params, now=1e9)      # final flush
    rep = engine.replicas[0]
    print("sync metrics:", engine.metrics())
    print("serve staleness vs train params:",
          f"{rep.staleness(state.params):.2e}")


if __name__ == "__main__":
    main()
