"""Tiny RPC transport for the multi-process cluster runtime: one AF_UNIX
socket per worker, ``multiprocessing.connection`` framing (length-prefixed
pickles — numpy arrays ride along for free).

Deliberately minimal: the supervisor is the only client and drives every
worker serially, so the server accepts one connection at a time and
dispatches requests in order. That serial discipline is what makes the
chaos harness deterministic — there is no request interleaving to race.

Wire format: request ``(method, kwargs)``; response ``("ok", value)`` or
``("err", traceback_text)``. A worker SIGKILLed mid-request surfaces as
``EOFError``/``ConnectionError`` in the supervisor's ``call`` — the death
signal the chaos supervisor's detect state consumes.
"""

from __future__ import annotations

import os
import time
import traceback
from multiprocessing.connection import Client, Listener
from typing import Any, Optional

AUTHKEY = b"weips-runtime"


class WorkerDied(ConnectionError):
    """A call could not complete because the worker's socket went away."""


class RpcServer:
    """Worker-side request loop over a unix socket."""

    def __init__(self, socket_path: str, handler):
        """``handler(method, kwargs)`` returns the result value (raising
        is fine — the traceback travels back to the caller)."""
        os.makedirs(os.path.dirname(socket_path), exist_ok=True)
        if os.path.exists(socket_path):        # stale socket from a killed
            os.unlink(socket_path)             # predecessor of this slot
        self.listener = Listener(socket_path, family="AF_UNIX",
                                 authkey=AUTHKEY)
        self.handler = handler

    def serve_forever(self) -> None:
        """Accept supervisor connections until a ``shutdown`` request.
        A dropped connection (supervisor restart) loops back to accept."""
        while True:
            try:
                conn = self.listener.accept()
            except (OSError, EOFError):
                continue
            try:
                while True:
                    method, kwargs = conn.recv()
                    if method == "shutdown":
                        conn.send(("ok", None))
                        return
                    try:
                        conn.send(("ok", self.handler(method, kwargs)))
                    except Exception:
                        conn.send(("err", traceback.format_exc()))
            except (EOFError, OSError, ConnectionError):
                continue
            finally:
                conn.close()


class RpcClient:
    """Supervisor-side handle to one worker."""

    def __init__(self, socket_path: str, connect_timeout: float = 30.0):
        self.socket_path = socket_path
        self.connect_timeout = connect_timeout
        self._conn = None

    def connect(self) -> None:
        """Retry until the worker binds its socket (process startup pays
        the jax import; SIGKILL respawns rebind the same path)."""
        deadline = time.monotonic() + self.connect_timeout
        last: Optional[Exception] = None
        while time.monotonic() < deadline:
            try:
                self._conn = Client(self.socket_path, family="AF_UNIX",
                                    authkey=AUTHKEY)
                return
            except (FileNotFoundError, ConnectionRefusedError,
                    EOFError, OSError) as e:
                last = e
                time.sleep(0.02)
        raise WorkerDied(
            f"could not connect to {self.socket_path}: {last!r}")

    def call(self, method: str, **kwargs) -> Any:
        if self._conn is None:
            self.connect()
        try:
            self._conn.send((method, kwargs))
            status, value = self._conn.recv()
        except (EOFError, OSError, ConnectionError) as e:
            self.close()
            raise WorkerDied(
                f"worker at {self.socket_path} died during "
                f"{method!r}: {e!r}") from e
        if status == "err":
            raise RuntimeError(
                f"remote {method!r} failed:\n{value}")
        return value

    def close(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
            self._conn = None
