"""Worker process entry for the multi-process cluster runtime: one master
PS shard or one slave PS replica per OS process, serving RPCs from the
supervisor (``launch/runtime.py``) over a unix socket and exchanging sync
records through the shared durable ``FileQueue``.

Run as ``python -m repro.launch.worker --role master --shard 0 --root
<dir> --socket <path>`` — ``launch/specs.py`` builds these argvs. The
worker reads the cluster shape from ``<root>/runtime.json`` and touches
only the numpy PS/queue layer (plus the optimizer module), so a SIGKILL +
respawn cycle costs process startup, not model compilation.

Fault injection: the supervisor arms a subset of the run's
:class:`~repro.launch.chaos.FaultPlan` on each worker (``arm`` RPC); the
worker calls ``FaultHooks.check`` at the instrumented points documented in
``launch/chaos.py`` — a ``kill`` event SIGKILLs the process mid-operation,
exactly at a deterministic (target, point, step) coordinate.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.launch.chaos import FaultEvent, FaultHooks
from repro.launch.transport import RpcServer


def _load_runtime_cfg(root: str) -> dict:
    with open(os.path.join(root, "runtime.json")) as f:
        return json.load(f)


def _build_optimizer(cfg: dict):
    from repro.optim import get_optimizer
    return get_optimizer(cfg["optimizer"], **cfg.get("optimizer_kwargs", {}))


def _sorted_table_state(table) -> dict:
    """Canonical (id-sorted) columnar dump of one table — the unit the
    trajectory-equality tests compare bit-for-bit."""
    snap = table.snapshot()
    order = np.argsort(snap["ids"], kind="stable")
    return {"ids": snap["ids"][order], "w": snap["w"][order],
            "slots": {n: v[order] for n, v in snap["slots"].items()}}


class MasterWorker:
    """One master PS shard + its collect→gather→push stages."""

    def __init__(self, shard_id: int, root: str, cfg: dict):
        from repro.core.ps import MasterShard
        from repro.core.queue import FileQueue
        from repro.core.routing import RoutingPlan
        from repro.core.streaming import Collector, Gatherer, Pusher
        from repro.core.transform import make_transform

        self.name = f"master-{shard_id}"
        self.hooks = FaultHooks(self.name)
        self.cfg = cfg
        self.plan = RoutingPlan(cfg["num_master"], cfg["num_slave"],
                                cfg["num_partitions"])
        self.optimizer = _build_optimizer(cfg)
        self.groups = {g: int(d) for g, d in cfg["groups"].items()}
        self.shard = MasterShard(shard_id, self.groups, self.optimizer)
        self.collector = Collector()
        self.shard.collector = self.collector
        self.gatherer = Gatherer(cfg.get("gather_mode", "realtime"))
        self.queue = FileQueue(os.path.join(root, "queue"))
        self.transform = make_transform(cfg.get("codec", "identity"),
                                        self.optimizer)
        self.pusher = Pusher(self.shard, self.queue, self.plan,
                             self.transform)
        # delta-checkpoint marks: per-group mutation clock / dense version
        # at the previous part write (lost on respawn — the supervisor
        # forces the next checkpoint full after any recovery)
        self._marks: dict[str, int] = {}
        self._dense_marks: dict[str, int] = {}

    # -- RPC methods -----------------------------------------------------
    def pull(self, group: str, ids: np.ndarray) -> np.ndarray:
        return self.shard.pull(group, np.asarray(ids, np.int64))

    def apply(self, group: str, ids: np.ndarray, grads: np.ndarray,
              step: int) -> int:
        uniq = self.shard.apply_batch(group, ids, grads, step=step)
        self.hooks.check("mid_train", step)
        return int(len(uniq))

    def flush(self, step: int, now: float) -> int:
        self.gatherer.offer(self.collector.drain())
        if not self.gatherer.ready(now):
            return 0
        gathered = self.gatherer.flush(now)
        kill = self.hooks.pending("mid_flush", step, kind="kill")
        if kill is not None:
            # die with the flush half-pushed: produce roughly half of
            # every id set (some partitions get records, some don't),
            # then fire the kill — the torn-flush crash window
            partial = {k: ids[: max(1, len(ids) // 2)]
                       for k, ids in gathered.items() if len(ids)}
            self.pusher.push(partial, now=self.hooks.now(now))
            self.hooks.check("mid_flush", step)       # no return
        self.hooks.check("mid_flush", step)           # delay/skew
        return self.pusher.push(gathered, now=self.hooks.now(now))

    def checkpoint_part(self, version: int, kind: str, path: str,
                        step: int) -> dict:
        if kind == "full" or not self._marks:
            kind = "full"
            snap = self.shard.snapshot()
        else:
            snap = self.shard.delta_snapshot(self._marks, self._dense_marks)
        part = {"snap": snap, "kind": kind,
                "pusher_seqs": self.pusher.seqs()}
        tmp = path + ".tmp"
        import pickle
        with open(tmp, "wb") as f:
            pickle.dump(part, f, protocol=4)
        # the torn-checkpoint window: part written but not yet published;
        # a kill here leaves only the .tmp — the supervisor never commits
        # the manifest and the previous chain stays authoritative
        self.hooks.check("mid_ckpt", step)
        os.replace(tmp, path)
        self._marks = {g: t["version"]
                       for g, t in snap["tables"].items()}
        self._dense_marks = dict(self.shard.dense.versions)
        for g, t in self.shard.tables.items():
            t.trim_evict_log(self._marks[g])
        return {"kind": kind, "shard_step": self.shard.step}

    def restore(self, snap: dict, pusher_seqs: dict, step: int) -> None:
        """Install materialized (full-equivalent) state — the recovery /
        replay entry. Clears every streaming buffer: the supervisor
        re-drives the steps after the cut, regenerating the events."""
        self.shard.clear()
        self.shard.load_snapshot(snap)
        self.pusher.restore_seqs(pusher_seqs)
        self.collector.drain()
        self.gatherer._pending.clear()
        self.gatherer._pending_count = 0
        self._marks = {}
        self._dense_marks = {}
        self.shard.step = step

    def table_state(self, group: str) -> dict:
        return _sorted_table_state(self.shard.tables[group])

    def metrics(self) -> dict:
        return {"step": self.shard.step,
                "pushed_records": self.pusher.pushed_records,
                "pushed_bytes": self.pusher.pushed_bytes,
                "rows": {g: len(t) for g, t in self.shard.tables.items()}}


class SlaveWorker:
    """One slave PS replica + its Scatter consumer."""

    def __init__(self, shard_id: int, replica: int, root: str, cfg: dict):
        from repro.core.ps import SlaveShard
        from repro.core.queue import FileQueue
        from repro.core.routing import RoutingPlan
        from repro.core.streaming import Scatter

        self.name = f"slave-{shard_id}.{replica}"
        self.hooks = FaultHooks(self.name)
        self.plan = RoutingPlan(cfg["num_master"], cfg["num_slave"],
                                cfg["num_partitions"])
        self.groups = {g: int(d) for g, d in cfg["groups"].items()}
        self.shard = SlaveShard(shard_id, self.groups)
        self.queue = FileQueue(os.path.join(root, "queue"))
        self.scatter = Scatter(self.shard, self.queue, self.plan)
        self.scatter.pre_apply = self._pre_apply
        self._cur_step = -1

    def _pre_apply(self, recs) -> None:
        # offsets already advanced in the consumer's memory, nothing
        # applied yet — a kill here forces redelivery after respawn
        self.hooks.check("pre_apply", self._cur_step)

    # -- RPC methods -----------------------------------------------------
    def poll(self, step: int, max_records=None, now=None) -> int:
        self._cur_step = step
        if self.hooks.pending("pre_apply", step, kind="drop"):
            self.hooks.check("pre_apply", step)   # dropped fetch response
            return 0
        return self.scatter.poll(max_records, now=now)

    def lookup(self, group: str, ids: np.ndarray) -> np.ndarray:
        return self.shard.lookup(group, np.asarray(ids, np.int64))

    def offsets(self) -> dict:
        return self.scatter.offsets()

    def seek(self, offsets: dict) -> None:
        self.scatter.seek({int(k): int(v) for k, v in offsets.items()})

    def load_group(self, group: str, ids: np.ndarray,
                   values: np.ndarray) -> None:
        self.shard.tables[group].scatter(np.asarray(ids, np.int64), values)

    def clear(self) -> None:
        """Hot-switch prelude: drop serve state + LWW seq memory so a
        checkpoint reload + offset seek replays into a clean table."""
        from repro.core.ps import SparseTable
        for g, dim in self.groups.items():
            self.shard.tables[g] = SparseTable(dim)
        self.shard._applied_seq = {}
        self.shard.dense = {}
        self.shard.dense_versions = {}

    def table_state(self, group: str) -> dict:
        return _sorted_table_state(self.shard.tables[group])

    def metrics(self) -> dict:
        return {"applied": self.shard.applied_records,
                "skipped": self.shard.skipped_records,
                "lag": self.scatter.lag(),
                "staleness": self.scatter.staleness.percentiles((50, 99)),
                "rows": {g: len(t) for g, t in self.shard.tables.items()}}


def _dispatch(worker, method: str, kwargs: dict):
    if method == "ping":
        return worker.name
    if method == "arm":
        worker.hooks.arm([FaultEvent(**e) for e in kwargs["events"]])
        return len(worker.hooks.events)
    fn = getattr(worker, method, None)
    if fn is None or method.startswith("_"):
        raise AttributeError(f"no RPC method {method!r}")
    return fn(**kwargs)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=("master", "slave"), required=True)
    ap.add_argument("--shard", type=int, required=True)
    ap.add_argument("--replica", type=int, default=-1)
    ap.add_argument("--root", required=True)
    ap.add_argument("--socket", required=True)
    args = ap.parse_args(argv)

    cfg = _load_runtime_cfg(args.root)
    if args.role == "master":
        worker = MasterWorker(args.shard, args.root, cfg)
    else:
        worker = SlaveWorker(args.shard, args.replica, args.root, cfg)
    print(f"[{worker.name}] pid={os.getpid()} ready", flush=True)
    server = RpcServer(args.socket,
                       lambda m, kw: _dispatch(worker, m, kw))
    server.serve_forever()
    print(f"[{worker.name}] shutdown", flush=True)
    sys.exit(0)


if __name__ == "__main__":
    main()
