"""Worker process entry for the multi-process cluster runtime: one master
PS shard or one slave PS replica per OS process, serving RPCs from the
supervisor (``launch/runtime.py``) over a unix socket and exchanging sync
records through the shared durable ``FileQueue``.

Run as ``python -m repro.launch.worker --role master --shard 0 --root
<dir> --socket <path>`` — ``launch/specs.py`` builds these argvs. The
worker reads the cluster shape from ``<root>/runtime.json`` and touches
only the numpy PS/queue layer (plus the optimizer module), so a SIGKILL +
respawn cycle costs process startup, not model compilation.

Fault injection: the supervisor arms a subset of the run's
:class:`~repro.launch.chaos.FaultPlan` on each worker (``arm`` RPC); the
worker calls ``FaultHooks.check`` at the instrumented points documented in
``launch/chaos.py`` — a ``kill`` event SIGKILLs the process mid-operation,
exactly at a deterministic (target, point, step) coordinate.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

import numpy as np

from repro.launch.chaos import FaultEvent, FaultHooks
from repro.launch.transport import RpcServer
from repro.obs import trace as obs_trace


def _load_runtime_cfg(root: str) -> dict:
    with open(os.path.join(root, "runtime.json")) as f:
        return json.load(f)


def _setup_observability(worker, root: str, cfg: dict) -> None:
    """Per-worker tracer + fault-annotation wiring, shared by both
    roles. With ``cfg["trace"]`` set the worker records spans into its
    own process-local ring (exported via the ``trace_dump`` RPC);
    either way every chaos fault firing is annotated, and a ``kill``
    dumps the ring to ``<root>/trace/`` first — the process (and its
    ring) is gone one line later, so the dump file is the only way the
    supervisor's merged timeline keeps the pre-kill spans."""
    worker.trace_root = os.path.join(root, "trace")
    if cfg.get("trace"):
        obs_trace.configure(enabled=True, process=worker.name,
                            capacity=int(cfg.get("trace_capacity", 1 << 15)))

    def on_fire(e: FaultEvent) -> None:
        tr = obs_trace.get_tracer()
        if not tr.enabled:
            return
        tr.instant(f"fault.{e.kind}", target=e.target, point=e.point,
                   step=e.step)
        if e.kind == "kill":
            _dump_trace(worker)

    worker.hooks.on_fire = on_fire


def _dump_trace(worker) -> Optional[str]:
    """Write this worker's span ring to ``<root>/trace/<name>.<pid>.json``
    (atomic rename). Returns the path, or None when tracing is off."""
    tr = obs_trace.get_tracer()
    if not tr.enabled:
        return None
    os.makedirs(worker.trace_root, exist_ok=True)
    path = os.path.join(worker.trace_root,
                        f"{worker.name}.{os.getpid()}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(tr.export(), f)
    os.replace(tmp, path)
    return path


def _build_optimizer(cfg: dict):
    from repro.optim import get_optimizer
    return get_optimizer(cfg["optimizer"], **cfg.get("optimizer_kwargs", {}))


def _sorted_table_state(table) -> dict:
    """Canonical (id-sorted) columnar dump of one table — the unit the
    trajectory-equality tests compare bit-for-bit."""
    snap = table.snapshot()
    order = np.argsort(snap["ids"], kind="stable")
    return {"ids": snap["ids"][order], "w": snap["w"][order],
            "slots": {n: v[order] for n, v in snap["slots"].items()}}


class MasterWorker:
    """One master PS shard + its collect→gather→push stages."""

    def __init__(self, shard_id: int, root: str, cfg: dict):
        from repro.core.ps import MasterShard
        from repro.core.queue import FileQueue
        from repro.core.routing import RoutingPlan
        from repro.core.streaming import Collector, Gatherer, Pusher
        from repro.core.transform import make_transform

        self.name = f"master-{shard_id}"
        self.hooks = FaultHooks(self.name)
        self.cfg = cfg
        self.plan = RoutingPlan(cfg["num_master"], cfg["num_slave"],
                                cfg["num_partitions"])
        self.optimizer = _build_optimizer(cfg)
        self.groups = {g: int(d) for g, d in cfg["groups"].items()}
        self.shard = MasterShard(shard_id, self.groups, self.optimizer)
        self.collector = Collector()
        self.shard.collector = self.collector
        self.gatherer = Gatherer(cfg.get("gather_mode", "realtime"))
        self.queue = FileQueue(os.path.join(root, "queue"))
        self.transform = make_transform(cfg.get("codec", "identity"),
                                        self.optimizer)
        self.pusher = Pusher(self.shard, self.queue, self.plan,
                             self.transform)
        # delta-checkpoint marks: per-group mutation clock / dense version
        # at the previous part write (lost on respawn — the supervisor
        # forces the next checkpoint full after any recovery)
        self._marks: dict[str, int] = {}
        self._dense_marks: dict[str, int] = {}
        _setup_observability(self, root, cfg)
        self.registry = self._build_registry()

    def _build_registry(self):
        from repro.obs.metrics import MetricsRegistry
        reg = MetricsRegistry()
        # keep the pre-PR-10 RPC keys (step/pushed_records/pushed_bytes/
        # rows) stable; the shard adds fused_batches + device_mirror
        self.shard.register_metrics(reg)
        reg.register("pushed_records",
                     lambda: self.pusher.pushed_records)
        reg.register("pushed_bytes", lambda: self.pusher.pushed_bytes)
        return reg

    # -- RPC methods -----------------------------------------------------
    def pull(self, group: str, ids: np.ndarray) -> np.ndarray:
        return self.shard.pull(group, np.asarray(ids, np.int64))

    def apply(self, group: str, ids: np.ndarray, grads: np.ndarray,
              step: int) -> int:
        uniq = self.shard.apply_batch(group, ids, grads, step=step)
        self.hooks.check("mid_train", step)
        return int(len(uniq))

    def flush(self, step: int, now: float) -> int:
        self.gatherer.offer(self.collector.drain())
        if not self.gatherer.ready(now):
            return 0
        gathered = self.gatherer.flush(now)
        kill = self.hooks.pending("mid_flush", step, kind="kill")
        if kill is not None:
            # die with the flush half-pushed: produce roughly half of
            # every id set (some partitions get records, some don't),
            # then fire the kill — the torn-flush crash window
            partial = {k: ids[: max(1, len(ids) // 2)]
                       for k, ids in gathered.items() if len(ids)}
            self.pusher.push(partial, now=self.hooks.now(now))
            self.hooks.check("mid_flush", step)       # no return
        self.hooks.check("mid_flush", step)           # delay/skew
        return self.pusher.push(gathered, now=self.hooks.now(now))

    def checkpoint_part(self, version: int, kind: str, path: str,
                        step: int) -> dict:
        if kind == "full" or not self._marks:
            kind = "full"
            snap = self.shard.snapshot()
        else:
            snap = self.shard.delta_snapshot(self._marks, self._dense_marks)
        part = {"snap": snap, "kind": kind,
                "pusher_seqs": self.pusher.seqs()}
        tmp = path + ".tmp"
        import pickle
        with open(tmp, "wb") as f:
            pickle.dump(part, f, protocol=4)
        # the torn-checkpoint window: part written but not yet published;
        # a kill here leaves only the .tmp — the supervisor never commits
        # the manifest and the previous chain stays authoritative
        self.hooks.check("mid_ckpt", step)
        os.replace(tmp, path)
        self._marks = {g: t["version"]
                       for g, t in snap["tables"].items()}
        self._dense_marks = dict(self.shard.dense.versions)
        for g, t in self.shard.tables.items():
            t.trim_evict_log(self._marks[g])
        return {"kind": kind, "shard_step": self.shard.step}

    def restore(self, snap: dict, pusher_seqs: dict, step: int) -> None:
        """Install materialized (full-equivalent) state — the recovery /
        replay entry. Clears every streaming buffer: the supervisor
        re-drives the steps after the cut, regenerating the events."""
        self.shard.clear()
        self.shard.load_snapshot(snap)
        self.pusher.restore_seqs(pusher_seqs)
        self.collector.drain()
        self.gatherer._pending.clear()
        self.gatherer._pending_count = 0
        self._marks = {}
        self._dense_marks = {}
        self.shard.step = step

    def table_state(self, group: str) -> dict:
        return _sorted_table_state(self.shard.tables[group])

    def metrics(self) -> dict:
        return self.registry.tree()

    def trace_dump(self) -> list:
        """Span export RPC — the supervisor merges every worker's ring
        (plus pre-kill dump files) into one Perfetto timeline."""
        return obs_trace.get_tracer().export()


class SlaveWorker:
    """One slave PS replica + its Scatter consumer."""

    def __init__(self, shard_id: int, replica: int, root: str, cfg: dict):
        from repro.core.ps import SlaveShard
        from repro.core.queue import FileQueue
        from repro.core.routing import RoutingPlan
        from repro.core.streaming import Scatter
        from repro.serving.cache import ServeCache

        self.name = f"slave-{shard_id}.{replica}"
        self.hooks = FaultHooks(self.name)
        self.plan = RoutingPlan(cfg["num_master"], cfg["num_slave"],
                                cfg["num_partitions"])
        self.groups = {g: int(d) for g, d in cfg["groups"].items()}
        self.shard = SlaveShard(shard_id, self.groups)
        self.queue = FileQueue(os.path.join(root, "queue"))
        self.scatter = Scatter(self.shard, self.queue, self.plan)
        self.scatter.pre_apply = self._pre_apply
        self._cur_step = -1
        # worker-local serve cache: the multi-process cache-invalidate
        # stage of the update's causal chain. Lookup RPCs fill it;
        # every applied scatter batch invalidates the rewritten rows
        # (``SlaveShard.on_apply``), exactly like the in-process
        # serving plane. serve_cache_rows=0 disables it.
        rows = int(cfg.get("serve_cache_rows", 1 << 16))
        self.cache = ServeCache(self.groups, max_rows=rows) if rows \
            else None
        if self.cache is not None:
            self.shard.on_apply = self._on_applied
        _setup_observability(self, root, cfg)
        self.registry = self._build_registry()

    def _build_registry(self):
        from repro.obs.metrics import MetricsRegistry
        reg = MetricsRegistry()
        # pre-PR-10 RPC keys stay: applied/skipped/rows from the shard,
        # lag/staleness from the scatter; the cache subtree is new
        self.shard.register_metrics(reg)
        reg.register("lag", self.scatter.lag)
        reg.register("staleness",
                     lambda: self.scatter.staleness.percentiles((50, 99)))
        if self.cache is not None:
            self.cache.register_metrics(reg, "cache")
        return reg

    def _on_applied(self, group: str, ids, op: str) -> None:
        if group in self.cache.offsets:
            self.cache.invalidate(ids)

    def _pre_apply(self, recs) -> None:
        # offsets already advanced in the consumer's memory, nothing
        # applied yet — a kill here forces redelivery after respawn
        self.hooks.check("pre_apply", self._cur_step)

    # -- RPC methods -----------------------------------------------------
    def poll(self, step: int, max_records=None, now=None) -> int:
        self._cur_step = step
        if self.hooks.pending("pre_apply", step, kind="drop"):
            self.hooks.check("pre_apply", step)   # dropped fetch response
            return 0
        return self.scatter.poll(max_records, now=now)

    def lookup(self, group: str, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if self.cache is None or group not in self.cache.offsets:
            return self.shard.lookup(group, ids)
        block, hit = self.cache.lookup(ids)
        if block is None or not hit.all():
            # pull the miss set's COMBINED-group rows once and install
            # them, so the next lookup for any group hits
            miss = ids if block is None else ids[~hit]
            uniq = np.unique(miss)
            fill = np.empty((len(uniq), self.cache.width), np.float32)
            for g, (lo, hi) in self.cache.offsets.items():
                fill[:, lo:hi] = self.shard.lookup(g, uniq)
            self.cache.fill(uniq, fill)
            block, hit = self.cache.lookup(ids)
            if block is None or not hit.all():
                # the bound-trim evicted part of the fill: serve the
                # request straight from the shard tables
                return self.shard.lookup(group, ids)
        lo, hi = self.cache.offsets[group]
        return block[:, lo:hi]

    def offsets(self) -> dict:
        return self.scatter.offsets()

    def seek(self, offsets: dict) -> None:
        self.scatter.seek({int(k): int(v) for k, v in offsets.items()})
        if self.cache is not None:      # replay rewrites outside on_apply
            self.cache.clear()

    def load_group(self, group: str, ids: np.ndarray,
                   values: np.ndarray) -> None:
        self.shard.tables[group].scatter(np.asarray(ids, np.int64), values)
        if self.cache is not None:      # bulk load bypasses the stream
            self.cache.clear()

    def clear(self) -> None:
        """Hot-switch prelude: drop serve state + LWW seq memory so a
        checkpoint reload + offset seek replays into a clean table."""
        from repro.core.ps import SparseTable
        for g, dim in self.groups.items():
            self.shard.tables[g] = SparseTable(dim)
        self.shard._applied_seq = {}
        self.shard.dense = {}
        self.shard.dense_versions = {}
        if self.cache is not None:
            self.cache.clear()

    def table_state(self, group: str) -> dict:
        return _sorted_table_state(self.shard.tables[group])

    def metrics(self) -> dict:
        return self.registry.tree()

    def trace_dump(self) -> list:
        return obs_trace.get_tracer().export()


def _dispatch(worker, method: str, kwargs: dict):
    if method == "ping":
        return worker.name
    if method == "arm":
        worker.hooks.arm([FaultEvent(**e) for e in kwargs["events"]])
        return len(worker.hooks.events)
    fn = getattr(worker, method, None)
    if fn is None or method.startswith("_"):
        raise AttributeError(f"no RPC method {method!r}")
    return fn(**kwargs)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=("master", "slave"), required=True)
    ap.add_argument("--shard", type=int, required=True)
    ap.add_argument("--replica", type=int, default=-1)
    ap.add_argument("--root", required=True)
    ap.add_argument("--socket", required=True)
    args = ap.parse_args(argv)

    cfg = _load_runtime_cfg(args.root)
    if args.role == "master":
        worker = MasterWorker(args.shard, args.root, cfg)
    else:
        worker = SlaveWorker(args.shard, args.replica, args.root, cfg)
    print(f"[{worker.name}] pid={os.getpid()} ready", flush=True)
    server = RpcServer(args.socket,
                       lambda m, kw: _dispatch(worker, m, kw))
    server.serve_forever()
    print(f"[{worker.name}] shutdown", flush=True)
    sys.exit(0)


if __name__ == "__main__":
    main()
