from repro.models.model import (decode_step, forward, init_cache, init_params,
                                precompute_cross_cache)

__all__ = ["decode_step", "forward", "init_cache", "init_params",
           "precompute_cross_cache"]
