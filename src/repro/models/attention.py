"""Attention layers: GQA self-attention (global / sliding-window / encoder
bidirectional), cross-attention, and single-token decode against a KV cache.

Prefill/train paths use a KV-chunked online-softmax formulation (the XLA
analogue of the Pallas flash kernel in ``repro.kernels.flash_attention``) so
the (S, S) score matrix is never materialized for long sequences. Sliding-
window layers use exact block-local attention: a query block attends only to
its own and the previous key block, giving O(S·W) FLOPs and a ring-buffer
decode cache of size W.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rope

_NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array                # (D, H, hd)
    wk: jax.Array                # (D, Kv, hd)
    wv: jax.Array                # (D, Kv, hd)
    wo: jax.Array                # (H, hd, D)
    bq: Optional[jax.Array] = None   # (H, hd)
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None


def project_qkv(p: dict, x: jax.Array, *, enc: Optional[jax.Array] = None):
    """Q from x; K/V from ``enc`` if given (cross-attention) else from x."""
    kv_src = enc if enc is not None else x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dgk->btgk", kv_src, p["wk"])
    v = jnp.einsum("btd,dgk->btgk", kv_src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _gqa_scores(q, k):
    """q (b,s,g,m,e), k (b,t,g,e) -> (b,g,m,s,t) fp32 logits."""
    return jnp.einsum("bsgme,btge->bgmst", q, k,
                      preferred_element_type=jnp.float32)


def _full_attention(q, k, v, mask):
    """Direct attention for short sequences. mask (b,1,1,s,t) or (s,t)."""
    scores = _gqa_scores(q, k)
    scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgmst,btge->bsgme", probs.astype(v.dtype), v)


def _chunked_causal(q, k, v, q_positions, kv_positions, chunk: int):
    """KV-chunked online-softmax causal attention (no (S,S) materialization).

    q (b,s,g,m,e); k,v (b,t,g,e). Scans KV chunks, maintaining running
    max / denominator / accumulator per query.
    """
    b, s, g, m, e = q.shape
    t = k.shape[1]
    n_chunks = math.ceil(t / chunk)
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=jnp.iinfo(jnp.int32).max)
    k = k.reshape(b, n_chunks, chunk, g, e).transpose(1, 0, 2, 3, 4)
    v = v.reshape(b, n_chunks, chunk, g, e).transpose(1, 0, 2, 3, 4)
    kv_pos = kv_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m_run, l_run, acc = carry
        k_c, v_c, pos_c = xs
        scores = _gqa_scores(q, k_c)                        # (b,g,m,s,c)
        mask = q_positions[:, None, None, :, None] >= pos_c[:, None, None, None, :]
        scores = jnp.where(mask, scores, _NEG_INF)
        m_new = jnp.maximum(m_run, scores.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgmsc,bcge->bgmse", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, g, m, s), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, g, m, s), dtype=jnp.float32)
    acc0 = jnp.zeros((b, g, m, s, e), dtype=jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (k, v, kv_pos))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.astype(q.dtype).transpose(0, 3, 1, 2, 4)     # (b,s,g,m,e)


def _block_local_causal(q, k, v, q_positions, window: int):
    """Exact sliding-window attention via block-local blocking: query block i
    attends key blocks {i-1, i} with |i-j| < window masking. O(S·2W) FLOPs.

    Requires block size == window and S % window == 0 (padded by caller).
    """
    b, s, g, m, e = q.shape
    w = window
    nb = s // w
    qb = q.reshape(b, nb, w, g, m, e)
    kb = k.reshape(b, nb, w, g, e)
    vb = v.reshape(b, nb, w, g, e)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)              # (b,nb,2w,g,e)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    scores = jnp.einsum("bnsgme,bntge->bngmst", qb, k2,
                        preferred_element_type=jnp.float32)
    # positions within the 2w strip
    pos_b = q_positions.reshape(b, nb, w)                   # (b,nb,w)
    kpos = jnp.concatenate([pos_b - w, pos_b], axis=-1)     # (b,nb,2w) key pos
    valid = (kpos >= 0)[:, :, None, None, None, :]
    qp = pos_b[:, :, None, None, :, None]
    kp = kpos[:, :, None, None, None, :]
    mask = valid & (qp >= kp) & (qp - kp < w)
    scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngmst,bntge->bnsgme", probs.astype(v2.dtype), v2)
    return out.reshape(b, s, g, m, e)


def _context_parallel_constraint(q, k, v):
    """Shard the query sequence over `model`; keep K/V replicated across it
    (sequence/context parallelism). Used when heads don't divide the TP
    degree — the alternative (head_dim sharded on `model`) makes every
    score einsum contract a sharded dim and all-reduce full fp32 score
    tensors (measured ~86 GB/layer on qwen1.5-4b train_4k)."""
    from jax.sharding import PartitionSpec as P
    from repro.models.common import mesh_axis_names
    if "model" not in mesh_axis_names():
        return q, k, v           # mesh-less (unit tests): constraint inert
    U = P.UNCONSTRAINED
    wsc = jax.lax.with_sharding_constraint
    q = wsc(q, P(U, "model", None, None))
    k = wsc(k, P(U, None, None, None))
    v = wsc(v, P(U, None, None, None))
    return q, k, v


def self_attention(p: dict, x: jax.Array, positions: jax.Array, *,
                   cfg: ModelConfig, causal: bool = True, window: int = 0,
                   chunk: int = 1024) -> jax.Array:
    """Train/prefill self-attention. x (B,S,D); positions (B,S) int32."""
    b, s, d = x.shape
    g = cfg.num_kv_heads
    m = cfg.num_heads // g
    q, k, v = project_qkv(p, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q * (cfg.head_dim ** -0.5)
    if cfg.context_parallel_attn:
        q, k, v = _context_parallel_constraint(q, k, v)
    q = q.reshape(b, s, g, m, cfg.head_dim)

    if not causal:
        mask = jnp.ones((s, k.shape[1]), dtype=bool)
        out = _full_attention(q, k, v, mask)
    elif window and s > window and s % window == 0:
        out = _block_local_causal(q, k, v, positions, window)
    elif s <= chunk:
        mask = (positions[:, None, None, :, None]
                >= positions[:, None, None, None, :])
        if window:
            mask &= (positions[:, None, None, :, None]
                     - positions[:, None, None, None, :]) < window
        out = _full_attention(q, k, v, mask)
    else:
        # (windowed fallback handled via masking inside the chunk scan)
        out = _chunked_causal(q, k, v, positions, positions, chunk)
        if window:
            raise NotImplementedError(
                "windowed attention requires s % window == 0")
    out = out.reshape(b, s, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention(p: dict, x: jax.Array, enc: jax.Array, *,
                    cfg: ModelConfig) -> jax.Array:
    """Cross-attention: queries from x (B,S,D), keys/values from encoder
    states (B,T,D). No positional rotation on the cross path."""
    b, s, d = x.shape
    g = cfg.num_kv_heads
    m = cfg.num_heads // g
    q, k, v = project_qkv(p, x, enc=enc)
    q = q * (cfg.head_dim ** -0.5)
    q = q.reshape(b, s, g, m, cfg.head_dim)
    mask = jnp.ones((s, enc.shape[1]), dtype=bool)
    out = _full_attention(q, k, v, mask)
    out = out.reshape(b, s, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------


def _quantize_row(x: jax.Array):
    """(B, Kv, hd) -> int8 rows + (B, Kv, 1) absmax scales."""
    scale = jnp.maximum(jnp.abs(x).max(axis=-1, keepdims=True), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decode_self_attention(p: dict, x: jax.Array, pos: jax.Array,
                          cache: dict, *, cfg: ModelConfig,
                          window: int = 0):
    """One-token decode. x (B,1,D); pos (B,) current positions;
    cache {"k","v": (B,S_cache,Kv,hd)} plus optional int8 "k_scale"/
    "v_scale" entries (quantized serving cache). For windowed layers the
    cache is a ring buffer of size ``window`` written at ``pos % window``.

    Returns (out (B,1,D), new_cache dict).
    """
    b = x.shape[0]
    g = cfg.num_kv_heads
    m = cfg.num_heads // g
    cache_k, cache_v = cache["k"], cache["v"]
    quant = "k_scale" in cache
    s_cache = cache_k.shape[1]
    q, k, v = project_qkv(p, x)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    q = q * (cfg.head_dim ** -0.5)

    slot = (pos % window) if window else pos                 # (B,)
    bidx = jnp.arange(b)
    if quant:
        k_q, k_s = _quantize_row(k[:, 0])
        v_q, v_s = _quantize_row(v[:, 0])
        cache_k = cache_k.at[bidx, slot].set(k_q)
        cache_v = cache_v.at[bidx, slot].set(v_q)
        k_scale = cache["k_scale"].at[bidx, slot].set(k_s)
        v_scale = cache["v_scale"].at[bidx, slot].set(v_s)
        keys = cache_k.astype(q.dtype) * k_scale.astype(q.dtype)
        values = cache_v.astype(jnp.float32) * v_scale
        new_cache = {"k": cache_k, "v": cache_v,
                     "k_scale": k_scale, "v_scale": v_scale}
    else:
        cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
        keys = cache_k.astype(q.dtype)
        values = cache_v
        new_cache = {"k": cache_k, "v": cache_v}

    scores = jnp.einsum("bgme,btge->bgmt", q.reshape(b, g, m, cfg.head_dim),
                        keys, preferred_element_type=jnp.float32)
    t_idx = jnp.arange(s_cache)[None, :]                     # (1,S)
    if window:
        # ring buffer: entry at slot t holds absolute position
        #   p_abs = largest p <= pos with p % window == t
        delta = (slot[:, None] - t_idx) % window
        abs_pos = pos[:, None] - delta
        valid = (abs_pos >= 0) & (pos[:, None] - abs_pos < window)
    else:
        valid = t_idx <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgmt,btge->bgme", probs.astype(values.dtype), values)
    out = out.reshape(b, 1, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def decode_cross_attention(p: dict, x: jax.Array, xk: jax.Array,
                           xv: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    """Decode-time cross attention against precomputed encoder K/V
    (xk/xv: (B,T,Kv,hd), static during decode)."""
    b = x.shape[0]
    g = cfg.num_kv_heads
    m = cfg.num_heads // g
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q * (cfg.head_dim ** -0.5)
    scores = jnp.einsum("bgme,btge->bgmt", q.reshape(b, g, m, cfg.head_dim),
                        xk.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgmt,btge->bgme", probs.astype(xv.dtype), xv)
    out = out.reshape(b, 1, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])
