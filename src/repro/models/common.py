"""Shared model building blocks: norms, rotary embeddings, initializers."""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp


def mesh_axis_names() -> tuple:
    """Axis names of the mesh currently in scope, () when mesh-less.

    Sharding-constraint helpers key off this to stay inert in mesh-less
    unit tests. Reads the new-style abstract mesh where the running jax
    exposes it (jax >= 0.5: ``jax.sharding.get_abstract_mesh``) and falls
    back to the classic ``with Mesh(...):`` thread resources otherwise —
    on jax 0.4.x the public accessor does not exist and the abstract mesh
    is unset under a classic mesh context, so both reads are needed.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        names = get().axis_names
        if names:
            return names
    try:
        from jax._src import mesh as mesh_lib
        return mesh_lib.thread_resources.env.physical_mesh.axis_names
    except Exception:                       # pragma: no cover - jax drift
        return ()


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32, cast back to input dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S).
    Pairs dimension halves (GPT-NeoX style).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = theta ** (-freq)                                   # (half,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, half)
    angles = angles[..., None, :]                                 # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape: tuple[int, ...], in_axis_size: int,
               dtype) -> jax.Array:
    """Scaled-normal initializer (variance ~ 1/fan_in)."""
    scale = in_axis_size ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def subkey(key: jax.Array, *names) -> jax.Array:
    """Deterministic per-path key derivation (stable across processes)."""
    for n in names:
        data = n if isinstance(n, int) else zlib.crc32(n.encode()) % (2 ** 31)
        key = jax.random.fold_in(key, data)
    return key
