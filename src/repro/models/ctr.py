"""The paper's own model family: sparse CTR models (LR / FM / DNN) whose
parameters live on the WeiPS parameter server.

Per-example inputs are ``fields`` hashed feature IDs. The PS supplies
gathered rows; these functions are pure JAX on the gathered values, so
gradients w.r.t. rows flow back to the PS push path.

Paper §4.1.2: "LR-FTRL has 3 sparse matrices" (w + z + n), "FM-FTRL has 6"
(w,z,n for linear + latent), "FM-SGD has two", "DNN is multiple sparse plus
multiple dense" — here groups are {"w": 1} for LR, {"w": 1, "v": k} for FM,
{"emb": k} + dense MLP for DNN; optimizer slots multiply the stored
matrices exactly as the paper counts them.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.weips_ctr import CTRConfig


def groups_for(cfg: CTRConfig) -> dict[str, int]:
    if cfg.model_type == "lr":
        return {"w": 1}
    if cfg.model_type == "fm":
        return {"w": 1, "v": cfg.embed_dim}
    if cfg.model_type == "dnn":
        return {"emb": cfg.embed_dim}
    raise ValueError(cfg.model_type)


def check_scenario_groups(scenario_groups: dict[str, int],
                          store_groups: dict[str, int]) -> None:
    """A scenario can serve off the shared parameter store only when every
    sparse group it reads exists there with the same row dim (scenarios
    select *subsets* of the store — an LR scenario reads ``w`` off an FM
    store — they never widen it)."""
    for g, dim in scenario_groups.items():
        have = store_groups.get(g)
        if have is None:
            raise ValueError(
                f"scenario group {g!r} is not in the parameter store "
                f"(store groups: {sorted(store_groups)})")
        if have != dim:
            raise ValueError(
                f"scenario group {g!r} wants dim {dim} but the store "
                f"holds dim {have}")


def dense_shapes(cfg: CTRConfig) -> dict[str, tuple[int, ...]]:
    if cfg.model_type != "dnn":
        return {}
    sizes = (cfg.fields * cfg.embed_dim,) + cfg.dnn_hidden + (1,)
    out = {}
    for i in range(len(sizes) - 1):
        out[f"mlp/w{i}"] = (sizes[i], sizes[i + 1])
        out[f"mlp/b{i}"] = (sizes[i + 1],)
    return out


def init_dense(cfg: CTRConfig, key: jax.Array) -> dict[str, np.ndarray]:
    shapes = dense_shapes(cfg)
    n_layers = sum(1 for n in shapes if n.startswith("mlp/w"))
    out = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith(tuple("b%d" % i for i in range(9))):
            # hidden biases start small-POSITIVE: embedding rows are
            # created as zeros on the PS, so with zero biases every ReLU
            # sits exactly at 0 and its gradient is 0 — no signal ever
            # reaches the embeddings and the DNN never learns (it was the
            # weips-dnn-adam seed failure). The output bias stays 0 so the
            # first prediction is the uninformed prior.
            i = int(name[len("mlp/b"):])
            fill = 0.1 if i < n_layers - 1 else 0.0
            out[name] = np.full(shape, fill, np.float32)
        else:
            out[name] = np.asarray(
                jax.random.normal(sub, shape) * (shape[0] ** -0.5),
                dtype=np.float32)
    return out


# ---------------------------------------------------------------------------
# Forward / loss — pure functions of the gathered rows
# ---------------------------------------------------------------------------


def lr_logits(rows: dict, dense: dict) -> jax.Array:
    # rows["w"]: (B, F, 1)
    return rows["w"][..., 0].sum(axis=1)


def fm_logits(rows: dict, dense: dict) -> jax.Array:
    linear = rows["w"][..., 0].sum(axis=1)                    # (B,)
    v = rows["v"]                                             # (B, F, k)
    s = v.sum(axis=1)                                         # (B, k)
    inter = 0.5 * (jnp.square(s) - jnp.square(v).sum(axis=1)).sum(axis=-1)
    return linear + inter


def dnn_logits(rows: dict, dense: dict) -> jax.Array:
    emb = rows["emb"]                                         # (B, F, k)
    h = emb.reshape(emb.shape[0], -1)
    i = 0
    while f"mlp/w{i}" in dense:
        h = h @ dense[f"mlp/w{i}"] + dense[f"mlp/b{i}"]
        if f"mlp/w{i+1}" in dense:
            h = jax.nn.relu(h)
        i += 1
    return h[:, 0]


_LOGITS: dict[str, Callable] = {"lr": lr_logits, "fm": fm_logits,
                                "dnn": dnn_logits}


def predict_fn(cfg: CTRConfig) -> Callable:
    f = _LOGITS[cfg.model_type]

    @jax.jit
    def predict(rows, dense):
        return jax.nn.sigmoid(f(rows, dense))

    return predict


def predict_block_fn(cfg: CTRConfig,
                     offsets: dict[str, tuple[int, int]]) -> Callable:
    """Predict from a combined-group row block ``(B*F, sum of dims)`` —
    the serve cache's native layout (``ServeCache.offsets``): the
    per-group split happens *inside* the jitted function as device
    slices fused into the predict graph, so the serving hot path pays
    ONE host→device transfer and zero per-group host row copies."""
    f = _LOGITS[cfg.model_type]
    fields = cfg.fields
    offs = tuple((g, lo, hi) for g, (lo, hi) in offsets.items())

    @jax.jit
    def predict(block, dense):
        r3 = block.reshape(-1, fields, block.shape[1])
        rows = {g: r3[:, :, lo:hi] for g, lo, hi in offs}
        return jax.nn.sigmoid(f(rows, dense))

    return predict


def loss_and_grads_fn(cfg: CTRConfig) -> Callable:
    f = _LOGITS[cfg.model_type]

    def loss(rows, dense, y):
        logits = f(rows, dense)
        return jnp.mean(
            jnp.maximum(logits, 0) - logits * y
            + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    @jax.jit
    def loss_and_grads(rows, dense, y):
        val, grads = jax.value_and_grad(loss, argnums=(0, 1))(rows, dense, y)
        return val, grads[0], grads[1]

    return loss_and_grads


def weighted_loss_and_grads_fn(cfg: CTRConfig) -> Callable:
    """Per-example-weighted BCE — the training plane's step. Weights carry
    (a) the joiner's negative-downsampling correction (kept negatives
    weigh 1/rate, so the weighted loss stays unbiased) and (b) the
    pad-to-bucket zeros: the pipeline pads row tensors up to a pow2
    bucket so this jits once per bucket shape, and the padded examples'
    weight of 0 removes them from both the loss and every gradient."""
    f = _LOGITS[cfg.model_type]

    def loss(rows, dense, y, w):
        logits = f(rows, dense)
        per = (jnp.maximum(logits, 0) - logits * y
               + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        return jnp.sum(w * per) / jnp.maximum(jnp.sum(w), 1e-9)

    @jax.jit
    def loss_and_grads(rows, dense, y, w):
        val, grads = jax.value_and_grad(loss, argnums=(0, 1))(
            rows, dense, y, w)
        return val, grads[0], grads[1]

    return loss_and_grads
