"""Model composition: parameter init, segment-scanned forward, and
single-token decode for every architecture family in the zoo.

Layer stacks execute as ``jax.lax.scan`` over *segments* (repeating layer
patterns, see configs.base.Segment) with params stacked on a leading
``repeats`` axis — HLO size and compile time are depth-independent.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, CROSS_ATTN, ENC_ATTN, LOCAL_ATTN, MAMBA,
                                MLP, MOE, NONE, LayerSpec, ModelConfig, Segment)
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.common import dense_init, rms_norm, subkey

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ModelConfig) -> dict:
    d, h, g, e = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "norm": jnp.zeros((d,), dtype=pd),
        "wq": dense_init(subkey(key, "wq"), (d, h, e), d, pd),
        "wk": dense_init(subkey(key, "wk"), (d, g, e), d, pd),
        "wv": dense_init(subkey(key, "wv"), (d, g, e), d, pd),
        "wo": dense_init(subkey(key, "wo"), (h, e, d), h * e, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, e), dtype=pd)
        p["bk"] = jnp.zeros((g, e), dtype=pd)
        p["bv"] = jnp.zeros((g, e), dtype=pd)
    return p


def _init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "norm": jnp.zeros((d,), dtype=pd),
        "w_gate": dense_init(subkey(key, "w_gate"), (d, f), d, pd),
        "w_up": dense_init(subkey(key, "w_up"), (d, f), d, pd),
        "w_down": dense_init(subkey(key, "w_down"), (f, d), f, pd),
    }


def _init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "norm": jnp.zeros((d,), dtype=pd),
        "router": dense_init(subkey(key, "router"), (d, e), d, jnp.float32),
        "w_gate": dense_init(subkey(key, "w_gate"), (e, d, f), d, pd),
        "w_up": dense_init(subkey(key, "w_up"), (e, d, f), d, pd),
        "w_down": dense_init(subkey(key, "w_down"), (e, f, d), f, pd),
    }


def _init_mamba(key, cfg: ModelConfig) -> dict:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
    cw = cfg.ssm_conv_width
    pd = jnp.dtype(cfg.param_dtype)
    ch = di + 2 * ns
    return {
        "norm": jnp.zeros((d,), dtype=pd),
        "wz": dense_init(subkey(key, "wz"), (d, di), d, pd),
        "wx": dense_init(subkey(key, "wx"), (d, di), d, pd),
        "wB": dense_init(subkey(key, "wB"), (d, ns), d, pd),
        "wC": dense_init(subkey(key, "wC"), (d, ns), d, pd),
        "wdt": dense_init(subkey(key, "wdt"), (d, nh), d, pd),
        "conv_w": dense_init(subkey(key, "conv_w"), (cw, ch), cw, pd),
        "conv_b": jnp.zeros((ch,), dtype=pd),
        "A_log": jnp.zeros((nh,), dtype=jnp.float32),        # A = -1
        "D": jnp.ones((nh,), dtype=jnp.float32),
        "dt_bias": jnp.full((nh,), math.log(math.e - 1), dtype=jnp.float32),
        "gnorm": jnp.zeros((di,), dtype=pd),
        "out_proj": dense_init(subkey(key, "out_proj"), (di, d), di, pd),
    }


_MIXER_INIT = {ATTN: _init_attn, LOCAL_ATTN: _init_attn, ENC_ATTN: _init_attn,
               CROSS_ATTN: _init_attn, MAMBA: _init_mamba}
_FFN_INIT = {MLP: _init_mlp, MOE: _init_moe}


def _init_layer(key, spec: LayerSpec, cfg: ModelConfig) -> dict:
    p = {"mixer": _MIXER_INIT[spec.mixer](subkey(key, "mixer"), cfg)}
    if spec.ffn != NONE:
        p["ffn"] = _FFN_INIT[spec.ffn](subkey(key, "ffn"), cfg)
    return p


def _init_segment(key, seg: Segment, cfg: ModelConfig) -> dict:
    out = {}
    for i, spec in enumerate(seg.pattern):
        base = subkey(key, "pos", i)
        keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
            jnp.arange(seg.repeats))
        out[f"pos{i}"] = jax.vmap(
            lambda k, spec=spec: _init_layer(k, spec, cfg))(keys)
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    pd = jnp.dtype(cfg.param_dtype)
    params: dict = {
        "embed": dense_init(subkey(key, "embed"),
                            (cfg.padded_vocab, cfg.d_model),
                            cfg.d_model, pd),
        "final_norm": jnp.zeros((cfg.d_model,), dtype=pd),
        "segments": [
            _init_segment(subkey(key, "seg", si), seg, cfg)
            for si, seg in enumerate(cfg.segments)
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            subkey(key, "lm_head"), (cfg.padded_vocab, cfg.d_model),
            cfg.d_model, pd)
    if cfg.encoder_segments:
        params["encoder"] = {
            "segments": [
                _init_segment(subkey(key, "enc_seg", si), seg, cfg)
                for si, seg in enumerate(cfg.encoder_segments)
            ],
            "final_norm": jnp.zeros((cfg.d_model,), dtype=pd),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_mixer(spec: LayerSpec, p: dict, x, cfg: ModelConfig, positions,
                 enc: Optional[jax.Array]):
    h = rms_norm(x, p["norm"])
    if spec.mixer in (ATTN, LOCAL_ATTN):
        window = cfg.window_size if spec.mixer == LOCAL_ATTN else 0
        return attn.self_attention(p, h, positions, cfg=cfg, causal=True,
                                   window=window)
    if spec.mixer == ENC_ATTN:
        return attn.self_attention(p, h, positions, cfg=cfg, causal=False)
    if spec.mixer == CROSS_ATTN:
        return attn.cross_attention(p, h, enc, cfg=cfg)
    if spec.mixer == MAMBA:
        return ssm.mamba_block(p, h, cfg)
    raise ValueError(spec.mixer)


def _apply_ffn(spec: LayerSpec, p: dict, x, cfg: ModelConfig):
    """Returns (out, aux_loss, expert_counts)."""
    if spec.ffn == NONE:
        return jnp.zeros_like(x), 0.0, None
    h = rms_norm(x, p["norm"])
    if spec.ffn == MLP:
        gate = jnp.einsum("bsd,df->bsf", h, p["w_gate"])
        up = jnp.einsum("bsd,df->bsf", h, p["w_up"])
        out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, p["w_down"])
        return out, 0.0, None
    if spec.ffn == MOE:
        out, aux, counts = moe_lib.moe_ffn(p, h, cfg)
        return out, aux, counts
    raise ValueError(spec.ffn)


def _run_segments(x, segments_params, segments: tuple[Segment, ...],
                  cfg: ModelConfig, positions, enc):
    """Scan each segment; accumulate MoE aux loss and expert counts."""
    aux_total = jnp.zeros((), dtype=jnp.float32)
    counts_total = (jnp.zeros((cfg.num_experts,), dtype=jnp.int32)
                    if cfg.num_experts else None)

    per_layer_counts = []            # one dict {pos: (repeats, E)} per segment
    for seg, seg_params in zip(segments, segments_params):
        def body(carry, layer_params, seg=seg):
            x, aux, counts = carry
            iter_counts = {}
            for i, spec in enumerate(seg.pattern):
                lp = layer_params[f"pos{i}"]
                x = x + _apply_mixer(spec, lp["mixer"], x, cfg, positions, enc)
                dx, a, c = _apply_ffn(spec, lp.get("ffn", {}), x, cfg)
                x = x + dx
                aux = aux + a
                if c is not None:
                    counts = counts + c
                    iter_counts[f"pos{i}"] = c
            return (x, aux, counts), iter_counts

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux_total, counts_total), seg_counts = jax.lax.scan(
            body_fn, (x, aux_total, counts_total), seg_params)
        per_layer_counts.append(seg_counts)
    return x, aux_total, counts_total, per_layer_counts


def encode(params: PyTree, cfg: ModelConfig, enc_input: jax.Array):
    """Run the encoder stack over stub frontend embeddings (B,T,D)."""
    x = enc_input.astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])
    x, _, _, _ = _run_segments(x, params["encoder"]["segments"],
                               cfg.encoder_segments, cfg, positions, None)
    return rms_norm(x, params["encoder"]["final_norm"])


def forward(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
            enc_context: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            return_hidden: bool = False):
    """Full-sequence forward. tokens (B,S) int32; enc_context (B,T,D) stub
    embeddings for vlm/audio. Returns (logits (B,S,V), aux_metrics dict) —
    or (hidden (B,S,D), metrics) with ``return_hidden`` (chunked-CE loss
    computes logits itself)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))

    enc = None
    if cfg.is_encdec:
        enc = encode(params, cfg, enc_context)
    elif cfg.has_encoder_context:
        enc = enc_context.astype(x.dtype)       # VLM: projected patch embeds

    x, aux, counts, per_layer = _run_segments(
        x, params["segments"], cfg.segments, cfg, positions, enc)
    x = rms_norm(x, params["final_norm"])
    metrics = {"moe_aux": aux}
    if counts is not None:
        metrics["expert_counts"] = counts
        metrics["expert_counts_per_layer"] = per_layer
    if return_hidden:
        return x, metrics
    return _lm_logits(params, cfg, x), metrics


def lm_head_weights(params: PyTree, cfg: ModelConfig) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def head_logits(head: jax.Array, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Vocab projection over the padded table; pad columns masked out."""
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    if cfg.padded_vocab != cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def _lm_logits(params: PyTree, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return head_logits(lm_head_weights(params, cfg), cfg, x)


# ---------------------------------------------------------------------------
# Decode (serve_step body)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16, abstract: bool = False,
               kv_quant: bool = False) -> PyTree:
    """KV/SSM cache pytree mirroring the segment structure.

    Windowed layers use a ring buffer of size ``window``; attention layers a
    full ``seq_len`` buffer; mamba layers carry (conv_state, ssm_state);
    cross-attn layers carry precomputed encoder K/V.

    ``kv_quant`` stores self-attention K/V rows as int8 with per-(token,
    head) absmax scales — 2x (vs bf16) cache memory at ~1e-2 relative
    error, the fit-enabler for the 90B-class serving plane (§Perf).
    """
    g, e = cfg.num_kv_heads, cfg.head_dim

    def make(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dtype=dt)

    def kv_entry(shp):
        if not kv_quant:
            return {"k": make(shp, dtype), "v": make(shp, dtype)}
        s_shp = shp[:-1] + (1,)
        return {"k": make(shp, jnp.int8), "v": make(shp, jnp.int8),
                "k_scale": make(s_shp, jnp.float32),
                "v_scale": make(s_shp, jnp.float32)}

    def layer_cache(spec: LayerSpec, repeats: int):
        if spec.mixer == ATTN:
            return kv_entry((repeats, batch, seq_len, g, e))
        if spec.mixer == LOCAL_ATTN:
            w = min(cfg.window_size, seq_len)
            return kv_entry((repeats, batch, w, g, e))
        if spec.mixer == CROSS_ATTN:
            shp = (repeats, batch, cfg.encoder_len, g, e)
            return {"xk": make(shp, dtype), "xv": make(shp, dtype)}
        if spec.mixer == MAMBA:
            ch = cfg.d_inner + 2 * cfg.ssm_state
            return {
                "conv": make((repeats, batch, cfg.ssm_conv_width - 1, ch),
                             dtype),
                "state": make((repeats, batch, cfg.ssm_num_heads,
                               cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            }
        raise ValueError(spec.mixer)

    return {
        "segments": [
            {f"pos{i}": layer_cache(spec, seg.repeats)
             for i, spec in enumerate(seg.pattern)}
            for seg in cfg.segments
        ],
    }


def precompute_cross_cache(params: PyTree, cfg: ModelConfig, cache: PyTree,
                           enc_context: jax.Array) -> PyTree:
    """Fill cross-attention K/V entries of ``cache`` from encoder context."""
    enc = (encode(params, cfg, enc_context) if cfg.is_encdec
           else enc_context.astype(jnp.dtype(cfg.dtype)))

    for seg, seg_params, seg_cache in zip(cfg.segments, params["segments"],
                                          cache["segments"]):
        for i, spec in enumerate(seg.pattern):
            if spec.mixer != CROSS_ATTN:
                continue
            lp = seg_params[f"pos{i}"]["mixer"]

            def fill(lp_r):
                k = jnp.einsum("btd,dgk->btgk", enc, lp_r["wk"])
                v = jnp.einsum("btd,dgk->btgk", enc, lp_r["wv"])
                if "bk" in lp_r:
                    k = k + lp_r["bk"]
                    v = v + lp_r["bv"]
                return k, v

            k, v = jax.vmap(fill)(lp)
            seg_cache[f"pos{i}"]["xk"] = k.astype(
                seg_cache[f"pos{i}"]["xk"].dtype)
            seg_cache[f"pos{i}"]["xv"] = v.astype(
                seg_cache[f"pos{i}"]["xv"].dtype)
    return cache


def _decode_mixer(spec: LayerSpec, p: dict, x, pos, cache: dict,
                  cfg: ModelConfig):
    h = rms_norm(x, p["norm"])
    if spec.mixer in (ATTN, LOCAL_ATTN):
        window = cfg.window_size if spec.mixer == LOCAL_ATTN else 0
        if window and cache["k"].shape[1] < window:
            window = cache["k"].shape[1]
        out, new_cache = attn.decode_self_attention(
            p, h, pos, cache, cfg=cfg, window=window)
        return out, new_cache
    if spec.mixer == CROSS_ATTN:
        out = attn.decode_cross_attention(p, h, cache["xk"], cache["xv"],
                                          cfg=cfg)
        return out, cache
    if spec.mixer == MAMBA:
        out, conv, state = ssm.mamba_decode_step(p, h, cache["conv"],
                                                 cache["state"], cfg)
        return out, {"conv": conv, "state": state}
    raise ValueError(spec.mixer)


def decode_step(params: PyTree, cfg: ModelConfig, cache: PyTree,
                tokens: jax.Array, pos: jax.Array):
    """One decode step. tokens (B,1) int32; pos (B,) int32 positions of the
    new token. Returns (logits (B,V), new_cache)."""
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))

    new_cache_segments = []
    for seg, seg_params, seg_cache in zip(cfg.segments, params["segments"],
                                          cache["segments"]):
        def body(x, xs, seg=seg):
            layer_params, layer_cache = xs
            new_cache = {}
            for i, spec in enumerate(seg.pattern):
                lp = layer_params[f"pos{i}"]
                dx, nc = _decode_mixer(spec, lp["mixer"], x, pos,
                                       layer_cache[f"pos{i}"], cfg)
                x = x + dx
                dxf, _, _ = _apply_ffn(spec, lp.get("ffn", {}), x, cfg)
                x = x + dxf
                new_cache[f"pos{i}"] = nc
            return x, new_cache

        x, new_seg_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_cache_segments.append(new_seg_cache)

    x = rms_norm(x, params["final_norm"])
    logits = _lm_logits(params, cfg, x)[:, 0]
    return logits, {"segments": new_cache_segments}
