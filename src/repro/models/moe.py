"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Dispatch positions come from a stable sort by expert (O(Tk log Tk) compares
— the original cumsum-over-(Tk, E) formulation exploded to ~10^16 counted
FLOPs on granite's fine-grained config; see EXPERIMENTS.md §Perf). Tokens
are scattered into per-expert capacity buffers, experts run as one batched
einsum ``...ecd,edf->...ecf`` (expert axis shardable over the ``model``
mesh axis), results are gathered back with the router combine weights.
Overflowing tokens are dropped (standard capacity-factor semantics); the
router aux loss encourages balance.

``cfg.moe_dispatch_groups = G > 1`` enables *group-local dispatch*: tokens
reshape to (G, T/G) with G aligned to the ``data`` mesh axis, positions and
capacity are computed per group, and the buffer lays out as (G, E, C/G, D)
sharded (data, model) — dispatch becomes shard-local (no cross-device
scatter), expert compute stays local per (group, expert) block, and only
the final combine crosses the ``model`` axis. This is the beyond-paper
collective optimization for the MoE training pairs (§Perf).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _maybe_wsc(x: jax.Array, *axes) -> jax.Array:
    """Sharding constraint if a mesh is in scope (launchers set one); plain
    identity in mesh-less unit tests. XLA's SPMD propagation replicates the
    grouped capacity buffers without these hints (measured: 28 GB fp32
    all-reduces of expert intermediates per dbrx layer)."""
    from repro.models.common import mesh_axis_names
    names = mesh_axis_names()
    if not names:
        return x
    spec = jax.sharding.PartitionSpec(
        *[a if (a is None or a in names) else None for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)


def moe_capacity(num_tokens: int, cfg: ModelConfig) -> int:
    cap = cfg.moe_capacity_factor * num_tokens * cfg.experts_per_token
    cap = int(math.ceil(cap / cfg.num_experts))
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def route(router_w: jax.Array, x: jax.Array, cfg: ModelConfig):
    """x (T, D) -> (expert_idx (T,k), combine (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.experts_per_token)     # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss.
    me = probs.mean(axis=0)                                      # (E,)
    onehot = jax.nn.one_hot(idx[:, 0], cfg.num_experts, dtype=jnp.float32)
    ce = onehot.mean(axis=0)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return idx, gate, aux


def _slot_positions(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """Rank of each (token, k) assignment within its expert, first-come-
    first-served in original order (stable sort preserves arrival order —
    identical drop semantics to the cumsum formulation, ~30x cheaper)."""
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))   # (E,)
    ranks_sorted = jnp.arange(tk, dtype=jnp.int32) - \
        starts[sorted_e].astype(jnp.int32)
    return jnp.zeros((tk,), jnp.int32).at[order].set(ranks_sorted)


def _dispatch(xt, idx, gate, cap, cfg):
    """xt (T, D); idx/gate (T, k) -> (buf (E, C, D), flat_e, slot_c, keep)."""
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    flat_e = idx.reshape(-1)
    slot = _slot_positions(flat_e, e)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, 0)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, cap, d), dtype=xt.dtype)
    buf = buf.at[flat_e, slot_c].add(
        jnp.where(keep[:, None], xt[tok_idx], 0).astype(xt.dtype),
        mode="drop")
    return buf, flat_e, slot_c, keep, tok_idx


def _combine(out_buf, flat_e, slot_c, keep, tok_idx, gate, t):
    """out_buf (E, C, D) -> (T, D) with router combine weights."""
    picked = out_buf[flat_e, slot_c]                            # (T*k, D)
    picked = picked * (gate.reshape(-1, 1)
                       * keep[:, None]).astype(picked.dtype)
    return jnp.zeros((t, out_buf.shape[-1]),
                     dtype=picked.dtype).at[tok_idx].add(picked)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig):
    """x (B, S, D) -> (out (B, S, D), aux_loss, expert_counts (E,)).

    ``expert_counts`` feeds the WeiPS sync engine (touched-expert IDs).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(t, d)
    idx, gate, aux = route(p["router"], xt, cfg)

    g = max(1, cfg.moe_dispatch_groups)
    if g > 1 and t % g == 0:
        tg = t // g
        cap = moe_capacity(tg, cfg)
        xg = xt.reshape(g, tg, d)
        idx_g = idx.reshape(g, tg, k)
        gate_g = gate.reshape(g, tg, k)

        def one_group(xg_, idx_, gate_):
            buf, flat_e, slot_c, keep, tok_idx = _dispatch(
                xg_, idx_, gate_, cap, cfg)
            return buf, (flat_e, slot_c, keep, tok_idx)

        bufs, meta = jax.vmap(one_group)(xg, idx_g, gate_g)     # (G,E,C,D)
        bufs = _maybe_wsc(bufs, "data", "model", None, None)
        h_gate = jnp.einsum("gecd,edf->gecf", bufs, p["w_gate"])
        h_up = jnp.einsum("gecd,edf->gecf", bufs, p["w_up"])
        h = jax.nn.silu(_maybe_wsc(h_gate, "data", "model", None, None)) \
            * _maybe_wsc(h_up, "data", "model", None, None)
        out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
        out_buf = _maybe_wsc(out_buf, "data", "model", None, None)

        def one_combine(ob, flat_e, slot_c, keep, tok_idx, gate_):
            return _combine(ob, flat_e, slot_c, keep, tok_idx, gate_, tg)

        out = jax.vmap(one_combine)(out_buf, *meta, gate_g)     # (G,TG,D)
        out = _maybe_wsc(out, "data", None, None)
        out = out.reshape(t, d)
        keep_all = meta[2].reshape(-1)
        onehot = jax.nn.one_hot(idx.reshape(-1), e, dtype=jnp.int32)
        counts = jnp.sum(onehot * keep_all[:, None].astype(jnp.int32),
                         axis=0)
        return out.reshape(b, s, d), aux, counts

    cap = moe_capacity(t, cfg)
    buf, flat_e, slot_c, keep, tok_idx = _dispatch(xt, idx, gate, cap, cfg)
    h_gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = _combine(out_buf, flat_e, slot_c, keep, tok_idx, gate, t)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    counts = jnp.sum(onehot * keep[:, None].astype(jnp.int32), axis=0)
    return out.reshape(b, s, d), aux, counts
