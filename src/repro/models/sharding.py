"""PartitionSpec generation for params, optimizer slots, caches and batches.

Layout policy (see DESIGN.md §5):
  * FSDP on the ``data`` axis (d_model / vocab rows), TP on ``model``
    (heads, ffn, experts, vocab-for-logits). The ``pod`` axis (multi-pod)
    joins batch sharding only — pure DP across pods, ICI-frugal.
  * GQA with few KV heads shards head_dim on ``model`` when divisible,
    otherwise replicates the KV projections.
  * Decode KV caches: batch -> data, sequence -> model (flash-decode
    combine); long_500k (batch=1) shards sequence over (data, model).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, CROSS_ATTN, ENC_ATTN, LOCAL_ATTN, MAMBA,
                                MLP, MOE, NONE, LayerSpec, ModelConfig, Segment)

PyTree = Any

DATA, MODEL, POD = "data", "model", "pod"


from dataclasses import dataclass


@dataclass(frozen=True)
class ShardingOptions:
    """Layout policy knobs, iterated by the §Perf hillclimb.

    embed_mode:
      * "fsdp" (baseline): embedding/lm-head P(model, data). The D axis is
        sharded on ``data``, which makes the logits einsum contract a
        sharded dimension — XLA all-reduces the full global logits tensor
        (measured: 318 GB/step on qwen1.5-4b train_4k).
      * "tp": P(model, None) — vocab-TP with replicated D. Logits compute
        locally as (B/data, S, V/model) blocks; only softmax stats and
        dx/dhead grads cross shards.

    fsdp:
      * True (baseline, training): weight D-axes sharded on ``data`` —
        every matmul allgathers its weight shard, amortized over thousands
        of tokens/device in training.
      * False (serving plane): weight-stationary TP — no per-step weight
        allgathers. This is the paper's heterogeneous master/slave layout
        split applied to the dense plane: the slave does NOT mirror the
        master's partitioning (measured: llama-90b decode_32k spends 28 ms
        of ICI time/token re-gathering FSDP weight shards).
    """

    embed_mode: str = "fsdp"
    fsdp: bool = True
    # serve layout when fsdp=False — selected by memory fit (launch/dryrun):
    #  * "tp":   weights sharded `model`-way only (16-way). Zero extra
    #            collectives at decode; needs params/16 + cache <= HBM
    #            (llama-90b w/ int8 cache: 14.4 GB — fits; measured
    #            2.1 ms/token collective).
    #  * "tp2d": feature axes over (model, data) = 256-way weights, D
    #            never sharded. Fits anything (jamba-398B: 4.3 GB/dev) at
    #            the cost of (B,1,·)-sized activation psums (12 ms/token).
    serve_layout: str = "tp"


class MeshInfo:
    """Axis sizes + derived batch sharding axes for a mesh."""

    def __init__(self, mesh: jax.sharding.Mesh,
                 opts: Optional[ShardingOptions] = None):
        self.mesh = mesh
        self.opts = opts or ShardingOptions()
        self.axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.data = self.axes.get(DATA, 1)
        self.model = self.axes.get(MODEL, 1)
        self.batch_axes = ((POD, DATA) if POD in self.axes else (DATA,))

    def div(self, n: int, axis: str) -> bool:
        return n % self.axes.get(axis, 1) == 0


def _attn_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    h, g, e = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q_ax = (1, MODEL) if m.div(h, MODEL) else (
        (2, MODEL) if m.div(e, MODEL) else None)
    kv_ax = (1, MODEL) if m.div(g, MODEL) else (
        (2, MODEL) if m.div(e, MODEL) else None)
    if cfg.context_parallel_attn:
        # sequence-sharded attention: projections keep FSDP only; sharding
        # head_dim on `model` would force full-score all-reduces.
        if not m.div(h, MODEL):
            q_ax = None
        if not m.div(g, MODEL):
            kv_ax = None

    if not m.opts.fsdp and m.opts.serve_layout == "tp2d":
        # serving (weight-stationary 2D TP): never shard the contraction
        # dim D; spread heads on `model` and head_dim on `data` when they
        # divide — weights stay resident, decode psums are (B,1,·)-sized.
        def serve_proj(n_heads):
            ax_h = MODEL if m.div(n_heads, MODEL) else None
            ax_e = DATA if (ax_h and m.div(e, DATA)) else (
                MODEL if (not ax_h and m.div(e, MODEL)) else None)
            return P(None, ax_h, ax_e)

        qp, kvp = serve_proj(h), serve_proj(g)
        specs = {
            "norm": P(None),
            "wq": qp, "wk": kvp, "wv": kvp,
            "wo": P(qp[1], qp[2], None),
        }
        if cfg.qkv_bias:
            specs["bq"] = P(qp[1], qp[2])
            specs["bk"] = P(kvp[1], kvp[2])
            specs["bv"] = P(kvp[1], kvp[2])
        return specs

    def proj(base_len, ax, d_axis_pos):
        spec = [None] * base_len
        spec[d_axis_pos] = DATA
        if ax is not None:
            spec[ax[0]] = ax[1]
        return P(*spec)

    specs = {
        "norm": P(None),
        "wq": proj(3, q_ax, 0),                     # (D,H,hd)
        "wk": proj(3, kv_ax, 0),                    # (D,Kv,hd)
        "wv": proj(3, kv_ax, 0),
        # wo (H,hd,D): mirror the q sharding, D -> data
        "wo": P(MODEL if (q_ax and q_ax[0] == 1) else None,
                MODEL if (q_ax and q_ax[0] == 2) else None, DATA),
    }
    if cfg.qkv_bias:
        specs["bq"] = P(MODEL if (q_ax and q_ax[0] == 1) else None,
                        MODEL if (q_ax and q_ax[0] == 2) else None)
        kv_b = P(MODEL if (kv_ax and kv_ax[0] == 1) else None,
                 MODEL if (kv_ax and kv_ax[0] == 2) else None)
        specs["bk"] = kv_b
        specs["bv"] = kv_b
    return specs


def _mlp_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    if not m.opts.fsdp and m.opts.serve_layout == "tp2d":
        # serving: F over (model, data) = full 2D TP, D unsharded; the
        # w_down psum is (B,1,D)-sized at decode.
        f2d = cfg.d_ff % (m.data * m.model) == 0
        ax = (MODEL, DATA) if f2d else MODEL
        return {
            "norm": P(None),
            "w_gate": P(None, ax),
            "w_up": P(None, ax),
            "w_down": P(ax, None),
        }
    return {
        "norm": P(None),
        "w_gate": P(DATA, MODEL),
        "w_up": P(DATA, MODEL),
        "w_down": P(MODEL, DATA),
    }


def _moe_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    if not m.opts.fsdp and m.opts.serve_layout == "tp2d":
        # serving: experts on `model`, expert-ffn on `data`, D unsharded.
        e_ax = MODEL if m.div(cfg.num_experts, MODEL) else None
        f_ax = DATA if m.div(cfg.d_ff, DATA) else (
            None if e_ax else MODEL)
        return {
            "norm": P(None),
            "router": P(None, None),
            "w_gate": P(e_ax, None, f_ax),
            "w_up": P(e_ax, None, f_ax),
            "w_down": P(e_ax, f_ax, None),
        }
    if m.div(cfg.num_experts, MODEL):
        up, down = P(MODEL, DATA, None), P(MODEL, None, DATA)
    else:
        up, down = P(None, DATA, MODEL), P(None, MODEL, DATA)
    return {
        "norm": P(None),
        "router": P(DATA, None),
        "w_gate": up,
        "w_up": up,
        "w_down": down,
    }


def _mamba_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    if not m.opts.fsdp and m.opts.serve_layout == "tp2d":
        di2d = cfg.d_inner % (m.data * m.model) == 0
        ax = (MODEL, DATA) if di2d else MODEL
        return {
            "norm": P(None),
            "wz": P(None, ax),
            "wx": P(None, ax),
            "wB": P(None, None),
            "wC": P(None, None),
            "wdt": P(None, None),
            "conv_w": P(None, None),
            "conv_b": P(None),
            "A_log": P(None),
            "D": P(None),
            "dt_bias": P(None),
            "gnorm": P(ax),
            "out_proj": P(ax, None),
        }
    return {
        "norm": P(None),
        "wz": P(DATA, MODEL),
        "wx": P(DATA, MODEL),
        "wB": P(DATA, None),
        "wC": P(DATA, None),
        "wdt": P(DATA, None),
        "conv_w": P(None, None),
        "conv_b": P(None),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "gnorm": P(None),
        "out_proj": P(MODEL, DATA),
    }


_MIXER_SPECS = {ATTN: _attn_specs, LOCAL_ATTN: _attn_specs,
                ENC_ATTN: _attn_specs, CROSS_ATTN: _attn_specs,
                MAMBA: _mamba_specs}
_FFN_SPECS = {MLP: _mlp_specs, MOE: _moe_specs}


def _stack(spec_tree: PyTree) -> PyTree:
    """Prepend a None (the scan/repeats axis) to every PartitionSpec."""
    return jax.tree.map(lambda s: P(None, *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _segment_specs(seg: Segment, cfg: ModelConfig, m: MeshInfo) -> dict:
    out = {}
    for i, spec in enumerate(seg.pattern):
        layer = {"mixer": _MIXER_SPECS[spec.mixer](cfg, m)}
        if spec.ffn != NONE:
            layer["ffn"] = _FFN_SPECS[spec.ffn](cfg, m)
        out[f"pos{i}"] = _stack(layer)
    return out


def _strip_axis(spec_tree: PyTree, axis: str) -> PyTree:
    """Replace ``axis`` with None in every PartitionSpec of the tree."""
    def fix(p: P) -> P:
        return P(*[None if ax == axis else ax for ax in p])
    return jax.tree.map(fix, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_pspecs(cfg: ModelConfig, m: MeshInfo) -> PyTree:
    """PartitionSpec tree mirroring ``init_params`` output."""
    if not m.opts.fsdp:
        # serving: vocab sharding only, D unsharded — no gather on the
        # lookup/logit paths (2D = 256-way for the big-model layout).
        embed = (P((MODEL, DATA), None) if m.opts.serve_layout == "tp2d"
                 else P(MODEL, None))
    elif m.opts.embed_mode == "fsdp":
        embed = P(MODEL, DATA)
    else:
        embed = P(MODEL, None)
    specs: dict = {
        "embed": embed,
        "final_norm": P(None),
        "segments": [_segment_specs(s, cfg, m) for s in cfg.segments],
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = embed
    if cfg.encoder_segments:
        specs["encoder"] = {
            "segments": [_segment_specs(s, cfg, m)
                         for s in cfg.encoder_segments],
            "final_norm": P(None),
        }
    if not m.opts.fsdp and m.opts.serve_layout == "tp":
        # pure TP-16 serving: train layout minus the FSDP data axis
        specs = _strip_axis(specs, DATA)
    return specs


def cache_pspecs(cfg: ModelConfig, m: MeshInfo, batch: int,
                 kv_quant: bool = False) -> PyTree:
    """PartitionSpec tree mirroring ``init_cache`` output.

    batch >= data-axis size: batch -> data, seq -> model.
    batch == 1 (long-context): seq -> (data, model).
    """
    shard_seq_wide = batch < m.data

    def kv_spec(seq_len_small: bool):
        # (R, B, S, Kv, hd) — scale entries share the leading axes
        if shard_seq_wide:
            return P(None, None, (DATA, MODEL), None, None)
        if seq_len_small:
            return P(None, DATA, None, None, None)
        return P(None, DATA, MODEL, None, None)

    def kv_entry(s):
        if not kv_quant:
            return {"k": s, "v": s}
        return {"k": s, "v": s, "k_scale": s, "v_scale": s}

    def layer_cache(spec: LayerSpec):
        if spec.mixer == ATTN:
            return kv_entry(kv_spec(False))
        if spec.mixer == LOCAL_ATTN:
            return kv_entry(kv_spec(True))          # ring buffer of size W
        if spec.mixer == CROSS_ATTN:
            s = kv_spec(True)
            return {"xk": s, "xv": s}
        if spec.mixer == MAMBA:
            b_ax = None if shard_seq_wide else DATA
            h_ax = MODEL if m.div(cfg.ssm_num_heads, MODEL) else None
            return {
                "conv": P(None, b_ax, None, None),
                "state": P(None, b_ax, h_ax, None, None),
            }
        raise ValueError(spec.mixer)

    return {
        "segments": [
            {f"pos{i}": layer_cache(spec)
             for i, spec in enumerate(seg.pattern)}
            for seg in cfg.segments
        ],
    }


def batch_pspecs(cfg: ModelConfig, m: MeshInfo, kind: str,
                 global_batch: int) -> dict:
    """Input shardings for train/prefill batches or decode requests."""
    b_ax = m.batch_axes if global_batch >= m.data else None
    out = {"tokens": P(b_ax, None)}
    if cfg.has_encoder_context:
        out["enc_context"] = P(b_ax, None, None)
    if kind == "decode":
        out["pos"] = P(b_ax)
    return out


def logical_axis_constraint(x: jax.Array, m: Optional[MeshInfo],
                            spec: P) -> jax.Array:
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(m.mesh, spec))
