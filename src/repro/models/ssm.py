"""Mamba-2 (SSD — state-space duality) blocks: chunked quadratic-within-
chunk / linear-across-chunk training & prefill path, and O(1)-state decode.

Follows the SSD formulation of arXiv:2405.21060 (single B/C group):
    h_t = exp(dt_t·A) h_{t-1} + dt_t · x_t ⊗ B_t        (state (H, P, N))
    y_t = C_t · h_t + D ⊙ x_t
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rms_norm


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d. x (B,S,C); w (K,C); b (C,).

    Returns (y (B,S,C), new_state (B,K-1,C)). ``state`` carries the last
    K-1 inputs for decode continuity (zeros for a fresh sequence)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), dtype=x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                 # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def ssd_chunked(x, dt, A, B, C, chunk: int,
                initial_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x (b,s,h,p); dt (b,s,h) positive; A (h,) negative; B, C (b,s,n).
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s_orig, h, p = x.shape
    n = B.shape[-1]
    pad = (-s_orig) % chunk
    if pad:
        # zero-pad: dt=0 gives decay exp(0)=1 and zero input contribution,
        # so padded steps are identity on the state and emit garbage rows
        # that are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc, l = s // chunk, chunk
    f32 = jnp.float32

    xdt = (x * dt[..., None]).astype(f32)                    # dt-discretized input
    dA = (dt * A).astype(f32)                                # (b,s,h), negative
    xdt = xdt.reshape(b, nc, l, h, p)
    dA = dA.reshape(b, nc, l, h)
    Bc = B.reshape(b, nc, l, n).astype(f32)
    Cc = C.reshape(b, nc, l, n).astype(f32)

    dA_cs = jnp.cumsum(dA, axis=2)                           # (b,nc,l,h) inclusive

    # --- intra-chunk (quadratic within the chunk) ----------------------
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (b,nc,i,j,h)
    causal = jnp.tril(jnp.ones((l, l), dtype=bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)               # (b,nc,l,l)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", CB, L, xdt)

    # --- chunk states ---------------------------------------------------
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (b,nc,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xdt)

    # --- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # (b,nc,h)
    h0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((b, h, p, n), dtype=f32))

    def body(carry, xs):
        st, dec = xs                                         # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                    # emit PREVIOUS state

    final_state, prev_states = jax.lax.scan(
        body, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b,nc,h,p,n)

    # --- contribution of carried-in state --------------------------------
    state_decay = jnp.exp(dA_cs)                             # (b,nc,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrence. state (b,h,p,n); x (b,h,p); dt (b,h);
    A (h,); B, C (b,n). Returns (y (b,h,p), new_state)."""
    f32 = jnp.float32
    decay = jnp.exp((dt * A).astype(f32))                    # (b,h)
    upd = jnp.einsum("bhp,bn->bhpn", (x * dt[..., None]).astype(f32),
                     B.astype(f32))
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(f32))
    return y.astype(x.dtype), new_state


def _projections(p: dict, x: jax.Array, cfg: ModelConfig):
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xin = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bv = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cv = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    return z, xin, Bv, Cv, dt_raw


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig,
                initial_state=None, return_state: bool = False):
    """Full Mamba-2 mixer for train/prefill. x (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
    hp = cfg.ssm_head_dim
    z, xin, Bv, Cv, dt_raw = _projections(p, x, cfg)

    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)        # (B,S,di+2n)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :di]
    Bv = conv_out[..., di:di + ns]
    Cv = conv_out[..., di + ns:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, s, nh, hp)
    y, final_state = ssd_chunked(xh, dt, A, Bv, Cv, cfg.ssm_chunk,
                                 initial_state=initial_state)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gnorm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        return out, final_state
    return out


def mamba_decode_step(p: dict, x: jax.Array, conv_state: jax.Array,
                      ssm_state: jax.Array, cfg: ModelConfig):
    """One-token decode. x (B,1,D); conv_state (B,K-1,di+2n);
    ssm_state (B,H,P,N) fp32. Returns (out (B,1,D), conv_state, ssm_state).
    """
    b = x.shape[0]
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
    hp = cfg.ssm_head_dim
    z, xin, Bv, Cv, dt_raw = _projections(p, x, cfg)

    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)        # (B,1,di+2n)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        state=conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :di]
    Bv = conv_out[..., di:di + ns]
    Cv = conv_out[..., di + ns:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin[:, 0].reshape(b, nh, hp)
    y, ssm_state = ssd_decode_step(ssm_state, xh, dt, A, Bv[:, 0], Cv[:, 0])
    y = y + p["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gnorm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, conv_state, ssm_state
