"""Unified observability subsystem (PR 10).

Two halves, both pure stdlib so any layer (core, serving, launch) can
import them without adding jax/numpy cost to the hot paths they watch:

* ``obs.trace``   — a low-overhead span tracer (preallocated ring
  buffer, injectable clock, ~zero cost disabled). Trace ids are stamped
  into ``Record.meta`` at the Pusher and ride the FileQueue frames, so
  one streaming update is a single causal span tree across OS
  processes: push → queue-dwell → scatter-apply → cache-invalidate.
* ``obs.perfetto`` — Chrome/Perfetto JSON trace export + import +
  cross-process merge.
* ``obs.metrics`` — a ``MetricsRegistry`` of counters/gauges/histograms
  and provider dicts under stable dotted names; the subsystem counters
  (cluster, serving, training, workers) publish into it and
  ``WeiPSCluster.sync_metrics()`` is a thin view over it.

``python -m repro.obs.trace <dump.json>`` summarizes an exported trace
(per-stage p50/p99, slowest-trace tree). See docs/OBSERVABILITY.md.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import Tracer, configure, disable, get_tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Tracer", "configure", "disable", "get_tracer",
]
