"""MetricsRegistry: stable dotted names over the repo's ad-hoc dicts.

Every subsystem already keeps counters (`Pusher.pushed_bytes`,
`AdmissionStats`, `ServeCache.stats()`, `_DeviceMirror` sync counts …)
and exposes them through per-plane ``metrics()`` dicts. The registry
gives them one namespace:

* primitives — ``counter(name)`` / ``gauge(name)`` / ``histogram(name)``
  for new code that wants owned metric objects;
* providers — ``register(prefix, fn)`` publishes an *existing* counter
  or dict under a dotted prefix. ``fn`` may take the current clock
  (``fn(now)``) or nothing (``fn()``); arity is detected once at
  registration so collection stays cheap.

``tree(now)`` assembles the nested dict (this is what
``WeiPSCluster.sync_metrics`` returns — providers registered at the
pre-PR-10 key paths make it a thin view with an unchanged schema), and
``collect(now)`` flattens it to ``{"serving.latency.p99": ...}`` dotted
names — the shape the worker `metrics` RPC aggregation and the
`scripts/check_metrics_docs.py` lint consume.

Pure stdlib; safe to import from any hot path.
"""

from __future__ import annotations

import inspect
from typing import Callable, Optional


def join(prefix: str, name: str) -> str:
    """Dotted join that tolerates an empty prefix."""
    return f"{prefix}.{name}" if prefix else name


class Counter:
    """Monotonic count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value: either ``set()`` or backed by a callable."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._value = 0.0
        self._fn = fn

    def set(self, value: float) -> None:
        self._value = value

    @property
    def value(self):
        return self._fn() if self._fn is not None else self._value


class Histogram:
    """Windowed reservoir -> count/p50/p99 snapshot (pure python ring)."""

    __slots__ = ("name", "count", "_buf", "_cap", "_i")

    def __init__(self, name: str, window: int = 4096):
        self.name = name
        self.count = 0
        self._cap = int(window)
        self._buf: list = [0.0] * self._cap
        self._i = 0

    def record(self, value: float) -> None:
        self._buf[self._i] = value
        self._i = (self._i + 1) % self._cap
        self.count += 1

    def percentiles(self, qs=(50, 99)) -> dict:
        n = min(self.count, self._cap)
        vals = sorted(self._buf[:n])
        out = {}
        for q in qs:
            if not vals:
                out[f"p{q}"] = 0.0
                continue
            k = (len(vals) - 1) * (q / 100.0)
            lo = int(k)
            hi = min(lo + 1, len(vals) - 1)
            out[f"p{q}"] = vals[lo] + (vals[hi] - vals[lo]) * (k - lo)
        return out

    def snapshot(self) -> dict:
        return {"count": self.count, **self.percentiles()}


class MetricsRegistry:
    """Counters/gauges/histograms + provider dicts under dotted names."""

    def __init__(self):
        self._metrics: dict = {}     # name -> Counter | Gauge | Histogram
        self._providers: list = []   # (prefix, fn, wants_now)
        self._names: set = set()

    # -- owned primitives --------------------------------------------

    def counter(self, name: str) -> Counter:
        return self._add(Counter(name))

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        return self._add(Gauge(name, fn))

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._add(Histogram(name, window))

    def _add(self, m):
        self._claim(m.name)
        self._metrics[m.name] = m
        return m

    # -- providers ----------------------------------------------------

    def register(self, prefix: str, fn: Callable) -> None:
        """Publish ``fn``'s scalar-or-nested-dict result under
        ``prefix``. ``fn`` may accept the collection clock (``fn(now)``)
        or no arguments."""
        self._claim(prefix)
        try:
            wants_now = len(inspect.signature(fn).parameters) >= 1
        except (TypeError, ValueError):  # builtins without signatures
            wants_now = False
        self._providers.append((prefix, fn, wants_now))

    def _claim(self, name: str) -> None:
        if not name and self._names:
            raise ValueError("empty prefix collides with everything")
        if name in self._names:
            raise ValueError(f"metric {name!r} already registered")
        self._names.add(name)

    # -- collection ---------------------------------------------------

    def tree(self, now: float = 0.0) -> dict:
        """The nested metrics dict (dotted names split into levels)."""
        out: dict = {}
        for name, m in self._metrics.items():
            _set_path(out, name, m.snapshot() if isinstance(m, Histogram)
                      else m.value)
        for prefix, fn, wants_now in self._providers:
            _set_path(out, prefix, fn(now) if wants_now else fn())
        return out

    def collect(self, now: float = 0.0) -> dict:
        """Flat ``{dotted name: leaf value}`` view of ``tree(now)``."""
        return _flatten(self.tree(now))

    def names(self, now: float = 0.0) -> list:
        """Sorted dotted leaf names currently published."""
        return sorted(self.collect(now))


def _set_path(out: dict, dotted: str, value) -> None:
    parts = dotted.split(".") if dotted else []
    if not parts:
        if isinstance(value, dict):
            out.update(value)
        return
    node = out
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    leaf = parts[-1]
    if isinstance(value, dict) and isinstance(node.get(leaf), dict):
        node[leaf].update(value)
    else:
        node[leaf] = value


def _flatten(tree: dict, prefix: str = "") -> dict:
    flat: dict = {}
    for k, v in tree.items():
        name = join(prefix, str(k))
        if isinstance(v, dict):
            flat.update(_flatten(v, name))
        else:
            flat[name] = v
    return flat
