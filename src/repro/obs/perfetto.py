"""Chrome/Perfetto trace-event JSON for `repro.obs.trace` spans.

The on-disk format is the Chrome Trace Event JSON object form
(https://ui.perfetto.dev loads it directly): spans become "X" complete
events (ts/dur in microseconds, rebased to the earliest span), instant
annotations become "i" events, and each trace id additionally emits
flow events ("s" start / "t" step) so Perfetto draws arrows across the
process tracks of one causal chain. Per-process "M" metadata events
name the tracks after the tracer's process string.

Span identity (trace/span/parent ids) rides in each event's ``args``,
which makes the file round-trippable: ``load_spans`` reconstructs the
span dicts, and ``merge_spans`` combines exports from many processes
(supervisor ring + worker ``trace_dump`` RPCs + pre-kill dump files)
into one deduplicated timeline.
"""

from __future__ import annotations

import json

_SPAN_KEYS = ("trace", "span", "parent")


def to_chrome(spans: list) -> dict:
    """Chrome trace-event JSON object for a list of span dicts."""
    spans = [s for s in spans if s]
    procs = sorted({s["proc"] for s in spans})
    pid = {p: i + 1 for i, p in enumerate(procs)}
    base = min((s["t0"] for s in spans), default=0.0)
    events = [
        {"ph": "M", "name": "process_name", "pid": i, "tid": 0,
         "args": {"name": p}}
        for p, i in pid.items()
    ]
    flow_started: set = set()
    for s in sorted(spans, key=lambda s: s["t0"]):
        ts = (s["t0"] - base) * 1e6
        args = {"trace": s["trace"], "span": s["span"],
                "parent": s["parent"]}
        args.update(s.get("args") or {})
        common = {"name": s["name"], "cat": "weips",
                  "pid": pid[s["proc"]], "tid": 0, "args": args}
        if s["t1"] is None:
            events.append({**common, "ph": "i", "ts": ts, "s": "p"})
        else:
            dur = max(0.0, (s["t1"] - s["t0"]) * 1e6)
            events.append({**common, "ph": "X", "ts": ts, "dur": dur})
        tid = s["trace"]
        if tid:
            ph = "s" if tid not in flow_started else "t"
            flow_started.add(tid)
            events.append({"ph": ph, "id": tid, "name": "update",
                           "cat": "sync", "pid": pid[s["proc"]],
                           "tid": 0, "ts": ts})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"t_base": base, "format": "repro.obs/1"}}


def write_trace(path: str, spans: list) -> int:
    """Write spans as a Perfetto-loadable file; returns span count."""
    doc = to_chrome(spans)
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for e in doc["traceEvents"] if e["ph"] in ("X", "i"))


def load_spans(path: str) -> list:
    """Inverse of write_trace: span dicts back out of a trace file."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", [])
    base = doc.get("otherData", {}).get("t_base", 0.0)
    proc = {e["pid"]: e["args"]["name"] for e in events
            if e.get("ph") == "M" and e.get("name") == "process_name"}
    spans = []
    for e in events:
        if e.get("ph") not in ("X", "i") or "span" not in e.get("args", {}):
            continue
        a = e["args"]
        t0 = base + e["ts"] / 1e6
        t1 = t0 + e["dur"] / 1e6 if e["ph"] == "X" else None
        d = {"name": e["name"], "proc": proc.get(e["pid"], str(e["pid"])),
             "trace": a["trace"], "span": a["span"],
             "parent": a["parent"], "t0": t0, "t1": t1}
        extra = {k: v for k, v in a.items() if k not in _SPAN_KEYS}
        if extra:
            d["args"] = extra
        spans.append(d)
    return spans


def merge_spans(*span_lists) -> list:
    """Merge per-process exports into one t0-ordered list.

    Dedup key is the pid-salted span id (plus name, so a respawned
    worker that reuses a pid cannot silently swallow a span from its
    previous life's dump file).
    """
    seen: set = set()
    out = []
    for spans in span_lists:
        for s in spans or ():
            key = (s["span"], s["name"], s["t0"])
            if key in seen:
                continue
            seen.add(key)
            out.append(s)
    out.sort(key=lambda s: s["t0"])
    return out
