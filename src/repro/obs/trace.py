"""Low-overhead span tracer for the streaming update path.

Design constraints, in order:

* **~zero cost disabled.** The module-global tracer starts disabled;
  hot paths guard with ``if tr.enabled:`` (one attribute read) or call
  ``tr.begin(...)`` unconditionally and get back a shared no-op span.
  `benchmarks/obs_overhead.py` gates both regimes.
* **Low overhead enabled.** Spans land in a preallocated ring buffer of
  plain tuples — no allocation beyond the tuple itself, no locks (each
  OS process owns its tracer; the runtime merges exports), no I/O until
  ``export()``.
* **Cross-process causality.** Span/trace ids are salted with the pid
  so merged dumps never collide, and the default clock is
  ``time.perf_counter`` — CLOCK_MONOTONIC on Linux, which is
  system-wide, so timestamps from different processes line up on one
  Perfetto timeline. The Pusher stamps ``trace``/``span``/``t_push``
  into ``Record.meta``, which crosses the FileQueue for free (records
  are whole-pickled frames), letting the consumer reconstruct the
  queue-dwell span and parent the apply under it.

Run ``python -m repro.obs.trace dump.json`` to summarize an exported
trace: per-stage span counts and p50/p99 durations, plus the slowest
trace printed as a causal tree.
"""

from __future__ import annotations

import os
import time
from typing import Callable, Optional


class _NullSpan:
    """Shared no-op returned by a disabled tracer's ``begin``/``span``."""

    __slots__ = ()
    id = 0
    trace = 0
    t0 = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "trace", "id", "parent", "t0", "attrs")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tracer.end(self)
        return False


class Tracer:
    """Ring-buffered span recorder. One per OS process.

    Spans are stored as ``(name, trace, span, parent, t0, t1, attrs)``
    tuples; ``t1 is None`` marks an instant annotation. ``export()``
    returns dicts in ring order (oldest first) tagged with this
    tracer's process name.
    """

    def __init__(
        self,
        *,
        capacity: int = 1 << 15,
        clock: Optional[Callable[[], float]] = None,
        process: str = "main",
        enabled: bool = True,
    ):
        self.enabled = enabled
        self.clock = clock or time.perf_counter
        self.process = process
        self.capacity = int(capacity)
        self._buf: list = [None] * self.capacity
        self._n = 0  # spans ever recorded (ring wraps past capacity)
        self._ctx: list = []  # (trace, span) stack for implicit parenting
        self._open: dict = {}  # id -> _Span, begun but not yet ended
        # pid-salted id base: spans from different processes never
        # collide when their exports are merged supervisor-side
        self._base = (os.getpid() & 0xFFFF) << 32
        self._next = 0

    # -- ids ----------------------------------------------------------

    def _new_id(self) -> int:
        self._next += 1
        return self._base | self._next

    def new_trace(self) -> int:
        """Fresh trace id for a new causal chain (one pusher flush)."""
        return self._new_id()

    def current(self) -> tuple:
        """(trace, span) of the innermost open span, or (0, 0)."""
        return self._ctx[-1] if self._ctx else (0, 0)

    @property
    def dropped(self) -> int:
        """Spans evicted by ring wrap-around."""
        return max(0, self._n - self.capacity)

    # -- recording ----------------------------------------------------

    def begin(self, name: str, *, trace: Optional[int] = None,
              parent: Optional[int] = None, **attrs):
        """Open a span; close it with ``end`` or use as a context
        manager (``span`` is an alias). Unspecified trace/parent come
        from the innermost open span, so nesting is implicit."""
        if not self.enabled:
            return _NULL_SPAN
        if trace is None:
            trace, ctx_parent = self.current()
            if parent is None:
                parent = ctx_parent
        elif parent is None:
            parent = 0
        sp = _Span()
        sp._tracer = self
        sp.name = name
        sp.trace = trace
        sp.parent = parent
        sp.id = self._new_id()
        sp.attrs = attrs or None
        self._ctx.append((trace, sp.id))
        self._open[sp.id] = sp
        sp.t0 = self.clock()
        return sp

    span = begin

    def end(self, sp) -> None:
        if sp is _NULL_SPAN:
            return
        t1 = self.clock()
        self._open.pop(sp.id, None)
        if self._ctx:
            if self._ctx[-1][1] == sp.id:          # common case: LIFO
                self._ctx.pop()
            else:                                  # out-of-order end
                for i in range(len(self._ctx) - 1, -1, -1):
                    if self._ctx[i][1] == sp.id:
                        del self._ctx[i]
                        break
        self._put(sp.name, sp.trace, sp.id, sp.parent, sp.t0, t1, sp.attrs)

    def record(self, name: str, *, t0: float, t1: float, trace: int = 0,
               parent: int = 0, **attrs) -> int:
        """Record a completed span with explicit timestamps — used for
        spans reconstructed after the fact, like queue dwell measured
        from a record's ``t_push`` stamp at the consumer. Returns the
        new span id (0 when disabled)."""
        if not self.enabled:
            return 0
        sid = self._new_id()
        self._put(name, trace, sid, parent, t0, t1, attrs or None)
        return sid

    def instant(self, name: str, *, trace: Optional[int] = None,
                **attrs) -> int:
        """Zero-duration annotation (fault firings, recovery markers)."""
        if not self.enabled:
            return 0
        ctx_trace, ctx_parent = self.current()
        if trace is None:
            trace = ctx_trace
        sid = self._new_id()
        self._put(name, trace, sid, ctx_parent, self.clock(), None,
                  attrs or None)
        return sid

    def _put(self, name, trace, sid, parent, t0, t1, attrs) -> None:
        self._buf[self._n % self.capacity] = (
            name, trace, sid, parent, t0, t1, attrs)
        self._n += 1

    # -- export -------------------------------------------------------

    def export(self) -> list:
        """Span dicts, oldest first."""
        n, cap = self._n, self.capacity
        if n <= cap:
            entries = self._buf[:n]
        else:
            k = n % cap
            entries = self._buf[k:] + self._buf[:k]
        out = []
        for name, trace, sid, parent, t0, t1, attrs in entries:
            d = {"name": name, "proc": self.process, "trace": trace,
                 "span": sid, "parent": parent, "t0": t0, "t1": t1}
            if attrs:
                d["args"] = dict(attrs)
            out.append(d)
        # still-open spans export too, clipped at "now" and flagged
        # partial — a SIGKILL mid-span (the pre-kill dump hook) must
        # not orphan children whose parent never reached the ring
        if self._open:
            t1 = self.clock()
            for sp in sorted(self._open.values(), key=lambda s: s.t0):
                d = {"name": sp.name, "proc": self.process,
                     "trace": sp.trace, "span": sp.id,
                     "parent": sp.parent, "t0": sp.t0, "t1": t1,
                     "args": dict(sp.attrs or (), partial=True)}
                out.append(d)
        return out

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._n = 0
        self._ctx = []
        self._open = {}


# -- module-global tracer ---------------------------------------------
# Disabled by default with a 1-slot ring so an untraced process pays
# one tiny object. configure() swaps in a live tracer.

_tracer = Tracer(enabled=False, capacity=1)


def get_tracer() -> Tracer:
    return _tracer


def configure(*, enabled: bool = True, capacity: int = 1 << 15,
              clock: Optional[Callable[[], float]] = None,
              process: str = "main") -> Tracer:
    """Install (and return) a fresh process-global tracer."""
    global _tracer
    _tracer = Tracer(capacity=capacity, clock=clock, process=process,
                     enabled=enabled)
    return _tracer


def disable() -> Tracer:
    """Back to the zero-cost disabled state."""
    return configure(enabled=False, capacity=1)


# -- viewer / summarizer ----------------------------------------------

def _percentile(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    k = (len(sorted_vals) - 1) * (q / 100.0)
    lo = int(k)
    hi = min(lo + 1, len(sorted_vals) - 1)
    return sorted_vals[lo] + (sorted_vals[hi] - sorted_vals[lo]) * (k - lo)


def stage_stats(spans: list) -> dict:
    """Per-stage (span name) count + p50/p99 duration in ms."""
    by_name: dict = {}
    for s in spans:
        if s["t1"] is None:
            continue
        by_name.setdefault(s["name"], []).append(
            max(0.0, s["t1"] - s["t0"]) * 1e3)
    out = {}
    for name in sorted(by_name):
        vals = sorted(by_name[name])
        out[name] = {"count": len(vals),
                     "p50_ms": _percentile(vals, 50),
                     "p99_ms": _percentile(vals, 99)}
    return out


def trace_groups(spans: list) -> dict:
    """Spans grouped by non-zero trace id, each sorted by t0."""
    groups: dict = {}
    for s in spans:
        if s["trace"]:
            groups.setdefault(s["trace"], []).append(s)
    for g in groups.values():
        g.sort(key=lambda s: s["t0"])
    return groups


def slowest_traces(spans: list, n: int = 3) -> list:
    """The n longest traces as (trace_id, duration_s, spans)."""
    scored = []
    for tid, group in trace_groups(spans).items():
        t0 = min(s["t0"] for s in group)
        t1 = max(s["t1"] if s["t1"] is not None else s["t0"] for s in group)
        scored.append((tid, t1 - t0, group))
    scored.sort(key=lambda x: -x[1])
    return scored[:n]


def format_tree(group: list, t_base: Optional[float] = None) -> str:
    """Render one trace's spans as an indented causal tree."""
    if t_base is None:
        t_base = min(s["t0"] for s in group)
    ids = {s["span"] for s in group}
    kids: dict = {}
    roots = []
    for s in group:
        if s["parent"] in ids:
            kids.setdefault(s["parent"], []).append(s)
        else:
            roots.append(s)
    lines: list = []

    def walk(s, depth):
        dur = "" if s["t1"] is None else f" {1e3 * (s['t1'] - s['t0']):8.3f}ms"
        extra = f"  {s['args']}" if s.get("args") else ""
        lines.append(f"  {1e3 * (s['t0'] - t_base):9.3f}ms "
                     f"{'  ' * depth}{s['name']} [{s['proc']}]{dur}{extra}")
        for c in sorted(kids.get(s["span"], []), key=lambda c: c["t0"]):
            walk(c, depth + 1)

    for r in sorted(roots, key=lambda s: s["t0"]):
        walk(r, 0)
    return "\n".join(lines)


def summarize(spans: list, slowest: int = 3) -> str:
    """Human-readable report: per-stage p50/p99 + slowest-trace trees."""
    lines = [f"{len(spans)} spans, "
             f"{len(trace_groups(spans))} traces, "
             f"{len({s['proc'] for s in spans})} processes", "",
             f"{'stage':<28}{'count':>8}{'p50_ms':>10}{'p99_ms':>10}"]
    for name, st in stage_stats(spans).items():
        lines.append(f"{name:<28}{st['count']:>8}"
                     f"{st['p50_ms']:>10.3f}{st['p99_ms']:>10.3f}")
    annotations = [s for s in spans if s["t1"] is None]
    if annotations:
        lines.append("")
        lines.append("annotations:")
        for s in annotations:
            extra = f"  {s['args']}" if s.get("args") else ""
            lines.append(f"  {s['name']} [{s['proc']}]{extra}")
    for tid, dur, group in slowest_traces(spans, slowest):
        lines.append("")
        lines.append(f"trace {tid:#x}  ({1e3 * dur:.3f}ms, "
                     f"{len(group)} spans)")
        lines.append(format_tree(group))
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    from repro.obs import perfetto

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.trace",
        description="Summarize an exported Perfetto/Chrome trace: "
                    "per-stage p50/p99 and the slowest causal trees.")
    ap.add_argument("path", help="trace JSON written by obs.perfetto")
    ap.add_argument("--slowest", type=int, default=3, metavar="N",
                    help="how many slowest traces to dump (default 3)")
    args = ap.parse_args(argv)
    spans = perfetto.load_spans(args.path)
    if not spans:
        print(f"{args.path}: no spans")
        return 1
    print(summarize(spans, slowest=args.slowest))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
