from repro.optim.optimizers import (Adafactor, Adagrad, Adam, FTRL, Momentum,
                                    Optimizer, SGD, get_optimizer)

__all__ = ["Adafactor", "Adagrad", "Adam", "FTRL", "Momentum", "Optimizer",
           "SGD", "get_optimizer"]
