"""Optimizers with *named slots*, the contract the WeiPS parameter server
and its train→serve transform operate on (paper §1.2.1 "heterogeneous
parameters").

Each optimizer exposes:
  * ``init_slots(param)``       — auxiliary training state per parameter;
  * ``update(param, slots, grad, step)`` — one step, elementwise, so it
    applies identically to dense tensors and to gathered sparse rows;
  * ``serve_weights(param, slots)`` — the *inference* weights. Identity for
    most optimizers; FTRL derives ``w`` from ``z, n`` (the paper's flagship
    case: the master mainly stores ``z, n``; the slave stores only ``w``).
  * ``serve_slot_names`` — which slots the transform must read to build
    serve weights (everything else is never shipped to slaves).

All math is fp32 regardless of param dtype; params are cast back.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _f32(x):
    return x.astype(jnp.float32)


@dataclass(frozen=True)
class Optimizer:
    lr: float = 1e-3

    name: str = "base"
    serve_slot_names: tuple[str, ...] = ()

    def init_slots(self, param: jax.Array) -> dict[str, jax.Array]:
        return {}

    def update(self, param, slots, grad, step):
        raise NotImplementedError

    def serve_weights(self, param: jax.Array, slots: dict) -> jax.Array:
        return param

    def serve_weights_np(self, param: np.ndarray, slots: dict) -> np.ndarray:
        """CPU-native ``serve_weights`` for the sync plane's numpy codec
        backend: the pusher encodes whole 65k-row flushes, where per-op
        eager-JAX dispatch (not FLOPs) dominates. Default falls through to
        the jnp path; optimizers with a numpy mirror override this."""
        return np.asarray(self.serve_weights(
            jnp.asarray(param),
            {k: jnp.asarray(v) for k, v in slots.items()}))

    # -- batched PS row path -------------------------------------------
    def update_rows(self, w: np.ndarray, slots: dict, grads: np.ndarray,
                    step: int, *, backend: str = "numpy"):
        """One batched update over gathered (B, D) sparse rows — the
        MasterShard hot path. Returns NumPy (new_w, new_slots). The base
        implementation routes through ``update``; optimizers with a fused
        Pallas kernel override this and dispatch on ``backend``."""
        new_w, new_slots = self.update(
            jnp.asarray(w), {k: jnp.asarray(v) for k, v in slots.items()},
            jnp.asarray(grads), step)
        return np.asarray(new_w), {k: np.asarray(v)
                                   for k, v in new_slots.items()}

    # -- pytree conveniences -------------------------------------------
    def init_slots_tree(self, params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: self.init_slots(p), params)

    def update_tree(self, params: PyTree, slots: PyTree, grads: PyTree, step):
        flat_p, tdef = jax.tree.flatten(params)
        flat_s = tdef.flatten_up_to(slots)
        flat_g = tdef.flatten_up_to(grads)
        out = [self.update(p, s, g, step)
               for p, s, g in zip(flat_p, flat_s, flat_g)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, new_s


@dataclass(frozen=True)
class SGD(Optimizer):
    name: str = "sgd"

    def update(self, param, slots, grad, step):
        new = _f32(param) - self.lr * _f32(grad)
        return new.astype(param.dtype), slots


@dataclass(frozen=True)
class Momentum(Optimizer):
    momentum: float = 0.9
    name: str = "momentum"

    def init_slots(self, param):
        return {"m": jnp.zeros(param.shape, jnp.float32)}

    def update(self, param, slots, grad, step):
        m = self.momentum * slots["m"] + _f32(grad)
        new = _f32(param) - self.lr * m
        return new.astype(param.dtype), {"m": m}


@dataclass(frozen=True)
class Adagrad(Optimizer):
    eps: float = 1e-8
    name: str = "adagrad"

    def init_slots(self, param):
        return {"n": jnp.zeros(param.shape, jnp.float32)}

    def update(self, param, slots, grad, step):
        g = _f32(grad)
        n = slots["n"] + g * g
        new = _f32(param) - self.lr * g / (jnp.sqrt(n) + self.eps)
        return new.astype(param.dtype), {"n": n}


@dataclass(frozen=True)
class Adam(Optimizer):
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    name: str = "adam"

    def init_slots(self, param):
        return {"m": jnp.zeros(param.shape, jnp.float32),
                "v": jnp.zeros(param.shape, jnp.float32)}

    def update(self, param, slots, grad, step):
        g = _f32(grad)
        t = step + 1
        m = self.b1 * slots["m"] + (1 - self.b1) * g
        v = self.b2 * slots["v"] + (1 - self.b2) * g * g
        mhat = m / (1 - self.b1 ** t)
        vhat = v / (1 - self.b2 ** t)
        new = _f32(param) - self.lr * mhat / (jnp.sqrt(vhat) + self.eps)
        return new.astype(param.dtype), {"m": m, "v": v}


@dataclass(frozen=True)
class FTRL(Optimizer):
    """Follow-The-Regularized-Leader-Proximal (McMahan 2011). The training
    state is (z, n); the inference weight w is a pure function of them —
    the paper's canonical heterogeneous-parameter example."""

    alpha: float = 0.05
    beta: float = 1.0
    l1: float = 1.0
    l2: float = 1.0
    name: str = "ftrl"
    serve_slot_names: tuple[str, ...] = ("z", "n")

    def init_slots(self, param):
        return {"z": jnp.zeros(param.shape, jnp.float32),
                "n": jnp.zeros(param.shape, jnp.float32)}

    def weights_from(self, z, n):
        shrink = jnp.sign(z) * self.l1 - z
        denom = (self.beta + jnp.sqrt(n)) / self.alpha + self.l2
        return jnp.where(jnp.abs(z) > self.l1, shrink / denom, 0.0)

    def update(self, param, slots, grad, step):
        g = _f32(grad)
        z, n = slots["z"], slots["n"]
        w = self.weights_from(z, n)
        n_new = n + g * g
        sigma = (jnp.sqrt(n_new) - jnp.sqrt(n)) / self.alpha
        z_new = z + g - sigma * w
        new_w = self.weights_from(z_new, n_new)
        return new_w.astype(param.dtype), {"z": z_new, "n": n_new}

    def serve_weights(self, param, slots):
        return self.weights_from(slots["z"], slots["n"]).astype(param.dtype)

    def serve_weights_np(self, param, slots):
        return self._np_weights(
            np.asarray(slots["z"]), np.asarray(slots["n"])).astype(
            param.dtype, copy=False)

    def _np_weights(self, z: np.ndarray, n: np.ndarray) -> np.ndarray:
        # in-place ops: this runs inside the pusher's cache-blocked encode
        # tiles, where temporaries are the difference between staying in
        # L2 and spilling. Same op order as ``weights_from`` (jnp), so the
        # two stay bit-compatible.
        denom = np.sqrt(n)
        denom += self.beta
        denom /= self.alpha
        denom += self.l2
        w = np.sign(z)
        w *= self.l1
        w -= z
        w /= denom
        return np.where(np.abs(z) > self.l1, w, np.float32(0.0)).astype(
            np.float32, copy=False)

    def update_rows(self, w, slots, grads, step, *, backend: str = "numpy"):
        """Batched FTRL row update. ``pallas`` fuses the whole step into
        one VMEM pass (``kernels.ftrl_row_update``); ``numpy`` is the
        vectorized reference (identical math, fp32). Empty batches take
        the numpy path — a zero-row Pallas grid is undefined."""
        if backend == "pallas" and len(grads):
            from repro.kernels import ops
            z_new, n_new, w_new = ops.ftrl_row_update(
                jnp.asarray(slots["z"], jnp.float32),
                jnp.asarray(slots["n"], jnp.float32),
                jnp.asarray(grads, jnp.float32),
                alpha=self.alpha, beta=self.beta, l1=self.l1, l2=self.l2)
            return np.asarray(w_new), {"z": np.asarray(z_new),
                                       "n": np.asarray(n_new)}
        g = np.asarray(grads, np.float32)
        z = np.asarray(slots["z"], np.float32)
        n = np.asarray(slots["n"], np.float32)
        w_old = self._np_weights(z, n)
        n_new = n + g * g
        sigma = (np.sqrt(n_new) - np.sqrt(n)) / self.alpha
        z_new = z + g - sigma * w_old
        return self._np_weights(z_new, n_new), {"z": z_new, "n": n_new}


@dataclass(frozen=True)
class Adafactor(Optimizer):
    """Factored second-moment optimizer (Shazeer & Stern 2018, simplified:
    no update clipping, fixed decay). Slots for an (a, b, ...) tensor are
    row/col moment factors — O(a+b) memory instead of O(a·b), which is what
    lets the 90B/132B/398B training states fit 16 GB/chip (DESIGN.md §5)."""

    eps: float = 1e-30
    decay: float = 0.8
    name: str = "adafactor"

    def init_slots(self, param):
        if param.ndim >= 2:
            return {"vr": jnp.zeros(param.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(param.shape[:-2] + param.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(param.shape, jnp.float32)}

    def update(self, param, slots, grad, step):
        g = _f32(grad)
        t = step + 1
        beta = 1.0 - t ** (-self.decay)
        g2 = g * g + self.eps
        if param.ndim >= 2:
            vr = beta * slots["vr"] + (1 - beta) * g2.mean(axis=-1)
            vc = beta * slots["vc"] + (1 - beta) * g2.mean(axis=-2)
            rfac = vr / jnp.maximum(
                vr.mean(axis=-1, keepdims=True), self.eps)
            v = rfac[..., None] * vc[..., None, :]
            new_slots = {"vr": vr, "vc": vc}
        else:
            v = beta * slots["v"] + (1 - beta) * g2
            new_slots = {"v": v}
        upd = g * jax.lax.rsqrt(jnp.maximum(v, self.eps))
        new = _f32(param) - self.lr * upd
        return new.astype(param.dtype), new_slots


_OPTIMIZERS = {
    "sgd": SGD, "momentum": Momentum, "adagrad": Adagrad, "adam": Adam,
    "ftrl": FTRL, "adafactor": Adafactor,
}


def get_optimizer(name: str, **kw) -> Optimizer:
    if name not in _OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}: {sorted(_OPTIMIZERS)}")
    return _OPTIMIZERS[name](**kw)
