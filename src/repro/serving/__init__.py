from repro.serving.cache import DenseCache, ServeCache
from repro.serving.plane import ServingPlane
from repro.serving.predictor import make_prefill_step, make_serve_step
from repro.serving.registry import Scenario, ScenarioRegistry
from repro.serving.router import RowRouter
from repro.serving.scheduler import DEFAULT_BUCKETS, PredictScheduler

__all__ = [
    "DEFAULT_BUCKETS", "DenseCache", "PredictScheduler", "RowRouter",
    "Scenario", "ScenarioRegistry", "ServeCache", "ServingPlane",
    "make_prefill_step", "make_serve_step",
]
