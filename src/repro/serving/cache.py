"""Version-aware serve caches (predictor side).

``ServeCache`` short-circuits the shard pull for hot ids: one
``IdHashMap``-backed arena (reusing ``SparseTable`` — the same vectorized
probe/gather engine the shards run) stores, per row id, the columns of
EVERY group the scenario reads side by side, so a cached request costs
ONE probe + ONE gather regardless of group count.  Entries are
invalidated by the scatter stream's applied-id batches (upserts AND
streamed deletes — wired through ``SlaveShard.on_apply``), which keeps
cached reads bit-equal to direct replica reads once the stream has been
polled: a row the stream rewrote is dropped here before any predictor
can read it stale.

``DenseCache`` memoizes dense tensors by their sync version counter —
the predict path re-reshapes a dense tensor only when a newer version
actually streamed in, instead of re-pulling every tensor per request.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.ps import SparseTable
from repro.obs import trace as obs_trace


class ServeCache:
    """Combined-group row cache keyed by id, invalidated by the stream."""

    def __init__(self, groups: dict[str, int], max_rows: int = 1 << 20,
                 backend: str = "numpy"):
        self.groups = dict(groups)
        self.offsets: dict[str, tuple[int, int]] = {}
        lo = 0
        for g, dim in self.groups.items():
            self.offsets[g] = (lo, lo + dim)
            lo += dim
        self.width = lo
        self.max_rows = max_rows
        self.table = SparseTable(self.width, backend=backend,
                                 init_capacity=1024)
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.invalidated = 0
        self.trims = 0
        # counter snapshot at the last window_stats() read — windowed
        # deltas without ever resetting the lifetime counters
        self._window_mark = {"hits": 0, "misses": 0, "invalidated": 0,
                             "trims": 0}

    def __len__(self) -> int:
        return len(self.table)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def lookup(self, ids: np.ndarray) -> tuple[Optional[np.ndarray],
                                               np.ndarray]:
        """(block (n, width), hit mask). Rows where the mask is False are
        zeros — the caller pulls them from the shards and ``fill``s.
        ``block`` is None when NOTHING hit (the fully-cold caller builds
        its own block from the pull; allocating one here would be pure
        waste on exactly the cold path)."""
        self._tick += 1
        if not len(self.table):
            # cold cache (just cleared / first request): every id misses.
            # Skip the whole-request probe against an all-EMPTY map — on
            # the cold-pull path this probe is pure overhead the seed
            # (cacheless) never pays.
            self.misses += len(ids)
            return None, np.zeros(len(ids), dtype=bool)
        sl = self.table.lookup(ids)
        hit = sl >= 0
        n_hit = int(hit.sum())
        self.hits += n_hit
        self.misses += len(ids) - n_hit
        if n_hit == len(ids):
            # hot path (steady-state serving): every id cached — straight
            # gather, no zeros allocation, no masked scatter-copy
            w, _ = self.table.read_rows(sl)
            self.table.last_touch[sl] = self._tick      # LRU signal
            return w, hit
        if n_hit == 0:
            return None, hit
        block = np.zeros((len(ids), self.width), np.float32)
        s = sl[hit]
        w, _ = self.table.read_rows(s)
        block[hit] = w
        self.table.last_touch[s] = self._tick
        return block, hit

    def lookup_device(self, ids: np.ndarray):
        """Device-resident twin of ``lookup`` (pallas backend): ONE jitted
        probe→gather against the cache table's device mirror returns the
        combined-group block as a DEVICE array plus the probe's found
        mask — misses are counted straight off that mask instead of
        re-probing on host, and the block never round-trips through host
        numpy on its way to the jitted predict. Rows where the mask is
        False are zeros (the caller pulls and ``fill``s them, then
        overlays — see ``ServingPlane._pull_request_device``). Returns
        ``(block | None, hit)`` with the same cold-path contract as
        ``lookup``. Hits/misses feed the same lifetime + window counters
        as the host path."""
        self._tick += 1
        if not len(self.table):
            self.misses += len(ids)
            return None, np.zeros(len(ids), dtype=bool)
        rows, hit, slot = self.table.lookup_device(ids)
        n_hit = int(hit.sum())
        self.hits += n_hit
        self.misses += len(ids) - n_hit
        if n_hit == 0:
            return None, hit
        self.table.last_touch[slot[hit]] = self._tick       # LRU signal
        return rows, hit

    def fill(self, ids: np.ndarray, block: np.ndarray) -> None:
        """Install pulled rows — the UNIQUE MISS SET of the ``lookup``
        that preceded this call, so the ids are known absent and the
        install is a probe-free ``insert_rows`` (the cold-pull fix: no
        re-probe, no re-sort, no zero-init of rows the block overwrites).
        Trims least-recently-touched rows once the arena outgrows
        ``max_rows`` — the cache stays bounded no matter how wide the
        request id distribution is."""
        if not len(ids):
            return
        self.table.insert_rows(ids, block, step=self._tick)
        if len(self.table) > self.max_rows:
            self._trim()

    def _trim(self) -> None:
        ids = self.table.all_ids()
        drop = len(ids) - self.max_rows // 2
        if drop <= 0:
            return
        sl = self.table.lookup(ids)
        oldest = np.argpartition(self.table.last_touch[sl], drop)[:drop]
        self.table.evict(ids[oldest])
        self.table.trim_evict_log(self.table.version)
        self.trims += 1

    def invalidate(self, ids: np.ndarray) -> int:
        """Drop rows the stream just rewrote or deleted."""
        if not len(self.table):
            return 0        # nothing cached: keep the training-only
            #                 sync_tick path free of probe work
        tr = obs_trace.get_tracer()
        if tr.enabled:
            # nests under the sync.apply span via the tracer's implicit
            # context (SlaveShard.on_apply fires inside the apply) —
            # this is the cache-visible end of the update's causal chain
            with tr.span("cache.invalidate", ids=len(ids)):
                return self._invalidate(ids)
        return self._invalidate(ids)

    def _invalidate(self, ids: np.ndarray) -> int:
        n = self.table.evict(ids)
        if n:
            # a cache is never checkpointed: its table's eviction log
            # (delta-checkpoint machinery) would otherwise grow with
            # every stream invalidation, forever
            self.table.trim_evict_log(self.table.version)
        self.invalidated += n
        return n

    def clear(self) -> None:
        """Full flush — hot switch / downgrade rebuilds serving state
        wholesale, so every cached row is suspect. Keeps the grown arena
        and map capacity (``SparseTable.reset``): the refill after a flush
        re-installs roughly the same working set, so reallocating at 1024
        rows only re-pays every growth step."""
        self.table.reset()

    def split(self, block: np.ndarray) -> dict[str, np.ndarray]:
        """Carve a combined block back into per-group column views."""
        return {g: block[:, lo:hi] for g, (lo, hi) in self.offsets.items()}

    def stats(self) -> dict:
        return {"rows": len(self), "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate, "invalidated": self.invalidated,
                "trims": self.trims}

    def register_metrics(self, reg, prefix: str = "cache") -> None:
        """Publish the lifetime counters under ``prefix`` in a
        ``repro.obs.metrics.MetricsRegistry``."""
        reg.register(prefix, self.stats)

    def window_stats(self) -> dict:
        """Counter deltas since the previous ``window_stats`` call, then
        start a new window. Lifetime counters (``stats``) are untouched —
        the SLO harness reads per-measurement-window hit rates while the
        benchmark's end-of-run totals stay intact."""
        cur = {"hits": self.hits, "misses": self.misses,
               "invalidated": self.invalidated, "trims": self.trims}
        out = {k: cur[k] - self._window_mark[k] for k in cur}
        n = out["hits"] + out["misses"]
        out["hit_rate"] = out["hits"] / n if n else 0.0
        out["rows"] = len(self)
        self._window_mark = cur
        return out


class DenseCache:
    """Dense tensors memoized by sync version — one reshape per version,
    not one pull per predict (the seed re-read every tensor per call)."""

    def __init__(self):
        self._cached: dict[str, tuple[int, np.ndarray]] = {}
        self.hits = 0
        self.refreshes = 0
        self.invalidated = 0        # clear() calls (hot switch / downgrade)
        self._window_mark = {"hits": 0, "refreshes": 0, "invalidated": 0}

    def get(self, name: str, shape: tuple[int, ...], version: int,
            fetch: Callable[[], Optional[np.ndarray]]) -> np.ndarray:
        cur = self._cached.get(name)
        # >= : with round-robin replica picks, a lagging replica may
        # report an OLDER version than what is cached — serving the
        # cached newer tensor is both fresher and stable (versions only
        # move backwards on hot switch, which clear()s this cache)
        if cur is not None and cur[0] >= version:
            self.hits += 1
            return cur[1]
        v = fetch()
        arr = (np.asarray(v, np.float32).reshape(shape) if v is not None
               else np.zeros(shape, np.float32))
        self._cached[name] = (version, arr)
        self.refreshes += 1
        return arr

    def clear(self) -> None:
        self._cached = {}
        self.invalidated += 1

    def stats(self) -> dict:
        """Same shape family as ``ServeCache.stats`` so the harness can
        surface sparse and dense cache health uniformly: a dense "miss"
        is a refresh (version moved → re-fetch)."""
        n = self.hits + self.refreshes
        return {"rows": len(self._cached), "hits": self.hits,
                "misses": self.refreshes,
                "hit_rate": self.hits / n if n else 0.0,
                "invalidated": self.invalidated}

    def window_stats(self) -> dict:
        cur = {"hits": self.hits, "refreshes": self.refreshes,
               "invalidated": self.invalidated}
        out = {"hits": cur["hits"] - self._window_mark["hits"],
               "misses": cur["refreshes"] - self._window_mark["refreshes"],
               "invalidated": (cur["invalidated"]
                               - self._window_mark["invalidated"]),
               "rows": len(self._cached)}
        n = out["hits"] + out["misses"]
        out["hit_rate"] = out["hits"] / n if n else 0.0
        self._window_mark = cur
        return out
