"""The serving plane as a subsystem (the tentpole of the symmetric-fusion
claim): vectorized pull, lag-bounded replica selection, version-aware
serve cache, micro-batching predict scheduler, multi-scenario registry.

Request path (hot):

    predict(ids, scenario)            — immediate single-request path
    submit(ids) … flush()             — coalesced concurrent load
      └ PredictScheduler: chunk the (coalesced) load into buckets
          └ pull: ONE cache probe over the request's flat ids
              ├ hits  — gathered straight from the cache arena
              └ misses — unique → argsort ownership segments
                         (RowRouter, shared with the training plane)
                         → per-segment replica read (ReplicaSet.read:
                           lag-bounded pick + failover) → cache fill
          └ pad rows to the bucket, jitted predict_fn, slice, split

Cache consistency: every replica's ``SlaveShard.on_apply`` publishes the
(group, ids, op) batches its scatter applied; ``on_applied`` drops those
ids from every scenario cache whose group subset contains the group —
including streamed deletes. Hot switch / downgrade rebuilds serving
state outside the stream, so the cluster flushes the caches wholesale
(``invalidate_all``). Dense tensors are memoized by sync version
(``DenseCache``) instead of re-pulled per request.
"""

from __future__ import annotations

import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.weips_ctr import CTRConfig
from repro.core.routing import RoutingPlan
from repro.obs import trace as obs_trace
from repro.models import ctr as ctr_model
from repro.serving.cache import ServeCache
from repro.serving.registry import Scenario, ScenarioRegistry
from repro.serving.router import RowRouter
from repro.serving.scheduler import (AdmissionConfig, DEFAULT_BUCKETS,
                                     PredictScheduler)


class ServingPlane:
    """Serving-side subsystem over a cluster's slave replica sets."""

    def __init__(self, plan: RoutingPlan, replica_sets: list,
                 store_groups: dict[str, int], *,
                 max_replica_lag: Optional[int] = None,
                 cache_rows: int = 1 << 20,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 ps_backend: str = "numpy",
                 admission: Optional[AdmissionConfig] = None,
                 clock=None):
        self.plan = plan
        self.replica_sets = replica_sets
        self.store_groups = dict(store_groups)
        self.max_replica_lag = max_replica_lag
        self.cache_rows = cache_rows
        self.buckets = tuple(buckets)
        # shared by every scenario's scheduler: one admission policy and
        # one (injectable) clock per serving plane
        self.admission = admission
        self.clock = clock or time.perf_counter
        # row engine for scenario caches: "pallas" keeps each ServeCache's
        # combined-group arena device-resident (fused probe+gather lookups
        # via the cache table's mirror); "numpy" is the CPU path
        self.ps_backend = ps_backend
        self.router = RowRouter(plan)
        self.registry = ScenarioRegistry()
        self.shard_pulled_rows = 0          # rows read from replicas
        self.predict_seconds = 0.0
        self.device_blocks = 0              # pulls answered device-resident

    # ------------------------------------------------------------------
    # scenarios
    # ------------------------------------------------------------------
    def add_scenario(self, cfg: CTRConfig, *,
                     name: Optional[str] = None) -> Scenario:
        """Register a serving scenario: validates its group subset against
        the shared store, builds its predict fn, cache namespace, and
        micro-batching scheduler."""
        groups = ctr_model.groups_for(cfg)
        ctr_model.check_scenario_groups(groups, self.store_groups)
        cache = ServeCache(groups, max_rows=self.cache_rows,
                           backend=self.ps_backend)
        scn = Scenario(
            name=name or cfg.name, cfg=cfg, groups=groups,
            dense_shapes=ctr_model.dense_shapes(cfg),
            predict_raw=ctr_model.predict_fn(cfg),
            predict_block=ctr_model.predict_block_fn(cfg, cache.offsets),
            cache=cache)
        scn.scheduler = PredictScheduler(
            lambda ids, bucket, s=scn: self._run_bucket(s, ids, bucket),
            buckets=self.buckets, admission=self.admission,
            clock=self.clock)
        return self.registry.add(scn)

    def scenario(self, name: Optional[str] = None) -> Scenario:
        return self.registry.get(name)

    # ------------------------------------------------------------------
    # pull path
    # ------------------------------------------------------------------
    def _fetch_block(self, sid: int, ids: np.ndarray,
                     scn: Scenario) -> np.ndarray:
        """Read one owner segment's combined-group block from shard
        ``sid``'s replica set — ONE replica pick (lag-bounded, failover)
        covers every group of the request, where the seed picked a
        replica per (group, shard) lookup."""

        def read(rep):
            out = np.empty((len(ids), scn.cache.width), np.float32)
            for g, (lo, hi) in scn.cache.offsets.items():
                out[:, lo:hi] = rep.lookup(g, ids)
            return out

        self.shard_pulled_rows += len(ids)
        return self.replica_sets[sid].read(read,
                                           max_lag=self.max_replica_lag)

    def _pull_miss(self, scn: Scenario, miss_flat: np.ndarray) -> np.ndarray:
        """Pull + cache-fill the miss set; returns the pulled rows
        expanded back to ``miss_flat`` order (duplicates included)."""
        uniq, inverse = np.unique(miss_flat, return_inverse=True)
        # segment-ordered pull: rows arrive grouped by owner shard;
        # fold the ordering into the inverse-index expansion below
        # (rank maps uniq position -> pulled row) instead of paying a
        # row scatter back into uniq order
        pulled, order = self.router.pull_block_sorted(
            uniq, scn.cache.width, self.plan.slave_shard(uniq),
            lambda sid, seg: self._fetch_block(sid, seg, scn))
        scn.cache.fill(uniq.take(order, mode="clip"), pulled)
        rank = np.empty(len(uniq), dtype=np.int64)
        rank[order] = np.arange(len(uniq), dtype=np.int64)
        return pulled.take(rank.take(inverse, mode="clip"),
                           axis=0, mode="clip")

    def pull_request(self, ids: np.ndarray,
                     scenario: Optional[str] = None) -> np.ndarray:
        """Combined-group rows for a request's flat ids, in request order
        (duplicates included — no np.unique on the cache-hit path). Cache
        misses are uniqued, pulled through the shared router in owner
        segments, and installed in the cache. Under the pallas backend
        the returned block is a DEVICE array (jax) gathered by the fused
        cache lookup; numpy callers go through ``serve_rows``, which
        materializes — the predict path (``_run_bucket``) keeps it on
        device all the way into the jitted predict."""
        scn = self.registry.get(scenario)
        flat = np.asarray(ids, dtype=np.int64).reshape(-1)
        if self.ps_backend == "pallas":
            return self._pull_request_device(scn, flat)
        block, hit = scn.cache.lookup(flat)
        if block is None or not hit.all():
            miss_flat = flat if block is None else flat[~hit]
            expanded = self._pull_miss(scn, miss_flat)
            if block is None:
                block = expanded               # fully cold: no masked copy
            else:
                block[~hit] = expanded
        return block

    def _pull_request_device(self, scn: Scenario, flat: np.ndarray):
        """Device-resident pull: the cache's fused probe+gather answers
        hits as a device block and counts misses off the device found
        mask (``ServeCache.lookup_device``); misses are pulled from
        replicas host-side (replica reads are host numpy by nature),
        installed in the cache, and OVERLAID onto the device block with
        one scatter — the combined-group arena block never round-trips
        through host numpy between pull and predict."""
        block, hit = scn.cache.lookup_device(flat)
        if hit.all():
            self.device_blocks += 1
            return block
        expanded = self._pull_miss(scn, flat if block is None
                                   else flat[~hit])
        if block is None:
            # fully cold: the pulled rows ARE the block; hand it to the
            # device once, here — predict consumes it without another copy
            return jnp.asarray(expanded)
        self.device_blocks += 1
        miss_idx = jnp.asarray(np.flatnonzero(~hit).astype(np.int32))
        return block.at[miss_idx].set(jnp.asarray(expanded))

    def serve_rows(self, ids: np.ndarray,
                   scenario: Optional[str] = None) -> dict[str, np.ndarray]:
        """Predictor pull path: ``{group: (B, F, dim)}`` serve rows (host
        numpy — this is the host-facing API; the device block path stays
        inside ``_run_bucket``)."""
        scn = self.registry.get(scenario)
        b, f = np.asarray(ids).shape
        block = np.asarray(self.pull_request(ids, scenario))
        return {g: block[:, lo:hi].reshape(b, f, hi - lo)
                for g, (lo, hi) in scn.cache.offsets.items()}

    def serve_dense(self,
                    scenario: Optional[str] = None) -> dict[str, np.ndarray]:
        """Dense bank for predict — memoized by sync version, re-read from
        a replica only when a newer dense record actually streamed in."""
        scn = self.registry.get(scenario)
        if not scn.dense_shapes:
            return {}

        def read(rep):
            return {
                name: scn.dense_cache.get(
                    name, shape, rep.dense_versions.get(name, -1),
                    lambda n=name: rep.dense.get(n))
                for name, shape in scn.dense_shapes.items()}

        return self.replica_sets[0].read(read, max_lag=self.max_replica_lag)

    # ------------------------------------------------------------------
    # predict path
    # ------------------------------------------------------------------
    def _run_bucket(self, scn: Scenario, ids: np.ndarray,
                    bucket: int) -> np.ndarray:
        """Pull the combined-group block for the real examples, pad it
        (not the ids — the cache never sees padding) up to the bucket,
        run the jitted block predict at the bucket shape, slice the
        padding off. The per-group split happens on device inside
        ``predict_block`` — the host never copies per-group row
        tensors on this path."""
        b, f = ids.shape
        with obs_trace.get_tracer().span("serve.bucket", bucket=bucket,
                                         examples=b):
            return self._run_bucket_inner(scn, ids, b, f, bucket)

    def _run_bucket_inner(self, scn: Scenario, ids: np.ndarray, b: int,
                          f: int, bucket: int) -> np.ndarray:
        block = self.pull_request(ids, scn.name)       # (b*f, width)
        dense = self.serve_dense(scn.name)
        if isinstance(block, jnp.ndarray):
            # device-resident block (pallas backend): pad on device, feed
            # the jitted predict directly — no host materialization
            # anywhere between the cache gather and the logits
            if b < bucket:
                block = jnp.concatenate(
                    [block, jnp.zeros(((bucket - b) * f, block.shape[1]),
                                      block.dtype)])
        else:
            if b < bucket:
                block = np.concatenate(
                    [block, np.zeros(((bucket - b) * f, block.shape[1]),
                                     block.dtype)])
            block = jnp.asarray(block)
        p = scn.predict_block(
            block, {k: jnp.asarray(v) for k, v in dense.items()})
        return np.asarray(p)[:b]

    def predict(self, ids: np.ndarray,
                scenario: Optional[str] = None) -> np.ndarray:
        """Immediate single-request path. Requests admitted via
        ``submit`` are left pending for the next ``flush`` — their
        tickets stay valid."""
        scn = self.registry.get(scenario)
        t0 = self.clock()
        with obs_trace.get_tracer().span("serve.predict",
                                         scenario=scn.name,
                                         examples=len(ids)):
            out = scn.scheduler.run_one(ids)
        self.predict_seconds += self.clock() - t0
        scn.requests += 1
        scn.examples += len(ids)
        return out

    def submit(self, ids: np.ndarray,
               scenario: Optional[str] = None) -> int:
        """Admit a request without running it — concurrent requests queue
        here and execute coalesced on the next ``flush``. Under an
        admission policy, over-depth submits shed the oldest pending
        tickets (their flush results will be ``None``)."""
        return self.registry.get(scenario).scheduler.submit(ids)

    def flush(self, scenario: Optional[str] = None, *,
              budget: Optional[int] = None) -> list:
        """Execute the pending window; ticket-ordered results, ``None``
        for tickets the admission policy shed. With ``budget``, at most
        that many examples execute and the rest stays queued."""
        scn = self.registry.get(scenario)
        t0 = self.clock()
        with obs_trace.get_tracer().span("serve.flush",
                                         scenario=scn.name):
            out = scn.scheduler.flush(budget=budget)
        self.predict_seconds += self.clock() - t0
        scn.requests += sum(1 for p in out if p is not None)
        scn.examples += sum(len(p) for p in out if p is not None)
        return out

    # ------------------------------------------------------------------
    # invalidation (stream hooks)
    # ------------------------------------------------------------------
    def on_applied(self, group: str, ids: np.ndarray, op: str) -> None:
        """``SlaveShard.on_apply`` hook: the stream rewrote (or deleted)
        these rows — drop them from every cache namespace that reads the
        group, so the next read refills from a replica."""
        for scn in self.registry:
            if group in scn.groups:
                scn.cache.invalidate(ids)

    def invalidate_all(self) -> None:
        """Wholesale flush: hot switch / downgrade / recovery rebuilt the
        serving tables outside the stream."""
        for scn in self.registry:
            scn.cache.clear()
            scn.dense_cache.clear()

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def _admission_totals(self) -> dict:
        adm = {"offered_requests": 0, "offered_examples": 0,
               "executed_requests": 0, "executed_examples": 0,
               "shed_requests": 0, "shed_examples": 0,
               "shed_depth_requests": 0, "shed_deadline_requests": 0}
        for s in self.registry:
            if s.scheduler is None:
                continue
            for k, v in s.scheduler.adm.as_dict().items():
                adm[k] += v
        return adm

    def _latency_percentiles(self) -> dict:
        from repro.core.monitor import PercentileRing
        return PercentileRing.merged_percentiles(
            [s.scheduler.latency for s in self.registry
             if s.scheduler is not None], (50, 99))

    def register_metrics(self, reg, prefix: str = "serving") -> None:
        """Publish the plane's counters into a
        ``repro.obs.metrics.MetricsRegistry`` under stable dotted names
        (``serving.admission.shed_examples``, ``serving.latency.p99``,
        …). ``metrics()`` below and the registry's tree are views over
        the SAME underlying counters."""
        from repro.obs.metrics import join
        reg.register(join(prefix, "scenarios"),
                     lambda: {s.name: s.metrics() for s in self.registry})
        reg.register(join(prefix, "admission"), self._admission_totals)
        reg.register(join(prefix, "latency"), self._latency_percentiles)
        reg.register(join(prefix, "shard_pulled_rows"),
                     lambda: self.shard_pulled_rows)
        reg.register(join(prefix, "predict_seconds"),
                     lambda: self.predict_seconds)
        reg.register(join(prefix, "device_blocks"),
                     lambda: self.device_blocks)
        reg.register(join(prefix, "replica_lag_skips"),
                     lambda: sum(rs.lag_skips for rs in self.replica_sets))

    def metrics(self) -> dict:
        return {
            "scenarios": {s.name: s.metrics() for s in self.registry},
            "admission": self._admission_totals(),
            "latency": self._latency_percentiles(),
            "shard_pulled_rows": self.shard_pulled_rows,
            "predict_seconds": self.predict_seconds,
            "device_blocks": self.device_blocks,
            "replica_lag_skips": sum(rs.lag_skips
                                     for rs in self.replica_sets),
        }

    def window_metrics(self) -> dict:
        """Per-window cache counter deltas for every scenario (advances
        each cache's window mark)."""
        return {s.name: s.window_metrics() for s in self.registry}
