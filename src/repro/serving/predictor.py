"""Serving plane: prefill and decode step factories + a batched request
driver used by the serving example and benchmarks.

``serve_step`` consumes *serve params* — the slave-side state produced by
the WeiPS ModelSyncEngine — and a KV/SSM cache; it appends ONE token per
sequence. The driver supports hot weight updates between steps (the
second-level deployment the paper is about: new serve params swap in
without dropping in-flight sequences, because the cache layout is
independent of the weights)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward, init_cache

PyTree = Any


def make_serve_step(cfg: ModelConfig, jit: bool = True) -> Callable:
    def serve_step(params: PyTree, cache: PyTree, tokens: jax.Array,
                   pos: jax.Array):
        """tokens (B,1) int32; pos (B,) int32 -> (logits (B,V), new_cache)."""
        return decode_step(params, cfg, cache, tokens, pos)

    if jit:
        return jax.jit(serve_step, donate_argnums=(1,))
    return serve_step


def make_prefill_step(cfg: ModelConfig, jit: bool = True) -> Callable:
    def prefill_step(params: PyTree, batch: dict):
        logits, _ = forward(params, cfg, batch["tokens"],
                            enc_context=batch.get("enc_context"))
        return logits

    if jit:
        return jax.jit(prefill_step)
    return prefill_step


@dataclass
class ServeDriver:
    """Batched greedy-decode driver with hot weight swap."""

    cfg: ModelConfig
    params: PyTree
    batch: int
    max_len: int
    cache_dtype: Any = jnp.float32
    step_fn: Optional[Callable] = None
    generated: list = field(default_factory=list)

    def __post_init__(self):
        self.step_fn = self.step_fn or make_serve_step(self.cfg)
        self.cache = init_cache(self.cfg, self.batch, self.max_len,
                                dtype=self.cache_dtype)
        self.pos = jnp.zeros((self.batch,), jnp.int32)

    def hot_swap(self, new_params: PyTree) -> None:
        """Second-level deployment: swap weights between decode steps."""
        self.params = new_params

    def step(self, tokens: jax.Array) -> jax.Array:
        logits, self.cache = self.step_fn(self.params, self.cache, tokens,
                                          self.pos)
        self.pos = self.pos + 1
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.generated.append(np.asarray(nxt))
        return nxt[:, None]

    def generate(self, prompt_token: jax.Array, steps: int) -> np.ndarray:
        # fresh accumulator per call: a second generate must return only
        # its own tokens, not stack the previous call's on top (the cache
        # and position carry over — hot_swap mid-stream still works)
        self.generated = []
        tok = prompt_token
        for _ in range(steps):
            tok = self.step(tok)
        return np.stack(self.generated, axis=1)
