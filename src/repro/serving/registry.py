"""Multi-scenario serving registry.

One WeiPS cluster stores a shared sparse parameter space; many *serving
scenarios* (model variants — an LR head, the full FM, a DNN reading the
same embeddings) predict off subsets of it concurrently, each with its
own jitted predict fn, micro-batching scheduler, cache namespace, and
metrics — the EasyRec-style many-scenarios-one-store layout the ROADMAP
names. Scenario membership is also published to the coordination
registry (``core.scheduler``) so predictors can discover it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.configs.weips_ctr import CTRConfig
from repro.serving.cache import DenseCache, ServeCache
from repro.serving.scheduler import PredictScheduler


@dataclass
class Scenario:
    """Everything one serving scenario owns: config, group subset, predict
    fn, cache namespaces, scheduler, counters."""

    name: str
    cfg: CTRConfig
    groups: dict[str, int]                    # subset of the store groups
    dense_shapes: dict[str, tuple]
    predict_raw: Callable                     # jitted (rows, dense) -> (B,)
    predict_block: Callable                   # jitted (block, dense) -> (B,)
    cache: ServeCache
    dense_cache: DenseCache = field(default_factory=DenseCache)
    scheduler: Optional[PredictScheduler] = None
    requests: int = 0
    examples: int = 0

    def metrics(self) -> dict:
        out = {"requests": self.requests, "examples": self.examples,
               "cache": self.cache.stats(),
               "dense_cache": self.dense_cache.stats(),
               "dense_refreshes": self.dense_cache.refreshes}
        if self.scheduler is not None:
            s = self.scheduler.stats
            out["batches"] = s.batches
            out["padding_fraction"] = s.padding_fraction
            out["admission"] = self.scheduler.adm.as_dict()
            out["latency"] = self.scheduler.latency.percentiles((50, 99))
        return out

    def window_metrics(self) -> dict:
        """Per-window cache counter deltas (resets the window marks)."""
        return {"cache": self.cache.window_stats(),
                "dense_cache": self.dense_cache.window_stats()}


class ScenarioRegistry:
    """Named scenarios; the first one added is the default."""

    def __init__(self):
        self._scenarios: dict[str, Scenario] = {}
        self._default: Optional[str] = None

    def add(self, scenario: Scenario) -> Scenario:
        if scenario.name in self._scenarios:
            raise ValueError(f"scenario {scenario.name!r} already exists")
        self._scenarios[scenario.name] = scenario
        if self._default is None:
            self._default = scenario.name
        return scenario

    def get(self, name: Optional[str] = None) -> Scenario:
        key = self._default if name is None else name
        if key is None or key not in self._scenarios:
            raise KeyError(f"unknown scenario {name!r} "
                           f"(have: {sorted(self._scenarios)})")
        return self._scenarios[key]

    def register_metrics(self, reg, prefix: str = "scenarios") -> None:
        """Publish every scenario's ``metrics()`` dict under
        ``<prefix>.<scenario-name>`` in a
        ``repro.obs.metrics.MetricsRegistry``. Registered as ONE
        provider so scenarios added later still show up."""
        reg.register(prefix,
                     lambda: {s.name: s.metrics() for s in self})

    def names(self) -> list[str]:
        return sorted(self._scenarios)

    def __iter__(self):
        return iter(self._scenarios.values())

    def __len__(self) -> int:
        return len(self._scenarios)
