"""Shared row-pull routing — the code path the paper's *symmetric fusion*
actually shares between the two planes.

Both the training plane (trainer → master shards) and the serving plane
(predictor → slave replica sets) answer the same question: given a
request's ids, which shard owns each id, and how do we gather every
group's rows in bulk?  ``RowRouter`` answers it once for both: resolve
ownership with ONE argsort segment pass (``core.routing.owner_segments``
— the same primitive the streaming pusher and the recovery router use)
and bulk-fetch each contiguous owner segment, writing results straight
into preallocated output blocks.  The seed looped ``num_groups ×
num_shards`` boolean masks over the whole unique-id set per request.

``WeiPSCluster._pull_rows`` (training) and ``ServingPlane`` (serving)
are both thin adapters over this router — they differ only in the
``fetch`` callback (master ``pull`` with row creation vs. replica-set
read with lag-bounded failover).
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.routing import RoutingPlan, owner_segments


class RowRouter:
    """Vectorized ownership routing + bulk gather for row requests."""

    def __init__(self, plan: RoutingPlan):
        self.plan = plan

    @staticmethod
    def unique(ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(unique ids, inverse) for a request's flattened id tensor."""
        return np.unique(np.asarray(ids, dtype=np.int64).reshape(-1),
                         return_inverse=True)

    def pull(self, uniq: np.ndarray, groups: dict[str, int],
             owner: np.ndarray,
             fetch: Callable[[int, np.ndarray], dict[str, np.ndarray]],
             ) -> dict[str, np.ndarray]:
        """Gather ``(len(uniq), dim)`` blocks for every group.

        ``owner`` assigns each unique id to a destination shard;
        ``fetch(dst, ids)`` returns ``{group: (m, dim)}`` for one owner
        segment. One argsort pass; segment results are scattered into
        the output blocks by index — no per-destination boolean masks.
        """
        out = {g: np.zeros((len(uniq), dim), np.float32)
               for g, dim in groups.items()}
        for dst, idx in owner_segments(owner):
            vals = fetch(dst, uniq.take(idx, mode="clip"))
            for g, block in vals.items():
                out[g][idx] = block
        return out

    def pull_block(self, uniq: np.ndarray, width: int, owner: np.ndarray,
                   fetch: Callable[[int, np.ndarray], np.ndarray],
                   ) -> np.ndarray:
        """Single-block variant: ``fetch(dst, ids)`` returns one
        ``(m, width)`` block holding every group's columns side by side —
        the layout the serve cache stores, so a whole multi-group request
        fills with one gather per owner segment."""
        # empty, not zeros: owner_segments partitions ALL of uniq, so every
        # row is written exactly once — the memset would be pure overhead
        # on the cold-pull path (this block is multi-MB per request)
        out = np.empty((len(uniq), width), np.float32)
        for dst, idx in owner_segments(owner):
            out[idx] = fetch(dst, uniq.take(idx, mode="clip"))
        return out

    def pull_block_sorted(self, uniq: np.ndarray, width: int,
                          owner: np.ndarray,
                          fetch: Callable[[int, np.ndarray], np.ndarray],
                          ) -> tuple[np.ndarray, np.ndarray]:
        """``pull_block`` that leaves the rows in owner-segment order and
        returns ``(block, order)`` with ``block[i]`` the row for
        ``uniq[order[i]]``. Each segment lands as one contiguous slice
        write instead of a row scatter back into ``uniq`` order — callers
        that re-expand to request order anyway (via an inverse-index
        gather) fold ``order`` into that existing gather, so the scatter
        pass disappears entirely from the cold pull."""
        out = np.empty((len(uniq), width), np.float32)
        parts = []
        off = 0
        for dst, idx in owner_segments(owner):
            out[off:off + len(idx)] = fetch(dst, uniq.take(idx, mode="clip"))
            parts.append(idx)
            off += len(idx)
        order = (np.concatenate(parts) if parts
                 else np.empty(0, dtype=np.int64))
        return out, order

    @staticmethod
    def expand(vals: dict[str, np.ndarray], inverse: np.ndarray,
               shape: tuple[int, int]) -> dict[str, np.ndarray]:
        """Unique-space blocks → per-example ``(B, F, dim)`` tensors."""
        b, f = shape
        return {g: v.take(inverse, axis=0, mode="clip").reshape(b, f, -1)
                for g, v in vals.items()}
