"""Micro-batching request scheduler for the predict path.

Concurrent predict requests are admitted into a pending window, coalesced
into one id tensor, and executed in fixed-size *buckets*: each chunk is
padded up to the smallest configured bucket that covers it, so the jitted
``predict_fn`` compiles once per (bucket, fields) shape instead of once
per request shape — the paper's "heavy traffic from millions of users"
regime is exactly the one where per-request recompiles and per-request
dispatch overhead dominate.  Results are split back per request.

Padding happens on the *row tensors*, after the pull (see
``ServingPlane``): padded rows are zeros, padded predictions are sliced
off before the split, and the serve cache never sees a padding id.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

# power-of-two ladder: worst-case padding is <50 % of a bucket, and the
# jitted predict fn compiles at most len(DEFAULT_BUCKETS) shapes — the
# trade a serving system wants (a sparse ladder like (64, 4096) would
# waste up to 63/64 of a bucket on mid-sized requests)
DEFAULT_BUCKETS = (64, 128, 256, 512, 1024, 2048, 4096)


@dataclass
class SchedulerStats:
    requests: int = 0
    examples: int = 0
    padded_examples: int = 0        # zero-rows added to reach a bucket
    batches: int = 0                # bucket executions
    bucket_counts: dict = field(default_factory=dict)

    @property
    def padding_fraction(self) -> float:
        total = self.examples + self.padded_examples
        return self.padded_examples / total if total else 0.0


class PredictScheduler:
    """Admit → coalesce → bucket → split for one scenario's predict fn."""

    def __init__(self, runner: Callable[[np.ndarray, int], np.ndarray],
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS):
        assert buckets, "need at least one bucket size"
        self.runner = runner            # runner(ids (b, f), bucket) -> (b,)
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self._pending: list[np.ndarray] = []
        self.stats = SchedulerStats()

    def bucket_for(self, n: int) -> int:
        """Smallest bucket covering ``n``; the largest bucket for loads
        that exceed it (they run as multiple full buckets + one padded
        remainder)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def submit(self, ids: np.ndarray) -> int:
        """Admit one request; returns its ticket for the next ``flush``."""
        ids = np.asarray(ids, dtype=np.int64)
        assert ids.ndim == 2, "predict requests are (batch, fields) ids"
        self._pending.append(ids)
        self.stats.requests += 1
        self.stats.examples += len(ids)
        return len(self._pending) - 1

    def flush(self) -> list[np.ndarray]:
        """Run everything admitted since the last flush as one coalesced
        load; returns per-request predictions in ticket order."""
        reqs, self._pending = self._pending, []
        if not reqs:
            return []
        ids = reqs[0] if len(reqs) == 1 else np.concatenate(reqs, axis=0)
        preds = self._run(ids)
        bounds = np.cumsum([len(r) for r in reqs])[:-1]
        return np.split(preds, bounds)

    def run_one(self, ids: np.ndarray) -> np.ndarray:
        """Immediate single-request path: bucketed execution of ``ids``
        alone. Requests admitted via ``submit`` stay pending — their
        results belong to the next ``flush``, never to this call."""
        ids = np.asarray(ids, dtype=np.int64)
        assert ids.ndim == 2, "predict requests are (batch, fields) ids"
        self.stats.requests += 1
        self.stats.examples += len(ids)
        return self._run(ids)

    def _run(self, ids: np.ndarray) -> np.ndarray:
        total = len(ids)
        out = np.empty(total, np.float32)
        pos = 0
        while pos < total:
            bucket = self.bucket_for(total - pos)
            take = min(total - pos, bucket)
            out[pos:pos + take] = self.runner(ids[pos:pos + take], bucket)
            self.stats.batches += 1
            self.stats.padded_examples += bucket - take
            self.stats.bucket_counts[bucket] = \
                self.stats.bucket_counts.get(bucket, 0) + 1
            pos += take
        return out
