"""Micro-batching request scheduler for the predict path, with serve-side
admission control.

Concurrent predict requests are admitted into a pending window, coalesced
into one id tensor, and executed in fixed-size *buckets*: each chunk is
padded up to the smallest configured bucket that covers it, so the jitted
``predict_fn`` compiles once per (bucket, fields) shape instead of once
per request shape — the paper's "heavy traffic from millions of users"
regime is exactly the one where per-request recompiles and per-request
dispatch overhead dominate.  Results are split back per request.

Padding happens on the *row tensors*, after the pull (see
``ServingPlane``): padded rows are zeros, padded predictions are sliced
off before the split, and the serve cache never sees a padding id.

Admission control (the serving twin of the train pipeline's sync-lag
backpressure, which PR 5 gave the training plane while serving had
none): an :class:`AdmissionConfig` bounds the pending queue and stamps
every ticket with an arrival time from an injectable clock.

* **Depth shedding** — when admitting a request would push the pending
  window past ``max_pending`` examples, the OLDEST live tickets are shed
  first (they are the stalest; their callers have waited longest and are
  the most likely to have timed out upstream anyway). The newest request
  is always admitted: load shedding protects the queue, it never blanks
  the current caller while older work is holding the depth.
* **Deadline shedding** — at ``flush`` time, tickets whose
  ``deadline`` (seconds since admit) has passed are shed instead of
  executed: work nobody is still waiting for must not consume the
  bucket budget of work somebody is.
* **Budgeted flush** — ``flush(budget=n)`` drains at most ``n``
  examples (oldest first) and leaves the rest pending, which is what
  turns the scheduler into a closed-loop queueing system: offered load
  beyond the service budget accumulates as queue depth, and the depth
  bound converts the overflow into counted sheds instead of unbounded
  p99. At least one request always executes per budgeted flush
  (progress guarantee for requests larger than the budget).

Shed tickets resolve to ``None`` in ``flush``'s ticket-ordered result
list; counters keep ``executed + shed == offered`` balanced per request
AND per example. Per-request queueing+service latency is recorded into a
shared :class:`~repro.core.monitor.PercentileRing`, so the SLO harness,
the admission controller, and the domino-downgrade trigger all read one
percentile implementation.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.monitor import PercentileRing

# power-of-two ladder: worst-case padding is <50 % of a bucket, and the
# jitted predict fn compiles at most len(DEFAULT_BUCKETS) shapes — the
# trade a serving system wants (a sparse ladder like (64, 4096) would
# waste up to 63/64 of a bucket on mid-sized requests)
DEFAULT_BUCKETS = (64, 128, 256, 512, 1024, 2048, 4096)


@dataclass
class SchedulerStats:
    requests: int = 0
    examples: int = 0
    padded_examples: int = 0        # zero-rows added to reach a bucket
    batches: int = 0                # bucket executions
    bucket_counts: dict = field(default_factory=dict)

    @property
    def padding_fraction(self) -> float:
        total = self.examples + self.padded_examples
        return self.padded_examples / total if total else 0.0


@dataclass
class AdmissionConfig:
    """Serve-path admission bounds. Both default to None = unbounded —
    the pre-admission behavior, and what every existing caller gets."""

    max_pending: Optional[int] = None   # pending-example depth bound
    deadline: Optional[float] = None    # seconds from admit to execution


@dataclass
class AdmissionStats:
    """Load-shed accounting. Invariant once the queue is drained:
    ``executed + shed == offered`` at request AND example granularity
    (``shed = shed_depth + shed_deadline``)."""

    offered_requests: int = 0
    offered_examples: int = 0
    executed_requests: int = 0
    executed_examples: int = 0
    shed_depth_requests: int = 0
    shed_depth_examples: int = 0
    shed_deadline_requests: int = 0
    shed_deadline_examples: int = 0

    @property
    def shed_requests(self) -> int:
        return self.shed_depth_requests + self.shed_deadline_requests

    @property
    def shed_examples(self) -> int:
        return self.shed_depth_examples + self.shed_deadline_examples

    def as_dict(self) -> dict:
        return {
            "offered_requests": self.offered_requests,
            "offered_examples": self.offered_examples,
            "executed_requests": self.executed_requests,
            "executed_examples": self.executed_examples,
            "shed_requests": self.shed_requests,
            "shed_examples": self.shed_examples,
            "shed_depth_requests": self.shed_depth_requests,
            "shed_deadline_requests": self.shed_deadline_requests,
        }


class _Ticket:
    """One admitted request waiting for a flush."""

    __slots__ = ("ids", "t_admit", "shed")

    def __init__(self, ids: np.ndarray, t_admit: float):
        self.ids = ids
        self.t_admit = t_admit
        self.shed: Optional[str] = None      # None | "depth" | "deadline"


class PredictScheduler:
    """Admit → (maybe shed) → coalesce → bucket → split for one
    scenario's predict fn."""

    def __init__(self, runner: Callable[[np.ndarray, int], np.ndarray],
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS, *,
                 admission: Optional[AdmissionConfig] = None,
                 clock: Optional[Callable[[], float]] = None,
                 latency_ring: Optional[PercentileRing] = None):
        assert buckets, "need at least one bucket size"
        self.runner = runner            # runner(ids (b, f), bucket) -> (b,)
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.admission = admission or AdmissionConfig()
        self.clock = clock or time.perf_counter
        # queueing+service latency per executed request — shared percentile
        # machinery (core/monitor.py), readable by the downgrade trigger
        self.latency = latency_ring if latency_ring is not None \
            else PercentileRing(1 << 14)
        self._pending: deque[_Ticket] = deque()
        self._pending_examples = 0      # live (non-shed) queued examples
        self.stats = SchedulerStats()
        self.adm = AdmissionStats()

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    @property
    def pending_examples(self) -> int:
        """Live queue depth in examples (shed tickets excluded)."""
        return self._pending_examples

    def bucket_for(self, n: int) -> int:
        """Smallest bucket covering ``n``; the largest bucket for loads
        that exceed it (they run as multiple full buckets + one padded
        remainder)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def submit(self, ids: np.ndarray) -> int:
        """Admit one request; returns its ticket for the next ``flush``.
        Over the depth bound, the OLDEST live tickets shed to make room
        (resolved as ``None`` results at their flush)."""
        ids = np.asarray(ids, dtype=np.int64)
        assert ids.ndim == 2, "predict requests are (batch, fields) ids"
        self.stats.requests += 1
        self.stats.examples += len(ids)
        self.adm.offered_requests += 1
        self.adm.offered_examples += len(ids)
        self._pending.append(_Ticket(ids, self.clock()))
        self._pending_examples += len(ids)
        cap = self.admission.max_pending
        if cap is not None and self._pending_examples > cap:
            self._shed_depth(cap)
        return len(self._pending) - 1

    def _shed_depth(self, cap: int) -> None:
        """Shed oldest-first until the live depth fits ``cap``. The
        newest ticket survives even if it alone exceeds the bound (depth
        shedding bounds *queueing*, it does not reject big requests)."""
        for tk in self._pending:
            if self._pending_examples <= cap:
                break
            if tk.shed is not None or tk is self._pending[-1]:
                continue
            tk.shed = "depth"
            self._pending_examples -= len(tk.ids)
            self.adm.shed_depth_requests += 1
            self.adm.shed_depth_examples += len(tk.ids)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def flush(self, budget: Optional[int] = None) -> list:
        """Drain the pending window oldest-first as one coalesced load;
        returns per-request results in ticket order (``None`` for shed
        tickets). With ``budget``, at most that many examples execute
        (but always at least one request) and the remainder stays
        pending for the next flush — the queueing behavior the overload
        harness measures."""
        now = self.clock()
        dl = self.admission.deadline
        out: list = []                 # result slot per drained ticket
        run: list[_Ticket] = []        # tickets to execute this round
        spent = 0
        while self._pending:
            tk = self._pending[0]
            if tk.shed is None and dl is not None and now - tk.t_admit > dl:
                tk.shed = "deadline"
                self._pending_examples -= len(tk.ids)
                self.adm.shed_deadline_requests += 1
                self.adm.shed_deadline_examples += len(tk.ids)
            if tk.shed is not None:
                out.append(None)
                self._pending.popleft()
                continue
            if budget is not None and spent + len(tk.ids) > budget \
                    and spent > 0:
                break                  # budget exhausted; rest waits
            run.append(tk)
            out.append(tk)             # placeholder, filled below
            spent += len(tk.ids)
            self._pending_examples -= len(tk.ids)
            self._pending.popleft()
        if run:
            ids = run[0].ids if len(run) == 1 else \
                np.concatenate([tk.ids for tk in run], axis=0)
            preds = self._run(ids)
            bounds = np.cumsum([len(tk.ids) for tk in run])[:-1]
            parts = np.split(preds, bounds)
            done = self.clock()
            k = 0
            for i, slot in enumerate(out):
                if slot is None:
                    continue
                out[i] = parts[k]
                k += 1
            for tk in run:
                self.latency.record(done - tk.t_admit)
                self.adm.executed_requests += 1
                self.adm.executed_examples += len(tk.ids)
        return out

    def run_one(self, ids: np.ndarray) -> np.ndarray:
        """Immediate single-request path: bucketed execution of ``ids``
        alone, no admission (the caller is synchronous — there is no
        queue to protect). Requests admitted via ``submit`` stay pending
        — their results belong to the next ``flush``, never to this
        call."""
        ids = np.asarray(ids, dtype=np.int64)
        assert ids.ndim == 2, "predict requests are (batch, fields) ids"
        self.stats.requests += 1
        self.stats.examples += len(ids)
        self.adm.offered_requests += 1
        self.adm.offered_examples += len(ids)
        t0 = self.clock()
        out = self._run(ids)
        self.latency.record(self.clock() - t0)
        self.adm.executed_requests += 1
        self.adm.executed_examples += len(ids)
        return out

    def register_metrics(self, reg, prefix: str = "scheduler") -> None:
        """Publish this scheduler's admission/latency/batching counters
        into a ``repro.obs.metrics.MetricsRegistry``."""
        from repro.obs.metrics import join
        reg.register(join(prefix, "admission"), self.adm.as_dict)
        reg.register(join(prefix, "latency"),
                     lambda: self.latency.percentiles((50, 99)))
        reg.register(join(prefix, "batches"), lambda: self.stats.batches)
        reg.register(join(prefix, "padding_fraction"),
                     lambda: self.stats.padding_fraction)
        reg.register(join(prefix, "pending_examples"),
                     lambda: self.pending_examples)

    def _run(self, ids: np.ndarray) -> np.ndarray:
        total = len(ids)
        out = np.empty(total, np.float32)
        pos = 0
        while pos < total:
            bucket = self.bucket_for(total - pos)
            take = min(total - pos, bucket)
            out[pos:pos + take] = self.runner(ids[pos:pos + take], bucket)
            self.stats.batches += 1
            self.stats.padded_examples += bucket - take
            self.stats.bucket_counts[bucket] = \
                self.stats.bucket_counts.get(bucket, 0) + 1
            pos += take
        return out
