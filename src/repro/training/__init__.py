from repro.training.pipeline import TRAIN_BUCKETS, TrainPipeline
from repro.training.plane import TrainingPlane
from repro.training.registry import TrainRegistry, TrainScenario, TrainStats
from repro.training.scheduler import TrainScheduler
from repro.training.trainer import (TrainState, init_train_state,
                                    make_train_step)

__all__ = ["TRAIN_BUCKETS", "TrainPipeline", "TrainingPlane",
           "TrainRegistry", "TrainScenario", "TrainScheduler", "TrainStats",
           "TrainState", "init_train_state", "make_train_step"]
