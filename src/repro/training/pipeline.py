"""TrainPipeline: the online ingest path of the training plane.

    stream events ─► SampleJoiner (vectorized window join)
        │               └ emit-on-feedback fast path (positives) and
        │                 negative downsampling w/ correction weights
        ▼
    sample buffer ──► pow2 pad-to-bucket micro-batches ──► train_batch
        │                (jit compiles once per bucket shape — the
        │                 training twin of serving's PredictScheduler)
        │
        └ BACKPRESSURE: before training, the pipeline reads the sync
          plane's consumer lag (``Scatter.lag()`` via ``lag_fn``). Above
          ``max_sync_lag`` records it *throttles* — samples stay
          buffered, no updates are pushed, so training cannot outrun
          second-level deployment. If the buffer then outgrows
          ``buffer_cap`` examples, the OLDEST samples are *shed* (they
          are the stalest — timeliness is the whole point of online
          learning) and counted.

All counters (joiner late_feedback / join-delay percentiles, shed and
throttle counts, dedup ratio, padding fraction) surface through
``metrics()`` → ``WeiPSCluster.sync_metrics()["training"]`` — one source
of truth for the benchmark and the monitor.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.data.joiner import JoinedBatch, SampleJoiner
from repro.data.streams import EventBatch
from repro.training.plane import TrainingPlane
from repro.training.registry import TrainScenario

# pow2 ladder, same rationale as serving's DEFAULT_BUCKETS: worst-case
# padding <50 %, bounded compile count
TRAIN_BUCKETS = (128, 256, 512, 1024, 2048, 4096)


class TrainPipeline:
    """stream → join → admit → dedup/coalesce → bucketed train for ONE
    training scenario."""

    def __init__(self, plane: TrainingPlane, scn: TrainScenario,
                 joiner: SampleJoiner, *,
                 buckets: tuple[int, ...] = TRAIN_BUCKETS,
                 lag_fn: Optional[Callable[[], int]] = None,
                 max_sync_lag: Optional[int] = None,
                 buffer_cap: int = 1 << 16):
        assert buckets, "need at least one bucket size"
        self.plane = plane
        self.scn = scn
        self.joiner = joiner
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.lag_fn = lag_fn
        self.max_sync_lag = max_sync_lag
        self.buffer_cap = buffer_cap
        # deque: _take/_shed consume from the head (oldest first) batch
        # by batch — popleft is O(1) where list.pop(0) shifts the tail
        self._buf: deque[JoinedBatch] = deque()
        self._buffered = 0
        # feedback waits here until its event time arrives — delivering
        # it early would let the join window see "future" clicks and
        # nullify the timeliness vs. model-effect trade-off
        self._fb_t = np.empty(0, np.float64)
        self._fb_v = np.empty(0, np.int64)
        self.throttled_ticks = 0
        self.shed_examples = 0
        scn.pipeline = self

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def ingest(self, batch: EventBatch) -> None:
        """Offer one tick's columnar events: exposures immediately,
        feedback queued until its event time matures (delivered here and
        at every ``tick``). Fast-path emissions (emit-on-feedback
        positives) land in the buffer as their feedback arrives;
        window-expiry emissions arrive at the next tick."""
        self.joiner.offer_exposures(batch.t, batch.view_ids,
                                    batch.feature_ids)
        self._fb_t = np.concatenate([self._fb_t, batch.fb_t])
        self._fb_v = np.concatenate([self._fb_v, batch.fb_view_ids])
        self._deliver_feedback(batch.t)

    def _deliver_feedback(self, now: float) -> None:
        """Offer every queued feedback row whose event time has arrived,
        in event-time order."""
        due = self._fb_t <= now
        if not due.any():
            return
        order = np.argsort(self._fb_t[due], kind="stable")
        fast = self.joiner.offer_feedbacks(self._fb_t[due][order],
                                           self._fb_v[due][order])
        self._fb_t, self._fb_v = self._fb_t[~due], self._fb_v[~due]
        if fast is not None and len(fast):
            self._buffer(fast)

    def _buffer(self, batch: JoinedBatch) -> None:
        self._buf.append(batch)
        self._buffered += len(batch)
        self._shed_if_over()

    def _shed_if_over(self) -> None:
        """Drop exactly the OLDEST samples over ``buffer_cap`` (they are
        the stalest), slicing partway into a batch when needed."""
        while self._buffered > self.buffer_cap and self._buf:
            over = self._buffered - self.buffer_cap
            head = self._buf[0]
            if len(head) <= over:
                self._buf.popleft()
                self._buffered -= len(head)
                self.shed_examples += len(head)
            else:
                self._buf[0] = head.slice(over)
                self._buffered -= over
                self.shed_examples += over

    # ------------------------------------------------------------------
    # drive
    # ------------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def tick(self, now: float, *, flush: bool = False) -> list[dict]:
        """Deliver matured feedback, drain the join window into the
        buffer, then train full buckets (every remaining sample too,
        padded, when ``flush``). Throttles — trains nothing — while the
        sync plane's lag exceeds the bound."""
        self._deliver_feedback(now)       # before the expiry sweep: a
        # click due at ``now`` beats a window that closes at ``now``
        drained = self.joiner.drain_batch(now)
        if len(drained):
            self._buffer(drained)
        if self.max_sync_lag is not None and self.lag_fn is not None \
                and self.lag_fn() > self.max_sync_lag:
            self.throttled_ticks += 1
            return []
        out = []
        top = self.buckets[-1]
        while self._buffered >= self.buckets[0] or \
                (flush and self._buffered):
            ids, y, w = self._take(min(self._buffered, top))
            out.append(self.plane.train_batch(
                self.scn, ids, y, weights=w, now=now,
                bucket=self.bucket_for(len(ids))))
        return out

    def flush(self, now: float) -> list[dict]:
        return self.tick(now, flush=True)

    def _take(self, n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Pop the ``n`` oldest buffered samples as one train batch."""
        take, got = [], 0
        while got < n and self._buf:
            b = self._buf[0]
            need = n - got
            if len(b) <= need:
                take.append(b)
                got += len(b)
                self._buf.popleft()
            else:
                take.append(b.slice(0, need))
                self._buf[0] = b.slice(need)
                got = n
        self._buffered -= got
        merged = JoinedBatch.concat(take)
        return merged.feature_ids, merged.labels, merged.weights

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    @property
    def buffered(self) -> int:
        return self._buffered

    def metrics(self) -> dict:
        return {
            "joiner": self.joiner.metrics(),
            "buffered": self._buffered,
            "pending_feedback": len(self._fb_v),
            "throttled_ticks": self.throttled_ticks,
            "shed_examples": self.shed_examples,
        }

    def register_metrics(self, reg, prefix: str = "pipeline") -> None:
        """Publish the pipeline counters (and its joiner's) into a
        ``repro.obs.metrics.MetricsRegistry``."""
        from repro.obs.metrics import join
        self.joiner.register_metrics(reg, join(prefix, "joiner"))
        reg.register(join(prefix, "buffered"), lambda: self._buffered)
        reg.register(join(prefix, "pending_feedback"),
                     lambda: len(self._fb_v))
        reg.register(join(prefix, "throttled_ticks"),
                     lambda: self.throttled_ticks)
        reg.register(join(prefix, "shed_examples"),
                     lambda: self.shed_examples)
