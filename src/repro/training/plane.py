"""The training plane as a subsystem — the symmetric twin of
``serving/plane.py``, closing the paper's fusion claim from the other
side: after PR 4 gave serving its own subsystem (router, cache,
micro-batch scheduler, scenario registry), training was still one
``WeiPSCluster.train_on_batch`` method. This plane promotes it:

    ingest (TrainPipeline) ── join → admit → dedup → bucket
      └ train_batch(scenario, ids, y, w):
          ONE np.unique over the batch's ids (the ≥90 % update-repetition
          dedup, shared by admission, pull, and push)
            ├ FeatureFilter admission — gates row *creation*: the pull
            │   reads with create=False (absent rows are zeros, exactly
            │   what a fresh row would hold) and non-admitted ids are
            │   dropped from the gradient push, so junk features never
            │   allocate PS rows
            ├ pull: argsort owner segments (RowRouter — the SAME routing
            │   code the serving plane runs) → bulk master gathers
            ├ pad rows/labels/weights to the pow2 bucket → the jitted
            │   weighted loss compiles once per bucket shape (the exact
            │   mirror of serving's PredictScheduler)
            ├ progressive validation BEFORE the update (paper §4.3.1):
            │   per-scenario ProgressiveValidator (checkpoint metrics) +
            │   StreamingEvaluator (the downgrade trigger signal)
            └ push: per-row grads segment-summed over the batch inverse,
                routed to owner masters; per-scenario dense head updated
                through the shared optimizer and re-broadcast

Scenarios (``registry.py``) either share store groups or own namespaced
ones created online on every shard — N models training concurrently off
one shared PS, each with its own metrics, step clock, and pipeline.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.weips_ctr import CTRConfig
from repro.core.feature_filter import FeatureFilter
from repro.core.routing import RoutingPlan
from repro.models import ctr as ctr_model
from repro.optim import Optimizer
from repro.serving.router import RowRouter
from repro.training.registry import TrainRegistry, TrainScenario


class TrainingPlane:
    """Training-side subsystem over a cluster's master shards."""

    def __init__(self, plan: RoutingPlan, masters: list,
                 store_groups: dict[str, int], optimizer: Optimizer, *,
                 feature_filter: Optional[FeatureFilter] = None,
                 on_new_groups: Optional[Callable] = None,
                 seed: int = 0):
        self.plan = plan
        self.masters = masters
        self.store_groups = store_groups      # live view of the PS groups
        self.optimizer = optimizer
        self.filter = feature_filter
        # cluster hook: create slave tables / widen serving store_groups
        # when an isolated scenario adds namespaced groups
        self.on_new_groups = on_new_groups
        self.seed = seed
        self.router = RowRouter(plan)
        self.registry = TrainRegistry()

    # ------------------------------------------------------------------
    # scenarios
    # ------------------------------------------------------------------
    def add_scenario(self, cfg: CTRConfig, *, name: Optional[str] = None,
                     share_groups: bool = True) -> TrainScenario:
        """Register a training scenario. ``share_groups=True`` trains the
        store's own groups (validated subset — optimizer slots must line
        up, so the scenario's optimizer family must match the store's).
        ``share_groups=False`` namespaces every group (and dense tensor)
        under ``<name>/`` and creates the tables online on every master
        (and, via ``on_new_groups``, every slave): isolated parameters on
        shared infrastructure."""
        name = name or cfg.name
        if cfg.optimizer != getattr(self.optimizer, "name", cfg.optimizer):
            raise ValueError(
                f"scenario optimizer {cfg.optimizer!r} must match the "
                f"store optimizer {self.optimizer.name!r} (one Pusher "
                f"transform per cluster)")
        groups = ctr_model.groups_for(cfg)
        if share_groups:
            ctr_model.check_scenario_groups(groups, self.store_groups)
            group_map = {g: g for g in groups}
            dense_prefix = ""
        else:
            group_map = {g: f"{name}/{g}" for g in groups}
            dense_prefix = f"{name}/"
            created = {}
            for g, dim in groups.items():
                store_g = group_map[g]
                for m in self.masters:
                    m.add_group(store_g, dim)
                self.store_groups[store_g] = dim
                created[store_g] = dim
            if self.on_new_groups is not None:
                self.on_new_groups(created)

        dense = ctr_model.init_dense(
            cfg, jax.random.PRNGKey(self.seed + len(self.registry)))
        dense_slots = {k: self.optimizer.init_slots(jnp.asarray(v))
                       for k, v in dense.items()}
        scn = TrainScenario(
            name=name, cfg=cfg, group_map=group_map, groups=groups,
            predict=ctr_model.predict_fn(cfg),
            loss_grads=ctr_model.weighted_loss_and_grads_fn(cfg),
            dense=dense, dense_slots=dense_slots, dense_prefix=dense_prefix)
        for dn, v in dense.items():
            self.masters[0].push_dense(scn.dense_store_name(dn), v)
        return self.registry.add(scn)

    def scenario(self, name: Optional[str] = None) -> TrainScenario:
        return self.registry.get(name)

    # ------------------------------------------------------------------
    # pull path (the training twin of ServingPlane.pull_request)
    # ------------------------------------------------------------------
    def pull_unique(self, scn: TrainScenario,
                    uniq: np.ndarray) -> dict[str, np.ndarray]:
        """Unique-space ``{model group: (U, dim)}`` training rows through
        the shared argsort ownership router. ``create=False``: a row that
        does not exist yet reads as zeros — bit-identical to what a
        freshly created row would hold — so row *creation* stays with the
        gradient push, where admission gates it."""
        return self.router.pull(
            uniq, scn.groups, self.plan.master_shard(uniq),
            lambda mid, mids: {
                g: self.masters[mid].pull(scn.group_map[g], mids,
                                          create=False)
                for g in scn.groups})

    # ------------------------------------------------------------------
    # train step
    # ------------------------------------------------------------------
    def train_batch(self, scn: TrainScenario, ids: np.ndarray,
                    y: np.ndarray, *, now: float = 0.0,
                    weights: Optional[np.ndarray] = None,
                    bucket: Optional[int] = None) -> dict:
        """One online-learning step for one scenario: predict-before-train
        validation, weighted loss, gradient push through the PS
        optimizer. ``bucket`` pads rows/labels/weights up to that example
        count (padding weight 0) so the jitted fns compile once per
        bucket shape."""
        ids = np.asarray(ids, dtype=np.int64)
        b, f = ids.shape
        y = np.asarray(y, np.float32)
        w = np.ones(b, np.float32) if weights is None else \
            np.asarray(weights, np.float32)

        # ONE dedup serves admission, pull, and push
        uniq, inverse = RowRouter.unique(ids)
        scn.stats.raw_ids += ids.size
        scn.stats.unique_ids += len(uniq)
        admitted = self.filter.admit(uniq) if self.filter is not None \
            else uniq

        vals = self.pull_unique(scn, uniq)
        rows = RowRouter.expand(vals, inverse, (b, f))

        nb = b if bucket is None or bucket < b else bucket
        if nb > b:
            pad = nb - b
            rows = {g: np.concatenate(
                [v, np.zeros((pad,) + v.shape[1:], v.dtype)]) for g, v
                in rows.items()}
            y_in = np.concatenate([y, np.zeros(pad, np.float32)])
            w_in = np.concatenate([w, np.zeros(pad, np.float32)])
            scn.stats.padded_examples += pad
            scn.stats.bucket_counts[nb] = \
                scn.stats.bucket_counts.get(nb, 0) + 1
        else:
            y_in, w_in = y, w
        rows_j = {k: jnp.asarray(v) for k, v in rows.items()}
        dense_j = {k: jnp.asarray(v) for k, v in scn.dense.items()}

        # progressive validation (predict BEFORE applying the update);
        # padded rows are sliced off — the metrics never see them
        p = np.asarray(scn.predict(rows_j, dense_j))[:b]
        point = scn.validator.observe(now, scn.step, y, p)
        scn.evaluator.observe(now, scn.step, y, p, weights=w)

        loss, row_grads, dense_grads = scn.loss_grads(
            rows_j, dense_j, jnp.asarray(y_in), jnp.asarray(w_in))

        # aggregate per-row grads over duplicate ids, push to owner
        # masters; non-admitted ids are dropped BEFORE the push, so they
        # never create rows (padding rows carry weight 0 → zero grads,
        # and the [:b] slice drops them from the aggregation entirely)
        if self.filter is not None and len(admitted) != len(uniq):
            keep = np.isin(uniq, admitted, assume_unique=True)
        else:
            keep = None
        by_master = self.plan.split_by_master(
            uniq if keep is None else uniq[keep])
        for group, g in row_grads.items():
            g = np.asarray(g)[:b].reshape(-1, g.shape[-1])    # (B*F, dim)
            agg = np.zeros((len(uniq), g.shape[-1]), np.float32)
            np.add.at(agg, inverse, g)
            store_g = scn.group_map[group]
            for mid, mids in by_master.items():
                pos = np.searchsorted(uniq, mids)
                self.masters[mid].push_grad(store_g, mids, agg[pos],
                                            step=scn.step)
        # dense updates (DNN head) on master shard 0
        if dense_grads:
            for dn, g in dense_grads.items():
                new_w, new_slots = self.optimizer.update(
                    jnp.asarray(scn.dense[dn]), scn.dense_slots[dn],
                    g, scn.step)
                scn.dense[dn] = np.asarray(new_w)
                scn.dense_slots[dn] = new_slots
                self.masters[0].push_dense(scn.dense_store_name(dn),
                                           scn.dense[dn])

        scn.step += 1
        scn.stats.batches += 1
        scn.stats.examples += b
        return {"loss": float(loss), **point.values}

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        return {"scenarios": {s.name: s.metrics() for s in self.registry}}

    def register_metrics(self, reg, prefix: str = "training") -> None:
        """Publish per-scenario training counters into a
        ``repro.obs.metrics.MetricsRegistry`` — same shape as
        ``metrics()``."""
        from repro.obs.metrics import join
        reg.register(join(prefix, "scenarios"),
                     lambda: {s.name: s.metrics() for s in self.registry})
