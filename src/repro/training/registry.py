"""Multi-scenario training registry — the symmetric twin of
``serving/registry.py``.

One WeiPS cluster stores a shared sparse parameter space; many *training
scenarios* (model variants) learn off it concurrently, each with its own
jitted weighted loss fn, dense head, progressive-validation evaluators,
step counter, and (optionally) ingest pipeline. A scenario either
*shares* store groups (an LR head refining the ``w`` matrix an FM store
also trains — the EasyRec-style layout) or owns *namespaced* groups
(``"<name>/w"``) created online on every master and slave shard, so its
parameters are isolated while still riding the shared routing plan,
sync stream, checkpointing, and serving fabric. Membership is published
to the coordination registry (``core.scheduler.register_train_scenario``)
exactly like serving scenarios are.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.configs.weips_ctr import CTRConfig
from repro.core.monitor import ProgressiveValidator, StreamingEvaluator


@dataclass
class TrainStats:
    batches: int = 0
    examples: int = 0
    padded_examples: int = 0        # zero-weight rows added to reach a bucket
    raw_ids: int = 0                # ids entering train steps (with repeats)
    unique_ids: int = 0             # ids after per-batch dedup/coalesce
    bucket_counts: dict = field(default_factory=dict)

    @property
    def dedup_ratio(self) -> float:
        """Fraction of per-batch id traffic absorbed by dedup/coalesce
        (the paper's ≥90 % update-repetition observation, measured)."""
        if self.raw_ids == 0:
            return 0.0
        return 1.0 - self.unique_ids / self.raw_ids

    @property
    def padding_fraction(self) -> float:
        total = self.examples + self.padded_examples
        return self.padded_examples / total if total else 0.0


@dataclass
class TrainScenario:
    """Everything one training scenario owns. ``group_map`` maps the
    model's group names (what the loss fn reads) to store group names
    (what the PS tables are called) — identity for shared scenarios,
    ``name/``-prefixed for isolated ones."""

    name: str
    cfg: CTRConfig
    group_map: dict[str, str]                 # model group -> store group
    groups: dict[str, int]                    # model group -> row dim
    predict: Callable                         # jitted (rows, dense) -> (B,)
    loss_grads: Callable                      # jitted (rows, dense, y, w)
    dense: dict[str, np.ndarray]              # model-named dense tensors
    dense_slots: dict[str, dict]
    dense_prefix: str = ""                    # store-name prefix for dense
    validator: ProgressiveValidator = field(
        default_factory=ProgressiveValidator)
    evaluator: StreamingEvaluator = field(default_factory=StreamingEvaluator)
    pipeline: Optional[object] = None         # TrainPipeline, once attached
    step: int = 0
    stats: TrainStats = field(default_factory=TrainStats)

    @property
    def store_groups(self) -> dict[str, int]:
        return {self.group_map[g]: dim for g, dim in self.groups.items()}

    def dense_store_name(self, name: str) -> str:
        return self.dense_prefix + name

    def metrics(self) -> dict:
        out = {"step": self.step,
               "batches": self.stats.batches,
               "examples": self.stats.examples,
               "dedup_ratio": self.stats.dedup_ratio,
               "padding_fraction": self.stats.padding_fraction,
               "logloss": self.evaluator.smoothed("logloss"),
               "auc": self.evaluator.smoothed("auc"),
               "calibration": self.evaluator.smoothed("calibration")}
        if self.pipeline is not None:
            out["pipeline"] = self.pipeline.metrics()
        return out


class TrainRegistry:
    """Named training scenarios; the first one added is the default."""

    def __init__(self):
        self._scenarios: dict[str, TrainScenario] = {}
        self._default: Optional[str] = None

    def add(self, scenario: TrainScenario) -> TrainScenario:
        if scenario.name in self._scenarios:
            raise ValueError(
                f"train scenario {scenario.name!r} already exists")
        self._scenarios[scenario.name] = scenario
        if self._default is None:
            self._default = scenario.name
        return scenario

    def get(self, name: Optional[str] = None) -> TrainScenario:
        key = self._default if name is None else name
        if key is None or key not in self._scenarios:
            raise KeyError(f"unknown train scenario {name!r} "
                           f"(have: {sorted(self._scenarios)})")
        return self._scenarios[key]

    def names(self) -> list[str]:
        return sorted(self._scenarios)

    def __iter__(self):
        return iter(self._scenarios.values())

    def __len__(self) -> int:
        return len(self._scenarios)
