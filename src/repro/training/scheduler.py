"""TrainScheduler: drives N scenario pipelines concurrently off the
shared PS — the training twin of the serving plane's per-scenario
PredictSchedulers, but time-multiplexed (one process simulates the
cluster): each ``tick`` rotates through the registered pipelines in
round-robin order so no scenario starves, and every pipeline applies its
own backpressure bound before pushing updates. Scenario membership is
published through the core coordination ``Scheduler``
(``register_train_scenario``) by the cluster, exactly like serving
scenarios are.
"""

from __future__ import annotations

from typing import Optional

from repro.training.pipeline import TrainPipeline
from repro.training.plane import TrainingPlane


class TrainScheduler:
    """Round-robin driver over every scenario pipeline of a plane."""

    def __init__(self, plane: TrainingPlane):
        self.plane = plane
        self._rr = 0
        self.ticks = 0

    def pipelines(self) -> list[TrainPipeline]:
        return [s.pipeline for s in self.plane.registry
                if s.pipeline is not None]

    def pipeline(self, name: Optional[str] = None) -> TrainPipeline:
        p = self.plane.registry.get(name).pipeline
        if p is None:
            raise KeyError(f"scenario {name!r} has no pipeline attached")
        return p

    def tick(self, now: float, *, flush: bool = False) -> dict[str, list]:
        """Advance every pipeline once, rotating the start position so
        concurrent scenarios share the process fairly."""
        pipes = self.pipelines()
        if not pipes:
            return {}
        self._rr = (self._rr + 1) % len(pipes)
        order = pipes[self._rr:] + pipes[:self._rr]
        self.ticks += 1
        return {p.scn.name: p.tick(now, flush=flush) for p in order}

    def flush(self, now: float) -> dict[str, list]:
        return self.tick(now, flush=True)

    def metrics(self) -> dict:
        return {p.scn.name: p.metrics() for p in self.pipelines()}
