"""Training plane: TrainState, loss, and the pjit-able train_step factory.

``make_train_step`` builds the jitted step for any ModelConfig; batches are
{"tokens": (B, S) int32, optional "enc_context": (B, T, D)}. Labels are the
next-token shift of ``tokens`` (documents are pre-packed by the data
pipeline). The step returns progressive-validation metrics *before* the
update is applied (paper §4.3.1) alongside the post-update state.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward, init_params
from repro.optim import Optimizer, get_optimizer

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    slots: PyTree
    step: jax.Array


def init_train_state(cfg: ModelConfig, key: jax.Array,
                     optimizer: Optional[Optimizer] = None) -> TrainState:
    params = init_params(cfg, key)
    opt = optimizer or get_optimizer(cfg.optimizer)
    slots = opt.init_slots_tree(params)
    return TrainState(params=params, slots=slots,
                      step=jnp.zeros((), jnp.int32))


def _chunked_ce(hidden: jax.Array, head: jax.Array, targets: jax.Array,
                cfg: ModelConfig) -> jax.Array:
    """Cross-entropy over S-chunks: logits for one chunk at a time, with
    per-chunk remat — the (B, S, V) fp32 logits tensor is never fully
    materialized, and the vocab head crosses the mesh once instead of the
    full logits tensor (§Perf)."""
    from repro.models.model import head_logits

    b, s, d = hidden.shape
    chunk = cfg.loss_chunk
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = (s + pad) // chunk
    hidden = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    targets = targets.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h_c, t_c = xs
        logits = head_logits(head, cfg, h_c).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(
            logp, jnp.maximum(t_c, 0)[..., None], axis=-1)[..., 0]
        valid = (t_c >= 0).astype(jnp.float32)
        return (carry[0] + (nll * valid).sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (hidden, targets))
    return total / jnp.maximum(count, 1.0)


def loss_fn(params: PyTree, cfg: ModelConfig, batch: dict,
            aux_weight: float = 0.01):
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    if cfg.loss_chunk:
        from repro.models.model import lm_head_weights
        hidden, metrics = forward(params, cfg, tokens,
                                  enc_context=batch.get("enc_context"),
                                  return_hidden=True)
        ce = _chunked_ce(hidden[:, :-1], lm_head_weights(params, cfg),
                         targets, cfg)
    else:
        logits, metrics = forward(params, cfg, tokens,
                                  enc_context=batch.get("enc_context"))
        logits = logits[:, :-1].astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None],
                                   axis=-1)[..., 0]
        ce = nll.mean()
    loss = ce + aux_weight * metrics.get("moe_aux", 0.0)
    out_metrics = {
        "loss": loss,
        "ce": ce,
        "ppl_log": ce,
        "moe_aux": metrics.get("moe_aux", jnp.zeros(())),
    }
    if "expert_counts" in metrics:
        out_metrics["expert_counts"] = metrics["expert_counts"]
        out_metrics["expert_counts_per_layer"] = \
            metrics["expert_counts_per_layer"]
    return loss, out_metrics


def make_train_step(cfg: ModelConfig, optimizer: Optional[Optimizer] = None,
                    aux_weight: float = 0.01, jit: bool = True,
                    donate: bool = True):
    opt = optimizer or get_optimizer(cfg.optimizer)

    def train_step(state: TrainState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, cfg, batch, aux_weight)
        new_params, new_slots = opt.update_tree(
            state.params, state.slots, grads, state.step)
        new_state = TrainState(params=new_params, slots=new_slots,
                               step=state.step + 1)
        return new_state, metrics

    if jit:
        return jax.jit(train_step, donate_argnums=(0,) if donate else ())
    return train_step
