"""Shared helpers for the chaos-test suite: one place that fixes the
cluster shape and step budget so every test (and the baseline fixture)
runs the exact same trajectory."""

from __future__ import annotations

import numpy as np

from repro.launch.runtime import ClusterRuntime, RuntimeConfig

STEPS = 14
MASTERS = ["master-0", "master-1"]
SLAVES = ["slave-0.0", "slave-1.0"]

# one shape for the whole suite — the baseline run is only comparable to
# a chaos run that used the identical config
CLUSTER_KW = dict(num_master=2, num_slave=2, num_replicas=1,
                  num_partitions=4, ckpt_every=4)


def make_runtime(root, plan=None, **overrides) -> ClusterRuntime:
    kw = dict(CLUSTER_KW)
    kw.update(overrides)
    return ClusterRuntime(RuntimeConfig(root=str(root), **kw), plan)


def run_cluster(root, plan=None, steps=STEPS, **overrides):
    """Run a cluster to ``steps`` and return its end-state summary."""
    rt = make_runtime(root, plan, **overrides)
    try:
        rt.start()
        rt.run_to(steps)
        return {"recoveries": rt.recoveries,
                "masters": rt.master_state(),
                "slaves": rt.slave_state(),
                "downgrades": list(rt.downgrader.downgrades)}
    finally:
        rt.shutdown()


def tables_equal(a: dict, b: dict) -> bool:
    """Bit-equality of two canonical table dumps (ids, w, slots)."""
    if not np.array_equal(a["ids"], b["ids"]):
        return False
    if not np.array_equal(a["w"], b["w"]):
        return False
    if sorted(a["slots"]) != sorted(b["slots"]):
        return False
    return all(np.array_equal(a["slots"][k], b["slots"][k])
               for k in a["slots"])


def assert_states_equal(got: dict, want: dict, what: str) -> None:
    assert sorted(got) == sorted(want), \
        f"{what}: shard sets differ: {sorted(got)} vs {sorted(want)}"
    for name in want:
        assert tables_equal(got[name], want[name]), \
            f"{what}: state of {name} is not bit-equal"


def master_serve_w(masters: dict) -> dict:
    """id -> serve weight across all master shards (FTRL stores the
    derived serve weight in w, so this is what slaves must converge to)."""
    out = {}
    for st in masters.values():
        for i, wid in enumerate(st["ids"]):
            out[int(wid)] = st["w"][i]
    return out


def assert_slaves_consistent(masters: dict, slaves: dict) -> None:
    """Every slave row must hold exactly the master's current serve
    weight for that id (the symmetric-fusion consistency invariant once
    the stream is drained)."""
    want = master_serve_w(masters)
    for name, st in slaves.items():
        assert len(st["ids"]), f"{name} is empty"
        for i, wid in enumerate(st["ids"]):
            assert int(wid) in want, f"{name} serves unknown id {wid}"
            assert np.array_equal(st["w"][i], want[int(wid)]), \
                f"{name} serves stale value for id {wid}"
