import pytest

from _harness import STEPS, run_cluster


@pytest.fixture(scope="session")
def fault_free_run(tmp_path_factory):
    """The no-fault reference trajectory every chaos run is compared
    against — run once per session (cluster startup pays the jax import
    per worker process)."""
    root = tmp_path_factory.mktemp("fault-free")
    return run_cluster(root, plan=None, steps=STEPS)
