"""FaultPlan-driven chaos for the serve/train *planes* (the in-process
twin of test_chaos_runtime.py, which covers the process grid): a slave
replica dies mid-predict-stream while the admission path is actively
shedding, and a master kill lands mid-train-flush so a tick's sync never
completes. Recovery follows the PR 7 supervisor shape — restore ALL
masters from the last cut, rewind, replay the gap deterministically —
and the assertions are the trajectory-preservation invariants: the
post-recovery predict stream and the final table state are bit-equal to
the fault-free run.

These run in-process (no worker processes), so they are tier-1 tests —
no ``chaos`` marker needed."""

from dataclasses import replace

import numpy as np

from repro.configs.weips_ctr import LR_FTRL
from repro.core.cluster import ClusterConfig, WeiPSCluster
from repro.launch.chaos import FaultEvent, FaultPlan

SPACE = 1 << 10
FIELDS = 4
STEPS = 12
CKPT_EVERY = 4
BUDGET = 64                 # serve budget (examples) per step
SERVE_REQS = 3              # requests offered per step
REQ_N = 48                  # 3*48 offered vs 64 budget: sustained overload

CFG = replace(LR_FTRL, fields=FIELDS, feature_space=SPACE)


def make_cluster() -> WeiPSCluster:
    return WeiPSCluster(CFG, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=2, num_partitions=4,
        serve_max_pending=2 * BUDGET, seed=9))


def train_batch_for(step: int, n: int = 32):
    rng = np.random.default_rng(1000 + step)
    ids = (rng.zipf(1.3, size=(n, FIELDS)) % SPACE).astype(np.int64)
    return ids, (rng.random(n) < 0.5).astype(np.float32)


def serve_batch_for(step: int, r: int) -> np.ndarray:
    rng = np.random.default_rng(5000 + 31 * step + r)
    return (rng.zipf(1.3, size=(REQ_N, FIELDS)) % SPACE).astype(np.int64)


def run_planes(plan: FaultPlan = None, steps: int = STEPS):
    """Closed-loop serve+train driver interpreting a FaultPlan against
    one in-process cluster.

    * slave targets die mid-predict-stream: requests for the kill step
      are already admitted when the replica drops, so the flush's pulls
      must fail over to the surviving replica of the shard;
    * master targets die mid-train-flush: the tick trains (optimizer
      state mutated, updates collected) but the kill lands before the
      sync pushes, so the flush never reaches the queue. Recovery
      restores ALL masters from the latest cut and the driver rewinds to
      the cut and replays the gap with the same per-step batches — the
      supervisor state machine of launch/runtime.py, in-process.
    """
    cl = make_cluster()
    events = list(plan.kills()) if plan is not None else []
    fired = set()
    preds: dict[int, list] = {}
    recoveries = 0
    ckpt_step = 0
    cl.checkpoint(0.0)
    step = 0
    while step < steps:
        now = float(step + 1)
        due = [e for e in events if e.step == step and e not in fired]
        ids, y = train_batch_for(step)
        cl.train_on_batch(ids, y, now=now)
        dead_master = next((e for e in due
                            if e.target.startswith("master-")), None)
        if dead_master is not None:
            fired.add(dead_master)
            cl.kill_master(int(dead_master.target.split("-")[1]))
            cl.cold_backup.recover_all(cl.masters)
            recoveries += 1
            step = ckpt_step            # rewind + deterministic replay
            continue
        cl.sync_tick(now)
        for r in range(SERVE_REQS):     # admit the step's predict load
            cl.serving.submit(serve_batch_for(step, r))
        for e in due:                   # slave dies mid-predict-stream
            if e.target.startswith("slave-"):
                fired.add(e)
                sid, rid = e.target.split("-")[1].split(".")
                cl.kill_slave_replica(int(sid), int(rid))
        out = cl.serving.flush(budget=BUDGET)
        preds[step] = [p for p in out if p is not None]
        step += 1
        if step % CKPT_EVERY == 0:
            cl.checkpoint(float(step))
            ckpt_step = step
    cl.sync_tick(float(steps + 1))      # final drain
    return cl, preds, recoveries


def master_tables(cl: WeiPSCluster) -> dict:
    out = {}
    for m in cl.masters:
        for g, t in m.tables.items():
            ids = np.sort(t.all_ids())
            w, _ = t.gather(ids)
            out[(m.shard_id, g)] = (ids, w)
    return out


def slave_tables(cl: WeiPSCluster) -> dict:
    out = {}
    for sid, rs in enumerate(cl.replica_sets):
        for rid, shard in enumerate(rs.replicas):
            if not shard.alive:
                continue
            for g, t in shard.tables.items():
                ids = np.sort(t.all_ids())
                out[(sid, rid, g)] = (ids, shard.lookup(g, ids))
    return out


def assert_tables_equal(got: dict, want: dict, what: str) -> None:
    assert sorted(got) == sorted(want), f"{what}: key sets differ"
    for k in want:
        np.testing.assert_array_equal(got[k][0], want[k][0],
                                      err_msg=f"{what}: ids of {k}")
        np.testing.assert_array_equal(got[k][1], want[k][1],
                                      err_msg=f"{what}: values of {k}")


def test_slave_dies_mid_predict_stream():
    """Replica failover mid-stream: the kill lands between admit and
    flush, the survivor serves every executed ticket, and the WHOLE
    predict trajectory (and shed accounting) is bit-equal to the
    fault-free run — replicas are copies, so losing one must not change
    a single prediction."""
    base_cl, base_preds, _ = run_planes(None)
    plan = FaultPlan(seed=3, events=[
        FaultEvent("slave-0.1", "pre_apply", 5, "kill")])
    cl, preds, _ = run_planes(plan)
    assert sorted(preds) == sorted(base_preds)
    for s in base_preds:
        assert len(preds[s]) == len(base_preds[s]), f"step {s}"
        for a, b in zip(preds[s], base_preds[s]):
            np.testing.assert_array_equal(a, b, err_msg=f"step {s}")
    # the survivor actually carried reads after the kill
    assert cl.replica_sets[0].failovers > 0 or \
        not cl.replica_sets[0].replicas[1].alive
    # the admission path kept shedding (overload never paused) and its
    # accounting stayed balanced through the failover
    adm = cl.serving.metrics()["admission"]
    assert adm["shed_examples"] > 0
    pending = sum(s.scheduler.pending_examples
                  for s in cl.serving.registry)
    assert adm["executed_examples"] + adm["shed_examples"] + pending \
        == adm["offered_examples"]
    base_adm = base_cl.serving.metrics()["admission"]
    assert adm == base_adm     # shedding decisions identical w/ failover


def test_master_kill_mid_train_flush_replays_bit_equal():
    """A master dies after training mutated its optimizer state but
    before the sync flush lands. Restore-all + rewind + replay must
    reproduce the fault-free trajectory exactly: final master AND slave
    tables bit-equal, and the post-recovery predict stream bit-equal."""
    kill_step = 6
    base_cl, base_preds, base_rec = run_planes(None)
    assert base_rec == 0
    plan = FaultPlan(seed=4, events=[
        FaultEvent("master-1", "mid_flush", kill_step, "kill")])
    cl, preds, recoveries = run_planes(plan)
    assert recoveries == 1
    assert all(m.alive for m in cl.masters)
    assert_tables_equal(master_tables(cl), master_tables(base_cl),
                        "masters after mid-flush kill")
    assert_tables_equal(slave_tables(cl), slave_tables(base_cl),
                        "slaves after mid-flush kill")
    # during replay the slaves are AHEAD of the rolled-back masters, so
    # pre-kill-step predictions may legitimately differ; from the kill
    # step on the trajectory must be bit-equal
    for s in range(kill_step, STEPS):
        assert len(preds[s]) == len(base_preds[s]), f"step {s}"
        for a, b in zip(preds[s], base_preds[s]):
            np.testing.assert_array_equal(a, b, err_msg=f"step {s}")


def test_generated_plan_planes_survive():
    """Property over generated plans: whatever single kill the seeded
    generator draws (slave replica or master), the planes keep serving
    (counters balanced, at least one live replica per shard) and the
    final master state is bit-equal to the fault-free run — slave kills
    only remove redundancy, master kills are replayed away."""
    base_cl, _, _ = run_planes(None)
    want = master_tables(base_cl)
    for seed in (11, 23):
        gen = FaultPlan.generate(seed, steps=STEPS,
                                 masters=["master-0", "master-1"],
                                 slaves=["slave-0.1", "slave-1.1"])
        plan = FaultPlan(seed=seed, events=gen.kills()[:1])
        cl, preds, _ = run_planes(plan)
        assert len(preds) == STEPS
        assert_tables_equal(master_tables(cl), want,
                            f"masters (seed {seed})")
        for rs in cl.replica_sets:
            assert any(sh.alive for sh in rs.replicas)
        adm = cl.serving.metrics()["admission"]
        pending = sum(s.scheduler.pending_examples
                      for s in cl.serving.registry)
        assert adm["executed_examples"] + adm["shed_examples"] \
            + pending == adm["offered_examples"]
