"""Chaos-harness acceptance tests: deterministic FaultPlans, SIGKILL
recovery through the checkpoint chain + scatter seek, trajectory
preservation (bit-equal state vs. the fault-free run), evaluator-driven
domino downgrade, and elastic replica add/remove.

Every multi-process test carries the ``chaos`` marker: opt in with
``pytest -m chaos --chaos`` (per-test wall-clock cap via
``--chaos-timeout``). A failing CI seed reproduces locally with
``pytest tests/chaos --chaos --chaos-seed <seed>``.
"""

import numpy as np
import pytest

from _harness import (MASTERS, SLAVES, STEPS, assert_slaves_consistent,
                      assert_states_equal, make_runtime, run_cluster)

from repro.launch.chaos import KILL_POINTS, FaultEvent, FaultPlan


def test_fault_plan_deterministic():
    """Same (seed, shape) -> identical plan; JSON round-trips; events sit
    inside the driveable step range. Runs in-process (no cluster)."""
    for seed in (7, 11, 23):
        a = FaultPlan.generate(seed, steps=STEPS, masters=MASTERS,
                               slaves=SLAVES)
        b = FaultPlan.generate(seed, steps=STEPS, masters=MASTERS,
                               slaves=SLAVES)
        assert a.events == b.events
        assert FaultPlan.from_json(a.to_json()).events == a.events
        assert len(a.kills()) == 2
        for e in a.events:
            assert e.point in KILL_POINTS
            assert 1 <= e.step <= STEPS - 2
    assert FaultPlan.generate(7, steps=STEPS, masters=MASTERS,
                              slaves=SLAVES).events != \
        FaultPlan.generate(8, steps=STEPS, masters=MASTERS,
                           slaves=SLAVES).events


@pytest.mark.chaos
def test_slave_sigkill_recovers_and_serves(tmp_path):
    """A slave replica SIGKILLed mid-stream comes back via checkpoint
    bootstrap + scatter seek and converges to the master's serve state."""
    plan = FaultPlan(seed=0, events=[
        FaultEvent("slave-0.0", "pre_apply", 5, "kill")])
    out = run_cluster(tmp_path, plan)
    assert out["recoveries"] == 1
    assert_slaves_consistent(out["masters"], out["slaves"])


@pytest.mark.chaos
def test_master_sigkill_mid_train_recovers(tmp_path, fault_free_run):
    """A master SIGKILLed right after mutating optimizer state restores
    from the chain and replays to the exact fault-free trajectory."""
    plan = FaultPlan(seed=0, events=[
        FaultEvent("master-1", "mid_train", 6, "kill")])
    out = run_cluster(tmp_path, plan)
    assert out["recoveries"] == 1
    assert_states_equal(out["masters"], fault_free_run["masters"],
                        "masters after mid_train kill")
    assert_states_equal(out["slaves"], fault_free_run["slaves"],
                        "slaves after mid_train kill")


@pytest.mark.chaos
def test_recovery_is_trajectory_preserving(tmp_path, fault_free_run,
                                           chaos_seed):
    """Property: for generated FaultPlans (>= 3 seeds), N injected kills
    produce bit-equal master AND slave table state to the fault-free run
    once the cluster catches up — recovery neither loses nor double-
    applies a single update."""
    for seed in (chaos_seed, chaos_seed + 4, chaos_seed + 16):
        plan = FaultPlan.generate(seed, steps=STEPS, masters=MASTERS,
                                  slaves=SLAVES)
        out = run_cluster(tmp_path / f"seed{seed}", plan)
        assert out["recoveries"] >= 1, \
            f"seed {seed}: plan had kills but nothing died"
        assert_states_equal(out["masters"], fault_free_run["masters"],
                            f"masters (seed {seed})")
        assert_states_equal(out["slaves"], fault_free_run["slaves"],
                            f"slaves (seed {seed})")


@pytest.mark.chaos
def test_domino_downgrade_fires_and_unfires(tmp_path):
    """The streaming evaluator trips the smoothed trigger early (the
    untrained model's logloss sits at ~0.69), the downgrade executes a
    hot switch to the stable version, and the fired state decays once the
    cooldown window closes without a re-trip (the model has learned past
    the threshold by then)."""
    rt = make_runtime(
        tmp_path,
        # learn fast enough that smoothed logloss falls below the
        # threshold inside the run: weak l1, hot alpha
        optimizer_kwargs={"alpha": 0.5, "l1": 0.01},
        # the untrained model sits at ~0.69 and drops below 0.64 for good
        # by step 12; cooldown 8 blocks refires until the model is past
        # the threshold, so the trigger trips exactly once. min_points 5:
        # the first possible fire lands after checkpoint v2 exists, so
        # the bootstrap version is never the only candidate.
        trigger_threshold=0.64, trigger_window=3, trigger_min_points=5,
        downgrade_cooldown=8.0)
    try:
        rt.start()
        rt.run_to(30)
        fired = rt.downgrader.downgrades
        assert len(fired) == 1, f"expected exactly one downgrade: {fired}"
        t0, v = fired[0]
        assert v in rt.store.versions()
        # fired: active inside the cooldown window...
        assert rt.downgrader.active(t0 + rt.downgrader.cooldown / 2)
        # ...un-fired: inactive now, and the trigger never re-tripped
        assert not rt.downgrader.active(float(rt.step))
        assert rt.evaluator.smoothed("logloss", 3) < 0.64
        # post-switch, replayed stream re-converged serving to training
        assert_slaves_consistent(rt.master_state(), rt.slave_state())
    finally:
        rt.shutdown()


@pytest.mark.chaos
def test_elastic_add_remove_replica(tmp_path):
    """A replica added at runtime bootstraps from the latest committed
    checkpoint, catches up from the stream, and serves the same bits as
    the incumbent replica of its shard; removing it drains cleanly."""
    rt = make_runtime(tmp_path)
    try:
        rt.start()
        rt.run_to(6)
        name = rt.add_replica(0)
        assert name == "slave-0.1"
        rt.run_to(10)
        slaves = rt.slave_state()
        assert np.array_equal(slaves["slave-0.0"]["ids"],
                              slaves["slave-0.1"]["ids"])
        assert np.array_equal(slaves["slave-0.0"]["w"],
                              slaves["slave-0.1"]["w"])
        rt.remove_replica(name)
        assert name not in rt.clients and name not in rt.procs
        rt.run_to(12)          # cluster keeps running without the replica
        assert_slaves_consistent(rt.master_state(), rt.slave_state())
    finally:
        rt.shutdown()
