"""Cross-process trace propagation under chaos: with
``RuntimeConfig(trace=True)`` a seeded SIGKILL mid-flush must still
yield one merged Perfetto export containing the killed worker's
pre-kill spans (dump file written by the ``on_fire`` hook the instant
before the SIGKILL), the fault annotation, the supervisor's recovery
spans, and causally-linked push → queue → apply chains that span OS
processes — with no orphaned span parents."""

import numpy as np
import pytest

from _harness import SLAVES, STEPS, make_runtime

from repro.launch.chaos import FaultEvent, FaultPlan
from repro.obs import perfetto
from repro.obs import trace as obs_trace


@pytest.fixture
def _reset_tracer():
    """RuntimeConfig(trace=True) flips the process-global supervisor
    tracer on; restore the zero-cost disabled state for the rest of
    the chaos session."""
    yield
    obs_trace.disable()


@pytest.mark.chaos
def test_kill_mid_flush_exports_one_causal_trace(tmp_path, _reset_tracer):
    plan = FaultPlan(seed=0, events=[
        FaultEvent("master-0", "mid_flush", 5, "kill")])
    rt = make_runtime(tmp_path, plan, trace=True)
    try:
        rt.start()
        # warm every slave's serve cache over RPC so stream applies
        # invalidate real rows (the cache.invalidate leg of the chain)
        warm = np.arange(rt.cfg.vocab, dtype=np.int64)
        for name in rt.slave_names():
            rt.clients[name].call("lookup", group="emb", ids=warm)
        rt.run_to(STEPS)
        path = str(tmp_path / "chaos_trace.json")
        n = rt.export_trace(path)
        assert n > 0
        metrics = rt.cluster_metrics()
    finally:
        rt.shutdown()

    assert rt.recoveries == 1
    spans = perfetto.load_spans(path)
    by_name: dict = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s)

    # -- the fault annotation survived the SIGKILL (pre-kill dump file)
    kills = by_name.get("fault.kill", [])
    assert kills, "killed worker's pre-kill dump is missing"
    assert kills[0]["proc"] == "master-0"
    assert kills[0]["t1"] is None
    assert kills[0]["args"]["point"] == "mid_flush"

    # -- supervisor recorded detection + recovery
    assert by_name.get("fault.detected")
    recs = by_name.get("recover", [])
    assert recs and recs[0]["proc"] == "supervisor"
    assert "master-0" in recs[0]["args"]["workers"]
    assert by_name.get("driver.step") and by_name.get("ckpt.commit")

    # -- no orphaned span ids: every non-zero parent resolves
    ids = {s["span"] for s in spans}
    for s in spans:
        assert s["parent"] == 0 or s["parent"] in ids, \
            f"orphaned parent on {s['name']}: {s['parent']:#x}"

    # -- causal chains cross the process boundary: for every queue span
    # its parent push span lives in a master process, and applies
    # parent under queues in the same (slave) process
    pushes = {s["span"]: s for s in by_name.get("sync.push", [])}
    queues = by_name.get("sync.queue", [])
    applies = {s["span"]: s for s in by_name.get("sync.apply", [])}
    assert pushes and queues and applies
    crossed = 0
    for q in queues:
        push = pushes[q["parent"]]
        assert push["trace"] == q["trace"]
        assert push["proc"].startswith("master-")
        assert q["proc"] in SLAVES
        if push["proc"] != q["proc"]:
            crossed += 1
    assert crossed, "no trace crossed a process boundary"
    for a in applies.values():
        parent = next(q for q in queues if q["span"] == a["parent"])
        assert parent["trace"] == a["trace"]
        assert parent["proc"] == a["proc"]

    # -- the warm serve cache produced invalidations under applies
    invs = by_name.get("cache.invalidate", [])
    assert invs, "no cache.invalidate spans despite warmed caches"
    for inv in invs:
        assert inv["parent"] in applies
        assert inv["trace"] == applies[inv["parent"]]["trace"]

    # -- spans from the killed master's FIRST life made it into the
    # merge: its dump file carries spans with its pre-kill pid salt,
    # which differs from the respawned master-0's salt
    m0_salts = {s["span"] >> 32 for s in spans
                if s["proc"] == "master-0"}
    assert len(m0_salts) >= 2, \
        "expected spans from both lives of master-0"

    # -- worker metrics RPC aggregation held up through the fault
    assert metrics["recoveries"] == 1
    assert metrics["aggregate"]["applied"] > 0
    slave_trees = [m for n, m in metrics["workers"].items()
                   if n.startswith("slave-") and m]
    assert slave_trees
    for t in slave_trees:
        assert t["cache"]["invalidated"] > 0
