"""Crash-window edge cases: each test arms one surgical FaultEvent at a
specific instrumented point and proves the window it exposes is closed.

  * mid_flush  — kill between ``Pusher.push`` and the slaves' poll: the
    flush lands half-pushed; replay re-emits equal-seq full-value records
    and the end state shows no double-apply (bit-equal to fault-free).
  * mid_ckpt   — kill mid-delta-checkpoint: the part file is written but
    the manifest never commits, the previous chain stays materializable,
    and the next checkpoint after recovery is forced full.
  * bootstrap  — a replica bootstrapping from a checkpoint while the live
    scatter stream keeps producing converges to the incumbent's bits.
"""

import numpy as np
import pytest

from _harness import (assert_slaves_consistent, assert_states_equal,
                      make_runtime, run_cluster)

from repro.launch.chaos import FaultEvent, FaultPlan


@pytest.mark.chaos
def test_kill_between_flush_and_apply(tmp_path, fault_free_run):
    """Torn flush: master-0 dies having pushed only part of a flush's
    records. On replay the restored pusher re-emits the full flush under
    the SAME seq; slaves LWW-skip / idempotently re-apply, so nothing is
    double-applied and the trajectory is preserved bit-for-bit."""
    plan = FaultPlan(seed=0, events=[
        FaultEvent("master-0", "mid_flush", 6, "kill")])
    out = run_cluster(tmp_path, plan)
    assert out["recoveries"] == 1
    assert_states_equal(out["masters"], fault_free_run["masters"],
                        "masters after torn flush")
    assert_states_equal(out["slaves"], fault_free_run["slaves"],
                        "slaves after torn flush")


@pytest.mark.chaos
def test_kill_mid_delta_checkpoint(tmp_path, fault_free_run):
    """Torn checkpoint: master-0 dies after writing its delta part but
    before the atomic rename. The manifest for that version is never
    committed — the chain stays intact and materializable — and the
    first checkpoint after recovery is forced full."""
    # with ckpt_every=4 and the bootstrap full at step 0, the checkpoint
    # cut during step 3's step_once carries step index 4 and kind=delta
    plan = FaultPlan(seed=0, events=[
        FaultEvent("master-0", "mid_ckpt", 4, "kill")])
    rt = make_runtime(tmp_path, plan)
    try:
        rt.start()
        assert rt.store.versions() == [1]
        rt.run_to(14)
        assert rt.recoveries == 1
        vs = rt.store.versions()
        assert len(vs) >= 2
        # every committed version still materializes through its chain
        for v in vs:
            snaps, seqs = rt.store.materialize(v)
            assert sorted(snaps) == [0, 1]
        # the first post-recovery checkpoint was forced full
        post = rt.store.load(vs[1])
        assert post.kind == "full"
        assert post.base is None
        assert_states_equal(rt.master_state(), fault_free_run["masters"],
                            "masters after torn checkpoint")
        assert_states_equal(rt.slave_state(), fault_free_run["slaves"],
                            "slaves after torn checkpoint")
    finally:
        rt.shutdown()


@pytest.mark.chaos
def test_replica_bootstrap_races_live_stream(tmp_path):
    """Bootstrap vs. stream race: a replica added mid-run loads the
    checkpoint's serve rows while masters keep flushing. Because the
    bootstrap seeks to the checkpoint's queue offsets and stream records
    are full-value upserts, the replay overlap is idempotent — the new
    replica ends bit-equal to the incumbent replica of its shard."""
    rt = make_runtime(tmp_path)
    try:
        rt.start()
        rt.run_to(7)          # past checkpoint v2: real rows in the chain
        name = rt.add_replica(1)
        # the join races live production: keep training immediately
        rt.run_to(13)
        slaves = rt.slave_state()
        inc, new = slaves["slave-1.0"], slaves[name]
        assert len(inc["ids"])
        assert np.array_equal(inc["ids"], new["ids"])
        assert np.array_equal(inc["w"], new["w"])
        assert_slaves_consistent(rt.master_state(), slaves)
    finally:
        rt.shutdown()


@pytest.mark.chaos
def test_transport_drop_redelivers(tmp_path, fault_free_run):
    """A dropped fetch response leaves the consumer offsets unmoved; the
    next poll redelivers and the run still converges to the fault-free
    trajectory (at-least-once + idempotent apply)."""
    plan = FaultPlan(seed=0, events=[
        FaultEvent("slave-1.0", "pre_apply", 3, "drop"),
        FaultEvent("slave-1.0", "pre_apply", 9, "drop"),
        FaultEvent("master-1", "mid_flush", 5, "delay", 0.02)])
    out = run_cluster(tmp_path, plan)
    assert out["recoveries"] == 0
    assert_states_equal(out["slaves"], fault_free_run["slaves"],
                        "slaves after drops")
