import os

# Tests run on the single real CPU device — the 512-device override is ONLY
# for the dry-run launcher (see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked slow")


def pytest_collection_modifyitems(config, items):
    """Auto-skip: ``tpu``-marked tests (non-interpret Pallas) off-TPU, so
    the suite is green on CPU CI runners; ``slow`` unless opted in."""
    import jax
    on_tpu = jax.default_backend() == "tpu"
    run_slow = config.getoption("--runslow") or bool(os.environ.get("RUN_SLOW"))
    skip_tpu = pytest.mark.skip(
        reason="requires a real TPU (non-interpret Pallas)")
    skip_slow = pytest.mark.skip(reason="slow: pass --runslow or RUN_SLOW=1")
    for item in items:
        if "tpu" in item.keywords and not on_tpu:
            item.add_marker(skip_tpu)
        if "slow" in item.keywords and not run_slow:
            item.add_marker(skip_slow)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
