import os

# Tests run on the single real CPU device — the 512-device override is ONLY
# for the dry-run launcher (see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked slow")
    parser.addoption("--chaos", action="store_true", default=False,
                     help="run multi-process chaos-harness tests")
    parser.addoption("--chaos-seed", action="store", type=int, default=7,
                     help="FaultPlan seed for the fault_plan fixture")
    parser.addoption("--chaos-timeout", action="store", type=int,
                     default=600,
                     help="per-test SIGALRM timeout (s) for chaos tests")


def pytest_collection_modifyitems(config, items):
    """Auto-skip: ``tpu``-marked tests (non-interpret Pallas) off-TPU, so
    the suite is green on CPU CI runners; ``slow``/``chaos`` unless opted
    in (chaos tests spawn a process per PS shard — minutes, not ms)."""
    import jax
    on_tpu = jax.default_backend() == "tpu"
    run_slow = config.getoption("--runslow") or bool(os.environ.get("RUN_SLOW"))
    run_chaos = config.getoption("--chaos") or bool(os.environ.get("RUN_CHAOS"))
    skip_tpu = pytest.mark.skip(
        reason="requires a real TPU (non-interpret Pallas)")
    skip_slow = pytest.mark.skip(reason="slow: pass --runslow or RUN_SLOW=1")
    skip_chaos = pytest.mark.skip(reason="chaos: pass --chaos or RUN_CHAOS=1")
    # match the actual @pytest.mark markers, not item.keywords — keywords
    # include every parent node's *name*, so the tests/chaos directory
    # itself would gate even unmarked (in-process, tier-1) tests in it
    for item in items:
        if item.get_closest_marker("tpu") and not on_tpu:
            item.add_marker(skip_tpu)
        if item.get_closest_marker("slow") and not run_slow:
            item.add_marker(skip_slow)
        if item.get_closest_marker("chaos") and not run_chaos:
            item.add_marker(skip_chaos)


@pytest.fixture(autouse=True)
def _chaos_deadline(request):
    """Per-test wall-clock deadline for ``chaos``-marked tests: a stuck
    recovery (worker that never rebinds, supervisor waiting on a dead
    socket) fails loudly with a timeout instead of hanging CI. SIGALRM —
    no external timeout plugin in the image."""
    if "chaos" not in request.keywords:
        yield
        return
    import signal
    seconds = request.config.getoption("--chaos-timeout")

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"chaos test exceeded --chaos-timeout={seconds}s")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture
def chaos_seed(request):
    return request.config.getoption("--chaos-seed")


@pytest.fixture
def fault_plan(chaos_seed):
    """Deterministic FaultPlan for the default chaos cluster shape
    (2 masters x 2 slave shards x 1 replica), seeded by ``--chaos-seed``
    so a failed CI run is reproducible with one flag."""
    from repro.launch.chaos import FaultPlan
    return FaultPlan.generate(
        chaos_seed, steps=14,
        masters=["master-0", "master-1"],
        slaves=["slave-0.0", "slave-1.0"])


@pytest.fixture
def rng():
    return np.random.default_rng(0)
