"""Incremental checkpoint/recovery plane: delta capture, full+delta chain
restore bit-equality, vectorized reshard routing, retention demotion,
replica bootstrap-from-checkpoint, downgrade queue-offset replay."""

import numpy as np
import pytest

from repro.configs.weips_ctr import LR_FTRL
from repro.core import ClusterConfig, RoutingPlan, WeiPSCluster
from repro.core.fault_tolerance import (BackupPolicy, CheckpointStore,
                                        ColdBackup, checkpoint_nbytes)
from repro.core.ps import MasterShard, SlaveShard, SparseTable
from repro.data import ClickStream
from repro.optim import get_optimizer

GROUPS = {"w": 4}


def _shards(n, opt=None):
    opt = opt or get_optimizer("ftrl")
    return [MasterShard(i, GROUPS, opt) for i in range(n)]


def _push(shards, plan, rng, n=512, step=0):
    """Push one random batch of grads, routed to owner shards."""
    ids = np.sort(rng.choice(1 << 30, size=n, replace=False).astype(np.int64))
    grads = rng.normal(size=(n, GROUPS["w"])).astype(np.float32)
    for sid, sids in plan.split_by_master(ids).items():
        shards[sid].push_grad("w", sids, grads[np.searchsorted(ids, sids)],
                              step=step)
    return ids


def _sorted_state(shard, group="w"):
    snap = shard.tables[group].snapshot()
    order = np.argsort(snap["ids"])
    return {"ids": snap["ids"][order], "w": snap["w"][order],
            "slots": {k: v[order] for k, v in snap["slots"].items()},
            "last_touch": snap["last_touch"][order],
            "touch_count": snap["touch_count"][order]}


def _assert_state_equal(a, b):
    np.testing.assert_array_equal(a["ids"], b["ids"])
    np.testing.assert_array_equal(a["w"], b["w"])
    assert set(a["slots"]) == set(b["slots"])
    for k in a["slots"]:
        np.testing.assert_array_equal(a["slots"][k], b["slots"][k])
    np.testing.assert_array_equal(a["last_touch"], b["last_touch"])
    np.testing.assert_array_equal(a["touch_count"], b["touch_count"])


# ---------------------------------------------------------------------------
# delta capture at the table level
# ---------------------------------------------------------------------------
def test_delta_snapshot_captures_only_dirty_rows_and_deletes():
    t = SparseTable(2)
    rng = np.random.default_rng(0)
    base_ids = np.arange(100, dtype=np.int64)
    t.scatter(base_ids, rng.normal(size=(100, 2)).astype(np.float32))
    mark = t.version
    dirty = np.array([3, 7, 250], dtype=np.int64)       # 250 is new
    t.scatter(dirty, np.ones((3, 2), np.float32))
    t.evict(np.array([10, 11], dtype=np.int64))
    d = t.delta_snapshot(mark)
    np.testing.assert_array_equal(np.sort(d["ids"]), dirty)
    np.testing.assert_array_equal(d["deleted"], [10, 11])
    assert d["since"] == mark and d["version"] == t.version
    # full snapshot stays complete
    assert len(t.snapshot()["ids"]) == 99


def test_trim_evict_log_drops_covered_entries():
    t = SparseTable(1)
    t.scatter(np.arange(10, dtype=np.int64), np.zeros((10, 1), np.float32))
    t.evict(np.array([1], dtype=np.int64))
    mark = t.version
    t.evict(np.array([2], dtype=np.int64))
    t.trim_evict_log(mark)
    d = t.delta_snapshot(0)
    np.testing.assert_array_equal(d["deleted"], [2])    # entry 1 trimmed


def test_load_snapshot_preserves_touch_stats():
    """Recovered shards must keep eviction/collection stats (last_touch,
    touch_count) — the seed load path dropped them."""
    rng = np.random.default_rng(1)
    [src] = _shards(1)
    ids = np.arange(64, dtype=np.int64)
    for step in range(3):          # repeated pushes -> touch_count > 1
        src.push_grad("w", ids,
                      rng.normal(size=(64, GROUPS["w"])).astype(np.float32),
                      step=step)
    fresh = _shards(1)[0]
    fresh.load_snapshot(src.snapshot())
    _assert_state_equal(_sorted_state(src), _sorted_state(fresh))
    assert fresh.step == src.step
    assert _sorted_state(fresh)["touch_count"].max() > 1


# ---------------------------------------------------------------------------
# full+delta chain restore
# ---------------------------------------------------------------------------
def _chained_cluster(compress="none", rng=None):
    """3-shard cluster checkpointed as full -> delta -> delta (with an
    eviction in between) -> full; the last delta and the final full
    describe the SAME state."""
    rng = rng or np.random.default_rng(2)
    plan = RoutingPlan(3, 1, 1)
    shards = _shards(3)
    store = CheckpointStore()
    cb = ColdBackup(shards, store, BackupPolicy(incremental=True,
                                                compress=compress))
    _push(shards, plan, rng, step=0)
    v_full0 = cb.checkpoint(0.0, tier="remote")
    ids1 = _push(shards, plan, rng, step=1)
    cb.checkpoint(1.0, tier="local")
    # evict a slice of live rows on their owner shards (feature expiry)
    stale = ids1[:40]
    for sid, sids in plan.split_by_master(stale).items():
        shards[sid].delete_rows("w", sids)
    _push(shards, plan, rng, step=2)
    v_chain = cb.checkpoint(2.0, tier="local")
    v_full = cb.checkpoint(3.0, tier="remote")
    assert store.load(v_full0).kind == "full"
    assert store.load(v_chain).kind == "delta"
    assert store.load(v_full).kind == "full"
    return shards, cb, v_chain, v_full


@pytest.mark.parametrize("compress", ["none", "int8"])
def test_chain_restore_bit_equals_full_restore(compress):
    src, cb, v_chain, v_full = _chained_cluster(compress)
    a, b = _shards(3), _shards(3)
    assert cb.recover_all(a, version=v_chain) == v_chain
    assert cb.recover_all(b, version=v_full) == v_full
    for sa, sb in zip(a, b):
        _assert_state_equal(_sorted_state(sa), _sorted_state(sb))
        assert sa.step == sb.step
    if compress == "none":
        # uncompressed restore is bit-equal to the live source too
        for sa, ss in zip(a, src):
            _assert_state_equal(_sorted_state(sa), _sorted_state(ss))


def test_int8_compressed_restore_within_quant_error():
    src, cb, v_chain, _ = _chained_cluster("int8")
    rec = _shards(3)
    cb.recover_all(rec, version=v_chain)
    for s_src, s_rec in zip(src, rec):
        a, b = _sorted_state(s_src), _sorted_state(s_rec)
        np.testing.assert_array_equal(a["ids"], b["ids"])
        # row-wise absmax int8: error bound is absmax/127 per row
        for name in ("z", "n"):
            bound = np.abs(a["slots"][name]).max(axis=1, keepdims=True) \
                / 127.0 + 1e-7
            assert (np.abs(a["slots"][name] - b["slots"][name])
                    <= bound).all()


def test_delta_checkpoint_is_small_and_cheap():
    """The acceptance shape of BENCH_checkpoint_path.json, in miniature:
    at ~10% dirty rows a delta is >= 5x smaller than a full."""
    rng = np.random.default_rng(3)
    plan = RoutingPlan(2, 1, 1)
    shards = _shards(2)
    store = CheckpointStore()
    cb = ColdBackup(shards, store, BackupPolicy(incremental=True))
    ids = _push(shards, plan, rng, n=4096, step=0)
    v_full = cb.checkpoint(0.0, tier="remote")
    dirty = ids[:400]                                   # ~10% (ids sorted)
    grads = rng.normal(size=(len(dirty), GROUPS["w"])).astype(np.float32)
    for sid, sids in plan.split_by_master(dirty).items():
        shards[sid].push_grad("w", sids,
                              grads[np.searchsorted(dirty, sids)], step=1)
    v_delta = cb.checkpoint(1.0, tier="local")
    full_b = checkpoint_nbytes(store.load(v_full))
    delta_b = checkpoint_nbytes(store.load(v_delta))
    assert full_b >= 5 * delta_b, (full_b, delta_b)


def test_checkpoint_kind_cadence_and_rebase_after_recovery():
    shards = _shards(1)
    store = CheckpointStore()
    cb = ColdBackup(shards, store, BackupPolicy(incremental=True))
    v1 = cb.checkpoint(0.0, tier="local")
    v2 = cb.checkpoint(1.0, tier="local")
    v3 = cb.checkpoint(2.0, tier="remote")
    v4 = cb.checkpoint(3.0, tier="local")
    assert store.load(v1).kind == "full"                # nothing to chain on
    assert store.load(v2).kind == "delta"
    assert store.load(v2).base == v1
    assert store.load(v3).kind == "full"                # remote cadence
    assert store.load(v4).base == v3
    # recovery resets the mutation clocks -> next local must re-base
    cb.recover_shard(shards[0], version=v4)
    v5 = cb.checkpoint(4.0, tier="local")
    assert store.load(v5).kind == "full"
    v6 = cb.checkpoint(5.0, tier="local")
    assert store.load(v6).kind == "delta" and store.load(v6).base == v5


def test_dense_tensors_chain_through_deltas():
    opt = get_optimizer("ftrl")
    shard = MasterShard(0, GROUPS, opt)
    store = CheckpointStore()
    cb = ColdBackup([shard], store, BackupPolicy(incremental=True))
    shard.push_dense("mlp/w0", np.full((4, 2), 1.0, np.float32))
    shard.push_dense("mlp/b0", np.zeros((2,), np.float32))
    cb.checkpoint(0.0, tier="remote")
    shard.push_dense("mlp/w0", np.full((4, 2), 2.0, np.float32))
    v = cb.checkpoint(1.0, tier="local")
    delta = store.load(v)
    # the delta ships only the tensor that moved
    assert set(delta.shard_snaps[0]["dense"]["tensors"]) == {"mlp/w0"}
    fresh = MasterShard(0, GROUPS, opt)
    cb.recover_all([fresh], version=v)
    np.testing.assert_array_equal(fresh.dense.tensors["mlp/w0"],
                                  np.full((4, 2), 2.0, np.float32))
    np.testing.assert_array_equal(fresh.dense.tensors["mlp/b0"],
                                  np.zeros((2,), np.float32))


# ---------------------------------------------------------------------------
# reshard routing
# ---------------------------------------------------------------------------
def test_reshard_recovery_equals_direct_state():
    """N->M reshard through the argsort ownership router restores every
    row bit-equal to the source shard's state — values, slots, and touch
    stats — even from a delta chain tip."""
    src, cb, v_chain, _ = _chained_cluster()
    plan_dst = RoutingPlan(5, 1, 1)
    dst = _shards(5)
    cb.recover_all(dst, version=v_chain, owner_of=plan_dst.master_shard)
    # collect both sides id->row and compare
    def collect(shards):
        states = [_sorted_state(s) for s in shards]
        ids = np.concatenate([st["ids"] for st in states])
        order = np.argsort(ids)
        out = {"ids": ids[order]}
        for k in ("w", "last_touch", "touch_count"):
            out[k] = np.concatenate([st[k] for st in states],
                                    axis=0)[order]
        out["slots"] = {
            n: np.concatenate([st["slots"][n] for st in states],
                              axis=0)[order]
            for n in states[0]["slots"]}
        return out
    _assert_state_equal(collect(src), collect(dst))
    for sid, shard in enumerate(dst):
        ids = shard.tables["w"].all_ids()
        assert (plan_dst.master_shard(ids) == sid).all()


# ---------------------------------------------------------------------------
# retention
# ---------------------------------------------------------------------------
def test_retention_demotes_local_checkpoints_to_remote(tmp_path):
    shards = _shards(1)
    store = CheckpointStore(root=str(tmp_path), keep=2)
    cb = ColdBackup(shards, store, BackupPolicy(incremental=False))
    versions = [cb.checkpoint(float(i), tier="local") for i in range(5)]
    # nothing lost: evicted local checkpoints were demoted to files
    assert store.versions() == versions
    oldest = store.load(versions[0])
    assert oldest.version == versions[0] and oldest.tier == "remote"


def test_retention_drop_without_root_is_recorded():
    shards = _shards(1)
    store = CheckpointStore(keep=2)
    cb = ColdBackup(shards, store, BackupPolicy(incremental=False))
    versions = [cb.checkpoint(float(i), tier="local") for i in range(4)]
    assert store.versions() == versions[2:]
    assert store.dropped == versions[:2]
    with pytest.raises(KeyError):
        store.load(versions[0])


def test_retention_cascade_drops_orphaned_deltas():
    """Dropping a chain link must also drop the deltas that chained
    through it — versions() never lists an unmaterializable version."""
    from repro.core.fault_tolerance import Checkpoint
    store = CheckpointStore(keep=1)
    store.save(Checkpoint(version=1, created_at=0.0, shard_snaps={},
                          queue_offsets={}, num_shards=1, kind="full"))
    store.save(Checkpoint(version=2, created_at=1.0, shard_snaps={},
                          queue_offsets={}, num_shards=1, kind="delta",
                          base=1))
    assert store.versions() == []                       # both gone...
    assert store.dropped == [1, 2]                      # ...and recorded
    with pytest.raises(KeyError):
        store.load(2)


def test_incremental_default_config_stays_recoverable():
    """Regression: with no store root and the default retention window,
    long local-cadence runs must keep every *listed* version
    materializable — the chain re-bases on a full before retention
    could evict its own base."""
    rng = np.random.default_rng(7)
    plan = RoutingPlan(2, 1, 1)
    shards = _shards(2)
    store = CheckpointStore(keep=8)
    cb = ColdBackup(shards, store, BackupPolicy(incremental=True))
    _push(shards, plan, rng, n=256, step=0)
    for i in range(12):
        _push(shards, plan, rng, n=64, step=i + 1)
        cb.checkpoint(float(i), tier="local")
    assert store.versions()
    kinds = {store.load(v).kind for v in store.versions()}
    assert "delta" in kinds                             # still incremental
    for v in store.versions():
        cb.materialize(v)                               # must not raise
    rec = _shards(2)
    cb.recover_all(rec, version=store.latest())


# ---------------------------------------------------------------------------
# cluster-level: replica bootstrap + downgrade replay
# ---------------------------------------------------------------------------
def _cluster(**kw):
    defaults = dict(num_master=3, num_slave=2, num_replicas=2,
                    num_partitions=4, gather_mode="realtime",
                    local_ckpt_interval=1e9, remote_ckpt_interval=1e9)
    defaults.update(kw)
    return WeiPSCluster(LR_FTRL, ClusterConfig(**defaults))


def _run(cl, stream, steps, t0=0.0, dt=0.5):
    now = t0
    for _ in range(steps):
        ids, y = stream.batch(32)
        cl.train_on_batch(ids, y, now=now)
        cl.sync_tick(now)
        now += dt
    return now


def _master_serve_truth(cl, group="w"):
    """id -> serve weight derived straight from the master tables."""
    ids_l, serve_l = [], []
    for m in cl.masters:
        ids = m.tables[group].all_ids()
        if not len(ids):
            continue
        w, slots = m.tables[group].gather(ids)
        ids_l.append(ids)
        serve_l.append(cl.transform.serve_values(w, slots))
    ids = np.concatenate(ids_l)
    order = np.argsort(ids)
    return ids[order], np.concatenate(serve_l, axis=0)[order]


def test_replica_bootstrap_from_checkpoint_converges(monkeypatch):
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    now = _run(cl, stream, 8)
    cl.checkpoint(now)
    now = _run(cl, stream, 4, t0=now + 1)               # post-ckpt updates
    # the peer-copy fallback must NOT be taken when a checkpoint exists
    def no_peer_copy(self, other):
        raise AssertionError("bootstrap used peer full copy")
    monkeypatch.setattr(SlaveShard, "full_sync_from", no_peer_copy)
    fresh = cl.add_slave_replica(0)
    assert fresh in cl.replica_sets[0].replicas
    assert cl.scatters[-1].shard is fresh
    assert cl.scatters[-1].consumer.lag() == 0          # caught up
    # checkpoint-restore + streaming catch-up == peer's streamed state
    peer = cl.replica_sets[0].replicas[0]
    ids = peer.tables["w"].all_ids()
    np.testing.assert_allclose(fresh.lookup("w", ids),
                               peer.lookup("w", ids), rtol=1e-6, atol=1e-7)


def test_replica_bootstrap_peer_fallback_without_checkpoint():
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    _run(cl, stream, 5)                                 # no checkpoint taken
    fresh = cl.add_slave_replica(1)
    peer = cl.replica_sets[1].replicas[0]
    ids = peer.tables["w"].all_ids()
    if len(ids):
        np.testing.assert_allclose(fresh.lookup("w", ids),
                                   peer.lookup("w", ids), rtol=1e-6)


def test_downgrade_switch_replays_from_offsets_without_double_apply():
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    now = _run(cl, stream, 8)
    v = cl.checkpoint(now)
    ckpt_offsets = cl.store.load(v).queue_offsets
    now = _run(cl, stream, 5, t0=now + 1)               # post-ckpt stream
    cl.sync_tick(now)                                   # drain
    cl.downgrader.execute(now + 1, version=v)
    # switch seeked every consumer back to the checkpoint offsets
    for sc in cl.scatters:
        for p, off in sc.offsets().items():
            assert off == ckpt_offsets.get(p, 0)
    # replay: full-value records bring every replica back to the live
    # master state exactly once
    replayed = sum(sc.poll() for sc in cl.scatters)
    assert replayed > 0
    ids, serve = _master_serve_truth(cl)
    owner = cl.plan.slave_shard(ids)
    for sid, rs in enumerate(cl.replica_sets):
        mask = owner == sid
        for rep in rs.replicas:
            np.testing.assert_allclose(rep.lookup("w", ids[mask]),
                                       serve[mask], rtol=1e-6, atol=1e-7)
    # no double-apply: the stream is fully consumed, nothing re-applies
    assert all(sc.poll() == 0 for sc in cl.scatters)
    assert all(sc.consumer.lag() == 0 for sc in cl.scatters)
