"""Codec backend equivalence: the transform's numpy mirror vs the Pallas
``delta_codec`` kernel (interpret mode off-TPU) vs the pure-jnp oracle in
kernels/ref.py, plus the cache-blocked encode path. (Separate from
test_transform.py, which is skipped wholesale when hypothesis is absent.)
"""

import jax.numpy as jnp
import numpy as np

from repro.core import Int8Transform, make_transform
from repro.optim import FTRL


def test_int8_backends_match_ref_kernel():
    """Int8Transform's numpy and pallas backends both equal the pure-jnp
    oracle in kernels/ref.py (the pallas path runs the real delta_codec
    kernel in interpret mode off-TPU)."""
    from repro.kernels import ref
    w = (np.random.default_rng(7).normal(size=(33, 16)) * 10).astype(
        np.float32)
    enc_np = Int8Transform().encode(w, {})
    enc_pl = Int8Transform(backend="pallas").encode(w, {})
    q_ref, s_ref = ref.quantize_rows(jnp.asarray(w))
    for enc in (enc_np, enc_pl):
        np.testing.assert_array_equal(enc["q"], np.asarray(q_ref))
        np.testing.assert_allclose(enc["scale"], np.asarray(s_ref),
                                   rtol=1e-7)
    dec_np = Int8Transform.decode(enc_pl)
    dec_pl = Int8Transform.decode(enc_pl, backend="pallas")
    np.testing.assert_array_equal(dec_np, dec_pl)
    np.testing.assert_allclose(
        dec_np, np.asarray(ref.dequantize_rows(q_ref, s_ref)), rtol=1e-7)


def test_int8_pallas_kernel_used_with_optimizer(monkeypatch):
    """With an optimizer attached the pusher passes a (n, 0) w
    placeholder — the pallas path must still invoke the delta_codec
    kernel (guard is on row count, not w.size) and match numpy."""
    from repro.kernels import ops
    calls = []
    real = ops.quantize_rows
    monkeypatch.setattr(ops, "quantize_rows",
                        lambda v: calls.append(1) or real(v))
    rng = np.random.default_rng(5)
    slots = {"z": (rng.normal(size=(24, 8)) * 3).astype(np.float32),
             "n": (rng.uniform(size=(24, 8)) * 5).astype(np.float32)}
    w = np.empty((24, 0), np.float32)
    enc_pl = make_transform("int8", FTRL(), backend="pallas").encode(
        w, slots)
    assert calls, "delta_codec kernel path was not exercised"
    enc_np = make_transform("int8", FTRL()).encode(w, slots)
    np.testing.assert_array_equal(enc_pl["q"], enc_np["q"])
    np.testing.assert_allclose(enc_pl["scale"], enc_np["scale"], rtol=1e-7)


def test_kernel_less_codecs_stay_on_numpy_engine():
    """backend='pallas' must not regress codecs without a kernel to the
    eager-jnp serve path — only int8 takes the device path."""
    assert not make_transform("identity", FTRL(),
                              backend="pallas")._device_path
    assert not make_transform("cast16", FTRL(),
                              backend="pallas")._device_path
    assert make_transform("int8", FTRL(), backend="pallas")._device_path


def test_encode_blocking_matches_unblocked():
    """Cache-blocked encode tiles produce exactly the same payload as a
    single-block encode (row-wise codecs are block-invariant)."""
    from repro.core.transform import _ENCODE_BLOCK
    n = _ENCODE_BLOCK + 257                    # forces the tiled path
    rng = np.random.default_rng(11)
    w = np.zeros((n, 4), np.float32)
    slots = {"z": (rng.normal(size=(n, 4)) * 3).astype(np.float32),
             "n": (rng.uniform(size=(n, 4)) * 5).astype(np.float32)}
    for codec in ("identity", "cast16", "int8"):
        t = make_transform(codec, FTRL())
        blocked = t.encode(w, slots)
        single = t.encode(w[:1], {k: v[:1] for k, v in slots.items()})
        for key in blocked:
            np.testing.assert_array_equal(np.asarray(blocked[key])[:1],
                                          np.asarray(single[key]))
            assert np.asarray(blocked[key]).shape[0] == n
