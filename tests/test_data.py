"""Data pipeline: multi-stream sample joining semantics."""

import numpy as np

from repro.data import ClickStream, SampleJoiner
from repro.data.joiner import ExposureEvent, FeedbackEvent


def test_join_window_positive_and_negative():
    j = SampleJoiner(window=10.0)
    j.offer_exposure(ExposureEvent(t=0.0, view_id=1, feature_ids=(1, 2)))
    j.offer_exposure(ExposureEvent(t=0.0, view_id=2, feature_ids=(3, 4)))
    j.offer_feedback(FeedbackEvent(t=5.0, view_id=1))
    assert j.drain(now=9.0) == []                # window still open
    out = j.drain(now=10.0)
    labels = {s.view_id: s.label for s in out}
    assert labels == {1: 1.0, 2: 0.0}
    assert all(s.join_delay == 10.0 for s in out)


def test_late_feedback_counted_not_joined():
    j = SampleJoiner(window=5.0)
    j.offer_exposure(ExposureEvent(t=0.0, view_id=1, feature_ids=(1,)))
    out = j.drain(now=6.0)
    assert out[0].label == 0.0
    j.offer_feedback(FeedbackEvent(t=7.0, view_id=1))   # too late
    assert j.late_feedback == 1


def test_stream_joiner_end_to_end():
    """Longer windows catch more positives (the paper's timeliness vs.
    model-effect trade-off is monotone)."""
    def positives(window):
        stream = ClickStream(feature_space=1 << 10, fields=4,
                             feedback_delay=3.0, seed=0)
        j = SampleJoiner(window=window)
        t, pos, tot = 0.0, 0, 0
        pending_fb = []
        for step in range(60):
            ex, fb = stream.events(16, t)
            for e in ex:
                j.offer_exposure(e)
            pending_fb.extend(fb)
            pending_fb.sort(key=lambda f: f.t)
            while pending_fb and pending_fb[0].t <= t:
                j.offer_feedback(pending_fb.pop(0))
            for s in j.drain(t):
                pos += s.label > 0
                tot += 1
            t += 1.0
        return pos / max(tot, 1)

    assert positives(12.0) > positives(1.0)


def test_zipf_skew_supports_dedup_claim():
    """The Zipfian update stream has >=80 % repetition within a short
    window — the empirical basis of the paper's 90 % observation."""
    stream = ClickStream(feature_space=1 << 16, fields=16, zipf_a=1.2,
                         seed=0)
    seen, raw = set(), 0
    for _ in range(50):
        ids, _ = stream.batch(64)
        raw += ids.size
        seen.update(ids.reshape(-1).tolist())
    dedup = 1 - len(seen) / raw
    assert dedup > 0.75
