"""Docs integrity: every intra-repo file reference in the markdown docs
resolves. CI runs the same checker as a standalone step (see
.github/workflows/ci.yml, docs job)."""

import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ("docs/ARCHITECTURE.md", "docs/OBSERVABILITY.md", "README.md")


def test_architecture_doc_exists():
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()


def test_doc_refs_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_doc_refs.py"),
         *(str(ROOT / d) for d in DOCS)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
