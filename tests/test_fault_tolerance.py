"""Multi-level fault tolerance: cold backup (full/partial/resharded
recovery with queue-offset replay) and hot backup (replica failover,
bootstrap catch-up)."""

import numpy as np
import pytest

from repro.configs.weips_ctr import FM_FTRL, LR_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.core.fault_tolerance import (BackupPolicy, CheckpointStore,
                                        ColdBackup, ReplicaSet)
from repro.core.ps import MasterShard, SlaveShard
from repro.data import ClickStream


def _cluster(**kw):
    defaults = dict(num_master=3, num_slave=2, num_replicas=2,
                    num_partitions=4, gather_mode="realtime",
                    local_ckpt_interval=1.0, remote_ckpt_interval=50.0)
    defaults.update(kw)
    return WeiPSCluster(LR_FTRL, ClusterConfig(**defaults))


def _run(cl, stream, steps, t0=0.0, dt=0.5):
    for i in range(steps):
        ids, y = cl_batch(stream)
        now = t0 + i * dt
        cl.train_on_batch(ids, y, now=now)
        cl.sync_tick(now)
        cl.maybe_checkpoint(now)
    return t0 + steps * dt


def cl_batch(stream, n=32):
    return stream.batch(n)


def test_cold_backup_full_recovery():
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 12, fields=LR_FTRL.fields)
    now = _run(cl, stream, 12)
    v = cl.checkpoint(now)
    before = {g: t.snapshot() for m in cl.masters
              for g, t in m.tables.items() if m.shard_id == 0}
    # catastrophic loss of every master
    for m in cl.masters:
        m.kill()
        m.clear()
    cl.cold_backup.recover_all(cl.masters, version=v)
    after = cl.masters[0].tables["w"].snapshot()
    order_b = np.argsort(before["w"]["ids"])
    order_a = np.argsort(after["ids"])
    np.testing.assert_array_equal(before["w"]["ids"][order_b],
                                  after["ids"][order_a])
    np.testing.assert_allclose(before["w"]["w"][order_b],
                               after["w"][order_a], rtol=1e-6)


def test_partial_single_shard_recovery():
    """Only the crashed shard recovers; the others keep their live (newer)
    state — the cluster never restarts (paper §4.2.1e)."""
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 12, fields=LR_FTRL.fields)
    now = _run(cl, stream, 10)
    cl.checkpoint(now)
    live_other = cl.masters[1].tables["w"].snapshot()
    cl.kill_master(0)
    with pytest.raises(AssertionError):
        cl.masters[0].pull("w", np.array([1]))
    cl.recover_master(0)
    assert cl.masters[0].alive
    # shard 1 untouched by shard 0's recovery
    after_other = cl.masters[1].tables["w"].snapshot()
    np.testing.assert_array_equal(np.sort(live_other["ids"]),
                                  np.sort(after_other["ids"]))


def test_recovery_streams_missing_updates_to_slaves():
    """After recovery the replayed full-state push reconverges slaves."""
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    now = _run(cl, stream, 8)
    cl.checkpoint(now)
    now = _run(cl, stream, 4, t0=now + 1)     # updates after checkpoint
    cl.kill_master(0)
    cl.recover_master(0)
    cl.sync_tick(now + 10)
    # every slave row equals the (possibly rolled-back) master value
    m = cl.masters[0]
    ids = m.tables["w"].all_ids()
    if len(ids) == 0:
        return
    w, slots = m.tables["w"].gather(ids)
    serve = cl.transform.serve_values(w, slots)
    owner = cl.plan.slave_shard(ids)
    for sid, rs in enumerate(cl.replica_sets):
        mask = owner == sid
        if mask.any():
            got = rs.replicas[0].lookup("w", ids[mask])
            np.testing.assert_allclose(got, serve[mask], rtol=1e-5,
                                       atol=1e-6)


def test_reshard_recovery_10_to_20_style():
    """Dynamic routing on reload: checkpoint from 3 shards loads into 5
    (paper §4.2.1d migration example)."""
    opt_groups = {"w": 1}
    from repro.optim import get_optimizer
    opt = get_optimizer("sgd", lr=0.1)
    src = [MasterShard(i, opt_groups, opt) for i in range(3)]
    rng = np.random.default_rng(1)
    from repro.core import RoutingPlan
    plan_src = RoutingPlan(3, 1, 1)
    all_ids = rng.choice(1 << 20, size=200, replace=False).astype(np.int64)
    split = plan_src.split_by_master(all_ids)
    for sid, ids in split.items():
        src[sid].push_grad("w", ids, rng.normal(size=(len(ids), 1))
                           .astype(np.float32))
    store = CheckpointStore()
    cb = ColdBackup(src, store, BackupPolicy())
    v = cb.checkpoint(0.0)

    dst = [MasterShard(i, opt_groups, opt) for i in range(5)]
    plan_dst = RoutingPlan(5, 1, 1)
    cb.recover_all(dst, version=v, owner_of=plan_dst.master_shard)
    # every id lives on exactly its new owner, with identical values
    for sid, shard in enumerate(dst):
        ids = shard.tables["w"].all_ids()
        np.testing.assert_array_equal(plan_dst.master_shard(ids), sid)
    total = sum(len(s.tables["w"]) for s in dst)
    assert total == len(all_ids)


def test_hot_backup_failover_zero_downtime():
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    _run(cl, stream, 6)
    ids, _ = stream.batch(16)
    p_before = cl.predict(ids)
    cl.kill_slave_replica(0, 0)      # kill one replica of shard 0
    p_after = cl.predict(ids)        # must not raise
    np.testing.assert_allclose(p_before, p_after, rtol=1e-5)
    assert cl.replica_sets[0].failovers >= 0
    assert len(cl.replica_sets[0].healthy()) == 1


def test_all_replicas_down_raises():
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    _run(cl, stream, 3)
    cl.kill_slave_replica(0, 0)
    cl.kill_slave_replica(0, 1)
    ids = np.array([[1, 2, 3, 4] * 8])
    with pytest.raises(RuntimeError):
        cl.predict(ids % (1 << 10))


def test_replica_bootstrap_full_sync():
    cl = _cluster()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    _run(cl, stream, 6)
    rs = cl.replica_sets[0]
    fresh = SlaveShard(0, cl.groups)
    rs.add_replica(fresh)
    peer = rs.replicas[0]
    ids = peer.tables["w"].all_ids()
    if len(ids):
        np.testing.assert_allclose(fresh.lookup("w", ids),
                                   peer.lookup("w", ids), rtol=1e-6)
