"""FileQueue — the durable file-backed partition log under the
multi-process cluster runtime. In-process tier-1 coverage: cross-handle
visibility (separate FileQueue instances stand in for separate
processes), torn-tail tolerance + write-open repair, seek-past-tail
guards, and interface parity with the in-memory PartitionedQueue."""

import os
import pickle
import struct
import zlib

import numpy as np
import pytest

from repro.core.queue import Consumer, FileQueue, PartitionedQueue, Record


def rec(i, group="emb", seq=0, producer=0):
    return Record(group=group, op="upsert",
                  ids=np.array([i], np.int64),
                  payload={"values": np.full((1, 1), float(i), np.float32)},
                  seq=seq, producer=producer,
                  meta={"partition": 0, "t": float(i)})


def test_roundtrip_and_cross_handle_visibility(tmp_path):
    """Records produced through one handle are visible to a second handle
    over the same directory — the master/slave process split."""
    q1 = FileQueue(tmp_path / "q", num_partitions=2)
    for i in range(5):
        q1.produce(i % 2, rec(i, seq=i))
    q2 = FileQueue(tmp_path / "q")          # partition count from meta
    assert q2.num_partitions == 2
    recs, nxt = q2.consume(0, 0)
    assert nxt == 3
    assert [int(r.ids[0]) for r in recs] == [0, 2, 4]
    assert np.array_equal(recs[1].payload["values"],
                          np.full((1, 1), 2.0, np.float32))
    # q2 sees later appends from q1 by rescanning the tail
    q1.produce(0, rec(6, seq=6))
    recs, nxt = q2.consume(0, nxt)
    assert [int(r.ids[0]) for r in recs] == [6] and nxt == 4
    q1.close()
    q2.close()


def test_offsets_match_in_memory_queue(tmp_path):
    """Offset arithmetic (consume/latest_offset/Consumer) is identical to
    PartitionedQueue, so checkpointed Scatter offsets replay unchanged."""
    fq = FileQueue(tmp_path / "q", num_partitions=4)
    mq = PartitionedQueue(4)
    for i in range(10):
        p = i % 4
        fq.produce(p, rec(i, seq=i))
        mq.produce(p, rec(i, seq=i))
    assert fq.latest_offsets() == mq.latest_offsets()
    cf = Consumer(fq, [1, 3])
    cm = Consumer(mq, [1, 3])
    got_f = [int(r.ids[0]) for r in cf.poll()]
    got_m = [int(r.ids[0]) for r in cm.poll()]
    assert got_f == got_m
    assert cf.offsets == cm.offsets
    assert cf.lag() == cm.lag() == 0
    fq.close()


def test_torn_tail_is_invisible_until_repaired(tmp_path):
    """A half-written frame at the tail (producer SIGKILLed mid-append)
    reads as 'not yet produced'; the next write-open truncates it so new
    frames are never appended beyond an unreachable gap."""
    q = FileQueue(tmp_path / "q", num_partitions=1)
    q.produce(0, rec(1, seq=1))
    q.close()
    path = tmp_path / "q" / "part-00000.log"
    clean_size = os.path.getsize(path)
    body = pickle.dumps(rec(2, seq=2), protocol=4)
    with open(path, "ab") as f:                       # torn: half a frame
        f.write(struct.Struct("<II").pack(len(body), zlib.crc32(body)))
        f.write(body[: len(body) // 2])

    reader = FileQueue(tmp_path / "q")
    recs, nxt = reader.consume(0, 0)
    assert [int(r.ids[0]) for r in recs] == [1] and nxt == 1
    reader.close()

    writer = FileQueue(tmp_path / "q")                # repair on write-open
    body3 = pickle.dumps(rec(3, seq=3), protocol=4)
    writer.produce(0, rec(3, seq=3))
    # garbage truncated: file is exactly frame 1 + frame 3, no gap
    assert os.path.getsize(path) == clean_size + 8 + len(body3)
    recs, _ = writer.consume(0, 0)
    assert [int(r.ids[0]) for r in recs] == [1, 3]
    writer.close()


def test_corrupt_crc_stops_scan(tmp_path):
    q = FileQueue(tmp_path / "q", num_partitions=1)
    q.produce(0, rec(1))
    q.produce(0, rec(2))
    q.close()
    path = tmp_path / "q" / "part-00000.log"
    data = bytearray(open(path, "rb").read())
    data[-1] ^= 0xFF                                  # flip a byte of rec 2
    open(path, "wb").write(bytes(data))
    reader = FileQueue(tmp_path / "q")
    recs, nxt = reader.consume(0, 0)
    assert [int(r.ids[0]) for r in recs] == [1] and nxt == 1
    reader.close()


def test_seek_past_unseen_tail_never_rewinds(tmp_path):
    """A recovering replica seeks to checkpointed offsets that may lie
    beyond what its fresh handle has scanned; an empty consume must not
    drag the offset backwards."""
    prod = FileQueue(tmp_path / "q", num_partitions=1)
    cons = FileQueue(tmp_path / "q")
    recs, nxt = cons.consume(0, 5)                    # nothing there yet
    assert recs == [] and nxt == 5
    for i in range(7):
        prod.produce(0, rec(i, seq=i))
    recs, nxt = cons.consume(0, 5)                    # tail now visible
    assert [int(r.ids[0]) for r in recs] == [5, 6] and nxt == 7
    prod.close()
    cons.close()


def test_meta_partition_mismatch_rejected(tmp_path):
    FileQueue(tmp_path / "q", num_partitions=2).close()
    with pytest.raises(AssertionError):
        FileQueue(tmp_path / "q", num_partitions=4)
