"""Sample-equivalence property suite for the vectorized SampleJoiner.

Oracle: the seed per-event dict+heap joiner, kept verbatim below. The
vectorized joiner must emit the same samples — view ids, feature ids,
labels, join delays — in the same (deadline, view_id) order, with the
same late-feedback counts and in-flight sizes, under adversarial event
schedules: out-of-order feedback, duplicate view_ids (within a batch and
across offers, including re-offers after emission), feedback-after-emit,
and exact window-boundary expiry.

Seeded differential runs always execute; hypothesis drives the same
checker with minimized adversarial schedules when installed (dev extra).
"""

import heapq

import numpy as np
import pytest

from repro.data.joiner import ExposureEvent, FeedbackEvent, SampleJoiner

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    given = settings = st = None


# ---------------------------------------------------------------------------
# the seed joiner, verbatim (the oracle)
# ---------------------------------------------------------------------------
class SeedSampleJoiner:
    def __init__(self, window: float = 30.0):
        self.window = window
        self._pending: dict[int, ExposureEvent] = {}
        self._labels: dict[int, float] = {}
        self._expiry: list[tuple[float, int]] = []
        self.late_feedback = 0
        self.emitted = 0

    def offer_exposure(self, ev: ExposureEvent) -> None:
        self._pending[ev.view_id] = ev
        heapq.heappush(self._expiry, (ev.t + self.window, ev.view_id))

    def offer_feedback(self, ev: FeedbackEvent) -> None:
        if ev.view_id in self._pending:
            self._labels[ev.view_id] = ev.label
        else:
            self.late_feedback += 1

    def drain(self, now: float) -> list[tuple]:
        out = []
        while self._expiry and self._expiry[0][0] <= now:
            deadline, vid = heapq.heappop(self._expiry)
            ev = self._pending.pop(vid, None)
            if ev is None:
                continue
            label = self._labels.pop(vid, 0.0)
            out.append((vid, tuple(ev.feature_ids), label, now - ev.t))
            self.emitted += 1
        return out

    @property
    def in_flight(self) -> int:
        return len(self._pending)


# ---------------------------------------------------------------------------
# the differential checker
# ---------------------------------------------------------------------------
def run_schedule(ops, window: float, fields: int = 3):
    """Apply one op schedule to both joiners, asserting equivalence after
    every drain. Ops: ("expose", t_array, vids, feats),
    ("feedback", t, vids), ("drain", now)."""
    seed = SeedSampleJoiner(window=window)
    vec = SampleJoiner(window=window)
    for op in ops:
        if op[0] == "expose":
            _, ts, vids, feats = op
            for i in range(len(vids)):
                seed.offer_exposure(ExposureEvent(
                    t=float(ts[i]), view_id=int(vids[i]),
                    feature_ids=tuple(feats[i].tolist())))
            vec.offer_exposures(ts, vids, feats)
        elif op[0] == "feedback":
            _, t, vids = op
            for v in vids:
                seed.offer_feedback(FeedbackEvent(t=t, view_id=int(v)))
            vec.offer_feedbacks(t, vids)
        else:
            _, now = op
            want = seed.drain(now)
            got = vec.drain_batch(now)
            assert len(want) == len(got), (want, got)
            for k, (vid, feats, label, delay) in enumerate(want):
                assert int(got.view_ids[k]) == vid
                assert tuple(got.feature_ids[k].tolist()) == feats
                assert float(got.labels[k]) == label
                assert abs(float(got.join_delay[k]) - delay) <= \
                    1e-4 * max(1.0, abs(delay))      # f32 vs f64 delay
        assert seed.in_flight == vec.in_flight
        assert seed.late_feedback == vec.late_feedback
    # terminal drain: every remaining sample, same totals
    final = ops[-1][1] if ops and ops[-1][0] == "drain" else 0.0
    want = seed.drain(final + 10 * window + 100)
    got = vec.drain_batch(final + 10 * window + 100)
    assert len(want) == len(got)
    assert seed.emitted == vec.emitted


def random_schedule(rng, *, n_ops=120, vid_space=25, fields=3,
                    max_batch=6, window=5.0):
    """Adversarial mix: tiny vid space → constant duplicate collisions;
    drains jump forward AND land exactly on window boundaries."""
    ops, t = [], 0.0
    deadlines = []
    for _ in range(n_ops):
        kind = rng.choice(["expose", "expose", "feedback", "drain"])
        if kind == "expose":
            n = int(rng.integers(1, max_batch))
            vids = rng.integers(0, vid_space, size=n)
            feats = rng.integers(0, 50, size=(n, fields))
            ts = t + rng.random(n) * 2          # out-of-order event times
            deadlines.extend((ts + window).tolist())
            ops.append(("expose", ts, vids, feats))
        elif kind == "feedback":
            n = int(rng.integers(1, 4))
            # feedback may target never-seen vids (late) and duplicates
            ops.append(("feedback", t,
                        rng.integers(0, vid_space + 5, size=n)))
        else:
            if deadlines and rng.random() < 0.4:
                # exact window-boundary expiry: drain AT a deadline
                t = max(t, float(rng.choice(deadlines)))
            else:
                t += rng.random() * 2 * window
            ops.append(("drain", t))
    ops.append(("drain", t + window * 3))
    return ops


@pytest.mark.parametrize("seed", range(25))
def test_random_schedules_match_seed_joiner(seed):
    rng = np.random.default_rng(seed)
    run_schedule(random_schedule(rng), window=5.0)


def test_feedback_after_emit_is_late():
    ops = [
        ("expose", np.array([0.0]), np.array([7]),
         np.array([[1, 2, 3]])),
        ("drain", 5.0),                      # boundary: deadline == now
        ("feedback", 5.5, np.array([7])),    # after emit → late
        ("feedback", 5.5, np.array([99])),   # never seen → late
    ]
    run_schedule(ops, window=5.0)


def test_duplicate_reoffer_after_emit_uses_stale_entry():
    """The seed heap keeps an old offer's expiry entry alive across an
    emission; a re-offered view can therefore emit at the stale entry's
    deadline. The vectorized joiner reproduces it (checked by oracle)."""
    ops = [
        ("expose", np.array([0.0]), np.array([1]), np.array([[1, 1, 1]])),
        ("expose", np.array([10.0]), np.array([1]), np.array([[2, 2, 2]])),
        ("drain", 5.0),                      # emits gen-1 (features gen-2!)
        ("expose", np.array([20.0]), np.array([1]), np.array([[3, 3, 3]])),
        ("drain", 16.0),                     # stale entry (t=10+5) fires
        ("drain", 40.0),
    ]
    run_schedule(ops, window=5.0)


def test_in_batch_duplicates_last_wins():
    ops = [
        ("expose", np.array([0.0, 0.5, 1.0]), np.array([4, 4, 4]),
         np.array([[1, 1, 1], [2, 2, 2], [3, 3, 3]])),
        ("feedback", 1.5, np.array([4, 4])),
        ("drain", 5.0),
        ("drain", 10.0),
    ]
    run_schedule(ops, window=4.0)


def test_emit_on_feedback_fast_path():
    """Positives emit the moment feedback arrives; negatives wait the
    window; a second feedback for an emitted view counts late."""
    j = SampleJoiner(window=10.0, emit_on_feedback=True)
    vids = np.arange(6, dtype=np.int64)
    j.offer_exposures(0.0, vids, np.tile(np.arange(3), (6, 1)))
    fast = j.offer_feedbacks(2.0, np.array([1, 3]))
    assert fast is not None and len(fast) == 2
    assert (fast.labels == 1.0).all()
    np.testing.assert_allclose(fast.join_delay, 2.0)
    assert j.fast_emits == 2
    assert j.offer_feedbacks(3.0, np.array([1])) is None   # already emitted
    assert j.late_feedback == 1
    rest = j.drain_batch(10.0)
    assert len(rest) == 4 and (rest.labels == 0.0).all()
    assert j.in_flight == 0


def test_joiner_metrics_counters():
    j = SampleJoiner(window=1.0)
    j.offer_exposures(0.0, np.arange(10, dtype=np.int64),
                      np.zeros((10, 2), np.int64))
    j.offer_feedbacks(0.5, np.array([3, 99]))
    out = j.drain_batch(1.0)
    m = j.metrics()
    assert m["emitted"] == len(out) == 10
    assert m["late_feedback"] == 1
    assert m["in_flight"] == 0
    assert m["join_delay"]["p50"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# hypothesis-driven schedules (dev extra)
# ---------------------------------------------------------------------------
if st is not None:
    @st.composite
    def schedules(draw):
        n = draw(st.integers(5, 40))
        ops, t = [], 0.0
        for _ in range(n):
            kind = draw(st.sampled_from(
                ["expose", "expose", "feedback", "drain"]))
            if kind == "expose":
                k = draw(st.integers(1, 4))
                vids = np.array(
                    [draw(st.integers(0, 12)) for _ in range(k)], np.int64)
                feats = np.array(
                    [[draw(st.integers(0, 9)) for _ in range(2)]
                     for _ in range(k)], np.int64)
                ts = np.array(
                    [t + draw(st.floats(0, 3, allow_nan=False))
                     for _ in range(k)])
                ops.append(("expose", ts, vids, feats))
            elif kind == "feedback":
                k = draw(st.integers(1, 3))
                ops.append(("feedback", t, np.array(
                    [draw(st.integers(0, 15)) for _ in range(k)],
                    np.int64)))
            else:
                t += draw(st.floats(0, 8, allow_nan=False))
                ops.append(("drain", t))
        return ops

    @given(ops=schedules())
    @settings(max_examples=60, deadline=None)
    def test_hypothesis_schedules_match_seed_joiner(ops):
        run_schedule(ops, window=4.0)
