"""Per-kernel shape/dtype sweeps vs. the pure-jnp oracles in ref.py
(assignment requirement). Kernels run in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                       # hypothesis is a dev extra; the container may
    from hypothesis import given, settings        # not have it — fall back
    from hypothesis import strategies as st       # to fixed examples.
except ModuleNotFoundError:
    given = settings = st = None

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("v,d,n", [(32, 128, 8), (257, 256, 33),
                                   (64, 384, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_lookup_sweep(v, d, n, dtype):
    table = jax.random.normal(KEY, (v, d), dtype=jnp.float32).astype(dtype)
    ids = jax.random.randint(jax.random.fold_in(KEY, 1), (n,), 0, v)
    got = ops.embedding_lookup(table, ids)
    want = ref.embedding_lookup(table, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("v,d,n", [(64, 128, 16), (128, 256, 64)])
def test_embedding_scatter_add_sweep(v, d, n):
    table = jax.random.normal(KEY, (v, d))
    ids = jax.random.randint(jax.random.fold_in(KEY, 2), (n,), 0, v)
    upd = jax.random.normal(jax.random.fold_in(KEY, 3), (n, d))
    got = ops.embedding_scatter_add(table, ids, upd)
    want = ref.embedding_scatter_add(table, ids, upd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_embedding_scatter_add_heavy_duplicates():
    table = jnp.zeros((8, 128))
    ids = jnp.zeros((64,), jnp.int32)           # all hit row 0
    upd = jnp.ones((64, 128))
    got = ops.embedding_scatter_add(table, ids, upd)
    np.testing.assert_allclose(got[0], np.full(128, 64.0), rtol=1e-6)
    np.testing.assert_allclose(got[1:], np.zeros((7, 128)))


@pytest.mark.parametrize("b,d", [(8, 128), (300, 256), (1, 512)])
@pytest.mark.parametrize("params", [
    dict(alpha=0.05, beta=1.0, l1=1.0, l2=1.0),
    dict(alpha=0.1, beta=0.5, l1=0.0, l2=0.1),
])
def test_ftrl_sweep(b, d, params):
    ks = jax.random.split(jax.random.fold_in(KEY, b * d), 3)
    z = jax.random.normal(ks[0], (b, d)) * 2
    n = jax.random.uniform(ks[1], (b, d)) * 4
    g = jax.random.normal(ks[2], (b, d))
    got = ops.ftrl_row_update(z, n, g, **params)
    want = ref.ftrl_row_update(z, n, g, **params)
    for a, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,d", [(4, 128), (100, 256), (1, 1024)])
def test_codec_sweep(b, d):
    x = jax.random.normal(jax.random.fold_in(KEY, b + d), (b, d)) * 10
    q, s = ops.quantize_rows(x)
    qr, sr = ref.quantize_rows(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    got = ops.dequantize_rows(q, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x),
                               atol=float(np.abs(x).max()) / 120)


def _scale_cases(fn):
    if st is not None:
        return settings(max_examples=30, deadline=None)(
            given(st.floats(-1e4, 1e4, width=32))(fn))
    return pytest.mark.parametrize(
        "scale", [0.0, 1.0, -3.5, 127.0, -511.25, 1e4])(fn)


@_scale_cases
def test_codec_roundtrip_error_property(scale):
    x = jnp.asarray(np.linspace(-abs(scale) - 1, abs(scale) + 1, 256,
                                dtype=np.float32)).reshape(1, 256)
    q, s = ops.quantize_rows(x)
    back = ops.dequantize_rows(q, s)
    step = float(np.abs(x).max()) / 127.0
    assert float(np.abs(np.asarray(back) - np.asarray(x)).max()) <= \
        step / 2 + 1e-5


@pytest.mark.parametrize("b,h,g,s,d", [
    (1, 4, 2, 128, 128),       # GQA 2:1
    (2, 4, 4, 256, 128),       # MHA
    (1, 8, 1, 128, 256),       # MQA, bigger head
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, g, s, d, causal, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, b * h * s), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, g, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, g, s, d), jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,g,s,d,block", [
    (2, 8, 2, 1024, 128, 512),
    (1, 4, 4, 512, 128, 128),
    (3, 2, 1, 2048, 256, 512),
])
def test_decode_attention_sweep(b, h, g, s, d, block):
    ks = jax.random.split(jax.random.fold_in(KEY, b * h + s), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, g, d))
    v = jax.random.normal(ks[2], (b, s, g, d))
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    got = ops.decode_attention(q, k, v, lengths, block_k=block)
    want = ref.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_short_lengths():
    """Valid-length masking: only the first `len` cache slots count."""
    b, h, g, s, d = 1, 2, 1, 512, 128
    q = jax.random.normal(KEY, (b, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, g, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, g, d))
    # poison the tail: results must not change
    k_poison = k.at[:, 10:].set(1e6)
    v_poison = v.at[:, 10:].set(1e6)
    lengths = jnp.array([10], jnp.int32)
    a = ops.decode_attention(q, k, v, lengths)
    bb = ops.decode_attention(q, k_poison, v_poison, lengths)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-6)


@pytest.mark.parametrize("v,d,n", [(64, 128, 16), (128, 256, 64)])
def test_embedding_scatter_sweep(v, d, n):
    """Set-scatter (unique ids contract): rows named by ids are replaced,
    every other row passes through the input/output alias untouched."""
    table = jax.random.normal(KEY, (v, d))
    ids = jax.random.permutation(jax.random.fold_in(KEY, 4),
                                 jnp.arange(v))[:n]
    upd = jax.random.normal(jax.random.fold_in(KEY, 5), (n, d))
    got = ops.embedding_scatter(table, ids.astype(jnp.int32), upd)
    want = ref.embedding_scatter(table, ids, upd)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _probe_case(cap_pow, n_ids, n_del, seed):
    """Build a host map with live keys, tombstones, and a grown capacity;
    return it plus a probe batch mixing hits / misses / deleted ids /
    sentinel-valued queries."""
    from repro.core.hashmap import EMPTY, TOMB, IdHashMap
    rng = np.random.default_rng(seed)
    m = IdHashMap(16)                      # grows through every boundary
    ids = rng.choice(1 << 40, size=n_ids, replace=False).astype(np.int64)
    m.put(ids, np.arange(n_ids))
    dele = ids[:n_del]
    if n_del:
        m.delete(dele)
    assert m.capacity == 1 << cap_pow      # the size the sweep intends
    absent = rng.choice(1 << 40, size=64, replace=False).astype(np.int64)
    absent = absent[~np.isin(absent, ids)]
    qs = np.concatenate([
        ids[n_del:], dele, absent,
        np.array([int(EMPTY), int(TOMB), 0, -1], np.int64)])
    return m, qs


@pytest.mark.parametrize("cap_pow,n_ids,n_del", [
    (8, 60, 10),           # one windowed-tail round typical
    (12, 1000, 200),       # grown map, heavier tombstone load
    (14, 4000, 0),         # capacity boundary: exactly at 25% load trigger
])
def test_hashmap_probe_matches_host_map(cap_pow, n_ids, n_del):
    """Device probe (uint32-limb Fibonacci hash, windowed while_loop) is
    bit-equal to ``IdHashMap._probe`` on its own key table: same found
    mask, same position wherever found. Misses, tombstoned ids, and the
    two reserved sentinel values all resolve identically."""
    m, qs = _probe_case(cap_pow, n_ids, n_del, seed=cap_pow)
    host_pos, host_found = m._probe(qs)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    pos, found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                   shift=int(m.shift))
    pos, found = np.asarray(pos), np.asarray(found)
    np.testing.assert_array_equal(found, host_found)
    np.testing.assert_array_equal(pos[found], host_pos[host_found])
    # found positions hold exactly the queried ids
    np.testing.assert_array_equal(m.key_table[pos[found]], qs[found])


@pytest.mark.parametrize("cap_pow,n_ids,n_del", [(8, 60, 10),
                                                 (12, 1000, 200)])
def test_hashmap_probe_ref_oracle_matches_kernel(cap_pow, n_ids, n_del):
    """The brute-force ref oracle (full circular probe order, window-index
    binning) and the Pallas kernel agree everywhere — including the pos
    column at found rows (pos is unspecified where found is False)."""
    m, qs = _probe_case(cap_pow, n_ids, n_del, seed=100 + cap_pow)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    got_pos, got_found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                           shift=int(m.shift))
    ref_pos, ref_found = ref.hashmap_probe(klo, khi, qlo, qhi,
                                           shift=int(m.shift))
    got_found, ref_found = np.asarray(got_found), np.asarray(ref_found)
    np.testing.assert_array_equal(got_found, ref_found)
    np.testing.assert_array_equal(np.asarray(got_pos)[got_found],
                                  np.asarray(ref_pos)[ref_found])


def test_public_kernel_entrypoints_documented():
    """Every public symbol in the kernel modules carries a docstring that
    states its contract (KERNELS.md companion check)."""
    import inspect

    from repro.kernels import (delta_codec, embedding_lookup,
                               ftrl_row_update, hashmap_probe)
    for mod in (delta_codec, embedding_lookup, ftrl_row_update,
                hashmap_probe, ops, ref):
        assert (mod.__doc__ or "").strip(), mod.__name__
        for name, fn in vars(mod).items():
            if name.startswith("_") or not inspect.isfunction(fn):
                continue
            if fn.__module__ != mod.__name__:
                continue                    # re-exported helpers
            doc = (inspect.getdoc(fn) or "").strip()
            assert len(doc) >= 20, f"{mod.__name__}.{name} undocumented"


# -- HBM-resident probe: windowed DMA + double-buffered VMEM scratch --------
# The VMEM kernel streams the whole key table through BlockSpecs, which
# caps map capacity at VMEM_SLOT_BOUND. The HBM variant keeps the limbs
# in `pltpu.ANY` and DMAs fixed probe windows into scratch — these tests
# pin it bit-equal to the host map and the ref oracle across capacity
# edges, tombstone walks, grown maps, and probe chains that cross DMA
# window boundaries (forced via tiny windows + crafted hash collisions).

@pytest.mark.parametrize("cap_pow,n_ids,n_del", [
    (4, 3, 1),             # capacity edge: cap 16 << DMA window (wrap pad)
    (8, 60, 10),           # one windowed-tail round typical
    (12, 1000, 200),       # grown map, heavier tombstone load
    (14, 4000, 0),         # capacity boundary: exactly at 25% load trigger
])
def test_hashmap_probe_hbm_matches_host_map(cap_pow, n_ids, n_del):
    """Forced ``placement="hbm"`` probe is bit-equal to ``IdHashMap._probe``
    on the same table — found mask, positions, sentinels, tombstones —
    even when the map is far smaller than one DMA window (wrap pad)."""
    m, qs = _probe_case(cap_pow, n_ids, n_del, seed=7 + cap_pow)
    host_pos, host_found = m._probe(qs)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    pos, found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                   shift=int(m.shift), placement="hbm")
    pos, found = np.asarray(pos), np.asarray(found)
    np.testing.assert_array_equal(found, host_found)
    np.testing.assert_array_equal(pos[found], host_pos[host_found])
    np.testing.assert_array_equal(m.key_table[pos[found]], qs[found])


@pytest.mark.parametrize("cap_pow,n_ids,n_del", [(8, 60, 10),
                                                 (12, 1000, 200)])
def test_hashmap_probe_hbm_matches_vmem_and_ref(cap_pow, n_ids, n_del):
    """Triple agreement: HBM windowed-DMA kernel == VMEM streaming kernel
    == brute-force ref oracle, including pos at found rows."""
    m, qs = _probe_case(cap_pow, n_ids, n_del, seed=300 + cap_pow)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    h_pos, h_found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                       shift=int(m.shift), placement="hbm")
    v_pos, v_found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                       shift=int(m.shift), placement="vmem")
    r_pos, r_found = ref.hashmap_probe(klo, khi, qlo, qhi,
                                       shift=int(m.shift))
    h_found = np.asarray(h_found)
    np.testing.assert_array_equal(h_found, np.asarray(v_found))
    np.testing.assert_array_equal(h_found, np.asarray(r_found))
    np.testing.assert_array_equal(np.asarray(h_pos)[h_found],
                                  np.asarray(v_pos)[h_found])
    np.testing.assert_array_equal(np.asarray(h_pos)[h_found],
                                  np.asarray(r_pos)[h_found])


@pytest.mark.parametrize("window,chunk", [(16, 8), (32, 4)])
def test_hashmap_probe_hbm_window_boundary_chains(window, chunk):
    """Probe chains LONGER than one DMA window: ids crafted to share a
    home-slot neighbourhood pile into one collision cluster, so resolving
    them needs continuation passes (window i exhausted → DMA window i+1).
    Tiny windows make every cluster cross a boundary; still bit-equal."""
    from repro.core.hashmap import IdHashMap, home_slots
    from repro.kernels.hashmap_probe import hashmap_probe_hbm
    rng = np.random.default_rng(5)
    m = IdHashMap(1024)
    cand = rng.choice(1 << 40, size=200_000, replace=False).astype(np.int64)
    homes = home_slots(cand, m.shift)
    cluster = cand[(homes >= 100) & (homes < 104)][:48]   # one long chain
    assert len(cluster) >= 40
    spread = cand[homes % 7 == 0][:120]
    ids = np.unique(np.concatenate([cluster, spread]))
    m.put(ids, np.arange(len(ids)))
    assert m.capacity == 1024                  # load stays under 25%
    absent = cand[~np.isin(cand, ids)][:64]
    qs = np.concatenate([cluster, absent])
    host_pos, host_found = m._probe(qs)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    pos, found = hashmap_probe_hbm(klo, khi, qlo, qhi, shift=int(m.shift),
                                   interpret=True, window=window,
                                   chunk=chunk)
    pos, found = np.asarray(pos), np.asarray(found)
    np.testing.assert_array_equal(found, host_found)
    np.testing.assert_array_equal(pos[found], host_pos[host_found])


def test_hashmap_probe_hbm_past_vmem_bound():
    """A 4M-slot map — past VMEM_SLOT_BOUND, where auto placement flips to
    "hbm" and the old streaming kernel could not run at all. Lookup via
    the public auto path stays bit-equal to the host map."""
    from repro.core.hashmap import IdHashMap
    from repro.kernels.hashmap_probe import VMEM_SLOT_BOUND
    rng = np.random.default_rng(9)
    m = IdHashMap(1 << 22)
    assert m.capacity > VMEM_SLOT_BOUND
    ids = np.unique(rng.integers(1, 1 << 62, size=4096).astype(np.int64))
    m.put(ids, np.arange(len(ids)))
    m.delete(ids[::5])
    qs = np.concatenate([ids, ids[::5],
                         rng.integers(1 << 62, (1 << 63) - 1,
                                      size=256).astype(np.int64)])
    host_pos, host_found = m._probe(qs)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    pos, found = ops.hashmap_probe(klo, khi, qlo, qhi, shift=int(m.shift))
    pos, found = np.asarray(pos), np.asarray(found)
    np.testing.assert_array_equal(found, host_found)
    np.testing.assert_array_equal(pos[found], host_pos[host_found])


def test_fused_lookup_found_mask_and_slots():
    """``fused_lookup``'s third output: arena slots at found rows (the
    LRU-touch signal ``ServeCache.lookup_device`` consumes) and 0 at
    misses; rows at misses are zeros; mask matches the host map."""
    from repro.core.ps import SparseTable
    rng = np.random.default_rng(3)
    st = SparseTable(8, ("n", "z"), backend="pallas")
    ids = np.unique(rng.integers(1, 1 << 40, size=512).astype(np.int64))
    st.ensure(ids)
    absent = rng.integers(1 << 41, 1 << 42, size=64).astype(np.int64)
    qs = np.concatenate([ids[:128], absent])
    rows, found, slot = st.lookup_device(qs)
    rows = np.asarray(rows)
    assert found[:128].all() and not found[128:].any()
    np.testing.assert_array_equal(slot[found], st.lookup(qs)[found])
    assert (slot[~found] == 0).all()
    np.testing.assert_array_equal(rows[~found], 0.0)
    np.testing.assert_array_equal(rows[found],
                                  st._w[st.lookup(qs)[found]])


@pytest.mark.tpu
def test_hashmap_probe_hbm_mosaic_smoke():
    """On real hardware the same kernel lowers through Mosaic (no
    interpret): DMA window prefetch, semaphores and all. Auto-skipped
    off-TPU by conftest."""
    from repro.core.hashmap import IdHashMap
    rng = np.random.default_rng(1)
    m = IdHashMap(1 << 12)
    ids = rng.choice(1 << 40, size=600, replace=False).astype(np.int64)
    m.put(ids, np.arange(len(ids)))
    qs = np.concatenate([ids, ids + 1])
    host_pos, host_found = m._probe(qs)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    pos, found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                   shift=int(m.shift), placement="hbm")
    pos, found = np.asarray(pos), np.asarray(found)
    np.testing.assert_array_equal(found, host_found)
    np.testing.assert_array_equal(pos[found], host_pos[host_found])
