"""Per-kernel shape/dtype sweeps vs. the pure-jnp oracles in ref.py
(assignment requirement). Kernels run in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                       # hypothesis is a dev extra; the container may
    from hypothesis import given, settings        # not have it — fall back
    from hypothesis import strategies as st       # to fixed examples.
except ModuleNotFoundError:
    given = settings = st = None

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("v,d,n", [(32, 128, 8), (257, 256, 33),
                                   (64, 384, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_lookup_sweep(v, d, n, dtype):
    table = jax.random.normal(KEY, (v, d), dtype=jnp.float32).astype(dtype)
    ids = jax.random.randint(jax.random.fold_in(KEY, 1), (n,), 0, v)
    got = ops.embedding_lookup(table, ids)
    want = ref.embedding_lookup(table, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("v,d,n", [(64, 128, 16), (128, 256, 64)])
def test_embedding_scatter_add_sweep(v, d, n):
    table = jax.random.normal(KEY, (v, d))
    ids = jax.random.randint(jax.random.fold_in(KEY, 2), (n,), 0, v)
    upd = jax.random.normal(jax.random.fold_in(KEY, 3), (n, d))
    got = ops.embedding_scatter_add(table, ids, upd)
    want = ref.embedding_scatter_add(table, ids, upd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_embedding_scatter_add_heavy_duplicates():
    table = jnp.zeros((8, 128))
    ids = jnp.zeros((64,), jnp.int32)           # all hit row 0
    upd = jnp.ones((64, 128))
    got = ops.embedding_scatter_add(table, ids, upd)
    np.testing.assert_allclose(got[0], np.full(128, 64.0), rtol=1e-6)
    np.testing.assert_allclose(got[1:], np.zeros((7, 128)))


@pytest.mark.parametrize("b,d", [(8, 128), (300, 256), (1, 512)])
@pytest.mark.parametrize("params", [
    dict(alpha=0.05, beta=1.0, l1=1.0, l2=1.0),
    dict(alpha=0.1, beta=0.5, l1=0.0, l2=0.1),
])
def test_ftrl_sweep(b, d, params):
    ks = jax.random.split(jax.random.fold_in(KEY, b * d), 3)
    z = jax.random.normal(ks[0], (b, d)) * 2
    n = jax.random.uniform(ks[1], (b, d)) * 4
    g = jax.random.normal(ks[2], (b, d))
    got = ops.ftrl_row_update(z, n, g, **params)
    want = ref.ftrl_row_update(z, n, g, **params)
    for a, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,d", [(4, 128), (100, 256), (1, 1024)])
def test_codec_sweep(b, d):
    x = jax.random.normal(jax.random.fold_in(KEY, b + d), (b, d)) * 10
    q, s = ops.quantize_rows(x)
    qr, sr = ref.quantize_rows(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    got = ops.dequantize_rows(q, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x),
                               atol=float(np.abs(x).max()) / 120)


def _scale_cases(fn):
    if st is not None:
        return settings(max_examples=30, deadline=None)(
            given(st.floats(-1e4, 1e4, width=32))(fn))
    return pytest.mark.parametrize(
        "scale", [0.0, 1.0, -3.5, 127.0, -511.25, 1e4])(fn)


@_scale_cases
def test_codec_roundtrip_error_property(scale):
    x = jnp.asarray(np.linspace(-abs(scale) - 1, abs(scale) + 1, 256,
                                dtype=np.float32)).reshape(1, 256)
    q, s = ops.quantize_rows(x)
    back = ops.dequantize_rows(q, s)
    step = float(np.abs(x).max()) / 127.0
    assert float(np.abs(np.asarray(back) - np.asarray(x)).max()) <= \
        step / 2 + 1e-5


@pytest.mark.parametrize("b,h,g,s,d", [
    (1, 4, 2, 128, 128),       # GQA 2:1
    (2, 4, 4, 256, 128),       # MHA
    (1, 8, 1, 128, 256),       # MQA, bigger head
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, g, s, d, causal, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, b * h * s), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, g, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, g, s, d), jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,g,s,d,block", [
    (2, 8, 2, 1024, 128, 512),
    (1, 4, 4, 512, 128, 128),
    (3, 2, 1, 2048, 256, 512),
])
def test_decode_attention_sweep(b, h, g, s, d, block):
    ks = jax.random.split(jax.random.fold_in(KEY, b * h + s), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, g, d))
    v = jax.random.normal(ks[2], (b, s, g, d))
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    got = ops.decode_attention(q, k, v, lengths, block_k=block)
    want = ref.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_short_lengths():
    """Valid-length masking: only the first `len` cache slots count."""
    b, h, g, s, d = 1, 2, 1, 512, 128
    q = jax.random.normal(KEY, (b, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, g, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, g, d))
    # poison the tail: results must not change
    k_poison = k.at[:, 10:].set(1e6)
    v_poison = v.at[:, 10:].set(1e6)
    lengths = jnp.array([10], jnp.int32)
    a = ops.decode_attention(q, k, v, lengths)
    bb = ops.decode_attention(q, k_poison, v_poison, lengths)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-6)


@pytest.mark.parametrize("v,d,n", [(64, 128, 16), (128, 256, 64)])
def test_embedding_scatter_sweep(v, d, n):
    """Set-scatter (unique ids contract): rows named by ids are replaced,
    every other row passes through the input/output alias untouched."""
    table = jax.random.normal(KEY, (v, d))
    ids = jax.random.permutation(jax.random.fold_in(KEY, 4),
                                 jnp.arange(v))[:n]
    upd = jax.random.normal(jax.random.fold_in(KEY, 5), (n, d))
    got = ops.embedding_scatter(table, ids.astype(jnp.int32), upd)
    want = ref.embedding_scatter(table, ids, upd)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _probe_case(cap_pow, n_ids, n_del, seed):
    """Build a host map with live keys, tombstones, and a grown capacity;
    return it plus a probe batch mixing hits / misses / deleted ids /
    sentinel-valued queries."""
    from repro.core.hashmap import EMPTY, TOMB, IdHashMap
    rng = np.random.default_rng(seed)
    m = IdHashMap(16)                      # grows through every boundary
    ids = rng.choice(1 << 40, size=n_ids, replace=False).astype(np.int64)
    m.put(ids, np.arange(n_ids))
    dele = ids[:n_del]
    if n_del:
        m.delete(dele)
    assert m.capacity == 1 << cap_pow      # the size the sweep intends
    absent = rng.choice(1 << 40, size=64, replace=False).astype(np.int64)
    absent = absent[~np.isin(absent, ids)]
    qs = np.concatenate([
        ids[n_del:], dele, absent,
        np.array([int(EMPTY), int(TOMB), 0, -1], np.int64)])
    return m, qs


@pytest.mark.parametrize("cap_pow,n_ids,n_del", [
    (8, 60, 10),           # one windowed-tail round typical
    (12, 1000, 200),       # grown map, heavier tombstone load
    (14, 4000, 0),         # capacity boundary: exactly at 25% load trigger
])
def test_hashmap_probe_matches_host_map(cap_pow, n_ids, n_del):
    """Device probe (uint32-limb Fibonacci hash, windowed while_loop) is
    bit-equal to ``IdHashMap._probe`` on its own key table: same found
    mask, same position wherever found. Misses, tombstoned ids, and the
    two reserved sentinel values all resolve identically."""
    m, qs = _probe_case(cap_pow, n_ids, n_del, seed=cap_pow)
    host_pos, host_found = m._probe(qs)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    pos, found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                   shift=int(m.shift))
    pos, found = np.asarray(pos), np.asarray(found)
    np.testing.assert_array_equal(found, host_found)
    np.testing.assert_array_equal(pos[found], host_pos[host_found])
    # found positions hold exactly the queried ids
    np.testing.assert_array_equal(m.key_table[pos[found]], qs[found])


@pytest.mark.parametrize("cap_pow,n_ids,n_del", [(8, 60, 10),
                                                 (12, 1000, 200)])
def test_hashmap_probe_ref_oracle_matches_kernel(cap_pow, n_ids, n_del):
    """The brute-force ref oracle (full circular probe order, window-index
    binning) and the Pallas kernel agree everywhere — including the pos
    column at found rows (pos is unspecified where found is False)."""
    m, qs = _probe_case(cap_pow, n_ids, n_del, seed=100 + cap_pow)
    klo, khi = ops.int64_limbs(m.key_table)
    qlo, qhi = ops.int64_limbs(qs)
    got_pos, got_found = ops.hashmap_probe(klo, khi, qlo, qhi,
                                           shift=int(m.shift))
    ref_pos, ref_found = ref.hashmap_probe(klo, khi, qlo, qhi,
                                           shift=int(m.shift))
    got_found, ref_found = np.asarray(got_found), np.asarray(ref_found)
    np.testing.assert_array_equal(got_found, ref_found)
    np.testing.assert_array_equal(np.asarray(got_pos)[got_found],
                                  np.asarray(ref_pos)[ref_found])


def test_public_kernel_entrypoints_documented():
    """Every public symbol in the kernel modules carries a docstring that
    states its contract (KERNELS.md companion check)."""
    import inspect

    from repro.kernels import (delta_codec, embedding_lookup,
                               ftrl_row_update, hashmap_probe)
    for mod in (delta_codec, embedding_lookup, ftrl_row_update,
                hashmap_probe, ops, ref):
        assert (mod.__doc__ or "").strip(), mod.__name__
        for name, fn in vars(mod).items():
            if name.startswith("_") or not inspect.isfunction(fn):
                continue
            if fn.__module__ != mod.__name__:
                continue                    # re-exported helpers
            doc = (inspect.getdoc(fn) or "").strip()
            assert len(doc) >= 20, f"{mod.__name__}.{name} undocumented"
