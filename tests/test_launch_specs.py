"""Launch-layer machinery on the single local device: abstract specs,
sharding trees, lowering train/serve steps through jit (the 512-device
production meshes are exercised by launch/dryrun.py, not in unit tests)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, applicable, get_config, reduced
from repro.configs.shapes import InputShape
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import (abstract_params, abstract_train_state,
                                input_specs)
from repro.models.sharding import MeshInfo, cache_pspecs, param_pspecs
from repro.serving import make_serve_step
from repro.training import make_train_step
from repro.models import init_cache, init_params


def _tiny_shape(kind):
    return InputShape(f"tiny_{kind}", 64, 2, kind)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-1.3b",
                                  "granite-moe-3b-a800m"])
def test_lower_train_step_local_mesh(arch):
    cfg = reduced(get_config(arch))
    mesh = make_local_mesh(1, 1)
    m = MeshInfo(mesh)
    state = abstract_train_state(cfg, m)
    shape = _tiny_shape("train")
    specs = input_specs(cfg, shape, m)
    lowered = jax.jit(make_train_step(cfg, jit=False)).lower(
        state, specs["batch"])
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # jax 0.4.x: one dict per device
        ca = ca[0]
    assert ca["flops"] > 0


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-4b"])
def test_lower_serve_step_local_mesh(arch):
    cfg = reduced(get_config(arch))
    mesh = make_local_mesh(1, 1)
    m = MeshInfo(mesh)
    params = abstract_params(cfg, m)
    shape = _tiny_shape("decode")
    specs = input_specs(cfg, shape, m)
    lowered = jax.jit(make_serve_step(cfg, jit=False)).lower(
        params, specs["cache"], specs["tokens"], specs["pos"])
    assert lowered.compile() is not None


def test_param_pspecs_tree_matches_params():
    cfg = reduced(get_config("jamba-1.5-large-398b"))
    m = MeshInfo(make_local_mesh(1, 1))
    params = init_params(cfg, jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg, m)
    # identical tree structure
    jax.tree.map(lambda a, b: None, params, pspecs,
                 is_leaf=lambda x: isinstance(
                     x, jax.sharding.PartitionSpec))
    # every spec rank matches its leaf rank
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= p.ndim, (p.shape, s)


def test_cache_pspecs_tree_matches_cache():
    cfg = reduced(get_config("gemma3-4b"))
    m = MeshInfo(make_local_mesh(1, 1))
    cache = init_cache(cfg, 2, 32, abstract=True)
    cspecs = cache_pspecs(cfg, m, 2)
    jax.tree.map(lambda a, b: None, cache, cspecs,
                 is_leaf=lambda x: isinstance(
                     x, jax.sharding.PartitionSpec))


def test_applicability_rules():
    assert applicable(get_config("mamba2-1.3b"), SHAPES["long_500k"])[0]
    assert applicable(get_config("jamba-1.5-large-398b"),
                      SHAPES["long_500k"])[0]
    assert applicable(get_config("gemma3-4b"), SHAPES["long_500k"])[0]
    ok, why = applicable(get_config("qwen2-7b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    ok, why = applicable(get_config("whisper-medium"), SHAPES["long_500k"])
    assert not ok
    # every arch runs decode_32k and all train/prefill shapes
    for a in ("qwen2-7b", "whisper-medium", "dbrx-132b"):
        assert applicable(get_config(a), SHAPES["decode_32k"])[0]
        assert applicable(get_config(a), SHAPES["train_4k"])[0]
