"""Schema snapshot for the metrics surface: ``sync_metrics()`` must
remain a thin view over the cluster ``MetricsRegistry`` with the
pre-registry key layout, and the registry's canonical dotted names are
frozen here — adding a metric means updating SNAPSHOT *and* its row in
docs/OBSERVABILITY.md (`scripts/check_metrics_docs.py` enforces the
doc half)."""

import numpy as np
import pytest

from repro.configs.weips_ctr import FM_FTRL
from repro.core import ClusterConfig, WeiPSCluster

# the frozen canonical name set (scenario/group segments canonicalized)
SNAPSHOT = """
dedup_ratio
device_mirror.arena_bytes_uploaded
device_mirror.key_bytes_uploaded
device_mirror.key_full_uploads
device_mirror.key_incremental_uploads
device_mirror.syncs
device_mirror.tables
pushed_bytes
queue_bytes
replica_failovers
replica_lag_skips
serving.admission.executed_examples
serving.admission.executed_requests
serving.admission.offered_examples
serving.admission.offered_requests
serving.admission.shed_deadline_requests
serving.admission.shed_depth_requests
serving.admission.shed_examples
serving.admission.shed_requests
serving.device_blocks
serving.latency.p50
serving.latency.p99
serving.predict_seconds
serving.replica_lag_skips
serving.scenarios.<scenario>.admission.executed_examples
serving.scenarios.<scenario>.admission.executed_requests
serving.scenarios.<scenario>.admission.offered_examples
serving.scenarios.<scenario>.admission.offered_requests
serving.scenarios.<scenario>.admission.shed_deadline_requests
serving.scenarios.<scenario>.admission.shed_depth_requests
serving.scenarios.<scenario>.admission.shed_examples
serving.scenarios.<scenario>.admission.shed_requests
serving.scenarios.<scenario>.batches
serving.scenarios.<scenario>.cache.hit_rate
serving.scenarios.<scenario>.cache.hits
serving.scenarios.<scenario>.cache.invalidated
serving.scenarios.<scenario>.cache.misses
serving.scenarios.<scenario>.cache.rows
serving.scenarios.<scenario>.cache.trims
serving.scenarios.<scenario>.dense_cache.hit_rate
serving.scenarios.<scenario>.dense_cache.hits
serving.scenarios.<scenario>.dense_cache.invalidated
serving.scenarios.<scenario>.dense_cache.misses
serving.scenarios.<scenario>.dense_cache.rows
serving.scenarios.<scenario>.dense_refreshes
serving.scenarios.<scenario>.examples
serving.scenarios.<scenario>.latency.p50
serving.scenarios.<scenario>.latency.p99
serving.scenarios.<scenario>.padding_fraction
serving.scenarios.<scenario>.requests
serving.shard_pulled_rows
staleness.p50
staleness.p99
sync_lag_records
sync_lag_seconds
training.scenarios.<scenario>.auc
training.scenarios.<scenario>.batches
training.scenarios.<scenario>.calibration
training.scenarios.<scenario>.dedup_ratio
training.scenarios.<scenario>.examples
training.scenarios.<scenario>.logloss
training.scenarios.<scenario>.padding_fraction
training.scenarios.<scenario>.step
""".split()


@pytest.fixture(scope="module")
def driven_cluster():
    cl = WeiPSCluster(FM_FTRL, ClusterConfig(
        num_master=1, num_slave=2, num_replicas=1, num_partitions=2))
    ids = np.arange(64, dtype=np.int64).reshape(8, 8)
    cl.train_on_batch(ids, np.zeros(8, np.float32), now=0.0)
    cl.sync_tick(0.0)
    cl.predict(ids)
    return cl


def _canonical(cl):
    scenarios = {s.name for s in cl.serving.registry} | \
        {s.name for s in cl.training.registry}
    groups = set(cl.groups)
    out = set()
    for name in cl.metrics_registry.names(1.0):
        segs = ["<scenario>" if s in scenarios else
                "<group>" if s in groups else s
                for s in name.split(".")]
        out.add(".".join(segs))
    return sorted(out)


def test_registry_names_match_snapshot(driven_cluster):
    got = _canonical(driven_cluster)
    assert got == sorted(SNAPSHOT), (
        "registry schema drifted: "
        f"added={sorted(set(got) - set(SNAPSHOT))} "
        f"removed={sorted(set(SNAPSHOT) - set(got))} — update SNAPSHOT "
        "and docs/OBSERVABILITY.md")


def test_sync_metrics_is_registry_view(driven_cluster):
    cl = driven_cluster
    now = 2.0
    tree = cl.metrics_registry.tree(now)
    m = cl.sync_metrics(now)
    assert m == tree


def test_sync_metrics_top_level_schema(driven_cluster):
    m = driven_cluster.sync_metrics(1.0)
    assert set(m) == {
        "sync_lag_seconds", "staleness", "sync_lag_records",
        "pushed_bytes", "queue_bytes", "dedup_ratio",
        "replica_failovers", "replica_lag_skips", "device_mirror",
        "serving", "training"}
    assert set(m["staleness"]) == {"p50", "p99"}
    assert isinstance(m["serving"]["scenarios"], dict)
    assert isinstance(m["training"]["scenarios"], dict)


def test_values_are_live_not_frozen(driven_cluster):
    cl = driven_cluster
    before = cl.sync_metrics(1.0)["pushed_bytes"]
    ids = np.arange(64, 128, dtype=np.int64).reshape(8, 8)
    cl.train_on_batch(ids, np.ones(8, np.float32), now=2.0)
    cl.sync_tick(2.0)
    assert cl.sync_metrics(2.0)["pushed_bytes"] > before
