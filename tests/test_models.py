"""Model correctness: decode == forward (incremental consistency), SSD vs
naive recurrence oracle, block-local windowed attention vs masked oracle,
MoE dispatch semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import (decode_step, forward, init_cache, init_params,
                          precompute_cross_cache)
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm

CONSISTENCY_ARCHS = ["qwen2-1.5b", "mamba2-1.3b", "gemma3-4b",
                     "jamba-1.5-large-398b", "whisper-medium",
                     "llama-3.2-vision-90b", "dbrx-132b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    if cfg.num_experts:
        # disable capacity dropping so both paths compute identically
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc = (jax.random.normal(key, (B, cfg.encoder_len, cfg.d_model))
           if cfg.has_encoder_context else None)
    full, _ = forward(params, cfg, tokens, enc_context=enc)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.has_encoder_context:
        cache = precompute_cross_cache(params, cfg, cache, enc)
    step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    worst = 0.0
    for t in range(S):
        lg, cache = step(cache, tokens[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        err = float(jnp.abs(lg[:, :cfg.vocab_size]
                            - full[:, t, :cfg.vocab_size]).max())
        worst = max(worst, err)
    assert worst < 5e-4, f"{arch}: decode/forward divergence {worst}"


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step recurrence h_t = exp(dtA)h + dt x B."""
    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 2, 20, 3, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))

    y_chunk, final = ssm.ssd_chunked(x, dt, A, B, C, chunk=8)

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y_t, state = ssm.ssd_decode_step(state, x[:, t], dt[:, t], A,
                                         B[:, t], C[:, t])
        ys.append(y_t)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_naive, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(final, state, rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_threading():
    """Splitting a sequence in two with state handoff == one pass."""
    key = jax.random.PRNGKey(3)
    b, s, h, p, n = 1, 16, 2, 4, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_full, _ = ssm.ssd_chunked(x, dt, A, B, C, chunk=4)
    y1, st = ssm.ssd_chunked(x[:, :8], dt[:, :8], A, B[:, :8], C[:, :8],
                             chunk=4)
    y2, _ = ssm.ssd_chunked(x[:, 8:], dt[:, 8:], A, B[:, 8:], C[:, 8:],
                            chunk=4, initial_state=st)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), y_full,
                               rtol=2e-4, atol=2e-4)


def test_block_local_matches_masked_reference():
    cfg = reduced(get_config("gemma3-4b"))
    cfg = dataclasses.replace(cfg, window_size=8)
    key = jax.random.PRNGKey(2)
    b, s = 2, 32
    p = {
        "wq": jax.random.normal(key, (cfg.d_model, cfg.num_heads,
                                      cfg.head_dim)) * 0.05,
        "wk": jax.random.normal(key, (cfg.d_model, cfg.num_kv_heads,
                                      cfg.head_dim)) * 0.05,
        "wv": jax.random.normal(key, (cfg.d_model, cfg.num_kv_heads,
                                      cfg.head_dim)) * 0.05,
        "wo": jax.random.normal(key, (cfg.num_heads, cfg.head_dim,
                                      cfg.d_model)) * 0.05,
    }
    x = jax.random.normal(key, (b, s, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    # block-local path (s > window, s % window == 0)
    out_block = attn.self_attention(p, x, positions, cfg=cfg, causal=True,
                                    window=8)
    # masked full path (force via window > chunk threshold trick: use the
    # small-s branch by passing chunk >= s)
    out_masked = attn.self_attention(p, x, positions, cfg=cfg, causal=True,
                                     window=0, chunk=64)
    # apply window mask manually through the masked branch: recompute with
    # the (s <= chunk) branch and window set
    cfg_small = cfg
    out_masked_win = attn.self_attention(p, x, positions, cfg=cfg_small,
                                         causal=True, window=8, chunk=64)
    assert not np.allclose(out_masked, out_masked_win)   # window changes it
    np.testing.assert_allclose(out_block, out_masked_win, rtol=2e-4,
                               atol=2e-4)


def test_chunked_causal_matches_full():
    cfg = reduced(get_config("qwen2-7b"))
    key = jax.random.PRNGKey(4)
    b, s = 2, 64
    p = {
        "wq": jax.random.normal(key, (cfg.d_model, cfg.num_heads,
                                      cfg.head_dim)) * 0.05,
        "wk": jax.random.normal(key, (cfg.d_model, cfg.num_kv_heads,
                                      cfg.head_dim)) * 0.05,
        "wv": jax.random.normal(key, (cfg.d_model, cfg.num_kv_heads,
                                      cfg.head_dim)) * 0.05,
        "wo": jax.random.normal(key, (cfg.num_heads, cfg.head_dim,
                                      cfg.d_model)) * 0.05,
    }
    x = jax.random.normal(key, (b, s, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    out_chunked = attn.self_attention(p, x, positions, cfg=cfg, causal=True,
                                      chunk=16)       # forces kv-chunk scan
    out_full = attn.self_attention(p, x, positions, cfg=cfg, causal=True,
                                   chunk=s)
    np.testing.assert_allclose(out_chunked, out_full, rtol=2e-4, atol=2e-4)


def test_moe_routing_topk_and_counts():
    cfg = reduced(get_config("dbrx-132b"))
    key = jax.random.PRNGKey(5)
    p = {
        "router": jax.random.normal(key, (cfg.d_model, cfg.num_experts)),
        "w_gate": jax.random.normal(key, (cfg.num_experts, cfg.d_model,
                                          cfg.d_ff)) * 0.05,
        "w_up": jax.random.normal(key, (cfg.num_experts, cfg.d_model,
                                        cfg.d_ff)) * 0.05,
        "w_down": jax.random.normal(key, (cfg.num_experts, cfg.d_ff,
                                          cfg.d_model)) * 0.05,
    }
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, aux, counts = moe_lib.moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert counts.shape == (cfg.num_experts,)
    # every token routes to exactly k experts (no drops at cf=1.25, T=32)
    assert int(counts.sum()) <= 32 * cfg.experts_per_token
    assert float(aux) > 0


def test_moe_capacity_drops_overflow():
    cfg = reduced(get_config("dbrx-132b"))
    cfg = dataclasses.replace(cfg, moe_capacity_factor=0.1)
    key = jax.random.PRNGKey(6)
    p = {
        "router": jax.random.normal(key, (cfg.d_model, cfg.num_experts)),
        "w_gate": jnp.ones((cfg.num_experts, cfg.d_model, cfg.d_ff)) * .01,
        "w_up": jnp.ones((cfg.num_experts, cfg.d_model, cfg.d_ff)) * .01,
        "w_down": jnp.ones((cfg.num_experts, cfg.d_ff, cfg.d_model)) * .01,
    }
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    out, _, counts = moe_lib.moe_ffn(p, x, cfg)
    cap = moe_lib.moe_capacity(64, cfg)
    assert int(counts.max()) <= cap
    assert not jnp.isnan(out).any()
