"""Progressive validation metrics and domino downgrade behaviour."""

import numpy as np
import pytest

from repro.configs.weips_ctr import LR_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.core.downgrade import SmoothedThresholdTrigger
from repro.core.monitor import ProgressiveValidator, auc, logloss
from repro.data import ClickStream


def test_auc_reference_cases():
    y = np.array([0, 0, 1, 1], dtype=np.float32)
    assert auc(y, np.array([0.1, 0.2, 0.8, 0.9])) == 1.0
    assert auc(y, np.array([0.9, 0.8, 0.2, 0.1])) == 0.0
    assert auc(y, np.array([0.5, 0.5, 0.5, 0.5])) == pytest.approx(0.5)
    # matches the probabilistic definition on random data
    rng = np.random.default_rng(0)
    y = (rng.random(500) < 0.3).astype(np.float32)
    p = rng.random(500)
    pairs = [(pi, pj) for pi, yi in zip(p, y) for pj, yj in zip(p, y)
             if yi == 1 and yj == 0]
    want = np.mean([1.0 if a > b else (0.5 if a == b else 0.0)
                    for a, b in pairs])
    assert auc(y, p) == pytest.approx(want, abs=1e-9)


def test_logloss_sanity():
    y = np.array([1, 0], np.float32)
    assert logloss(y, np.array([0.9, 0.1])) < logloss(y, np.array([0.5, 0.5]))


def test_progressive_validation_is_pre_update():
    """The metric for step t is computed with the params BEFORE step t's
    gradient — so a model that memorizes batch t only shows it at t+1."""
    cl = WeiPSCluster(LR_FTRL, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2))
    ids = np.tile(np.arange(LR_FTRL.fields, dtype=np.int64), (32, 1))
    y = np.ones(32, np.float32)
    ms = [cl.train_on_batch(ids, y, now=float(i)) for i in range(6)]
    # first observation is the prior (p=0.5): the metric for step t is
    # computed BEFORE step t's update; later ones reflect learning (FTRL
    # needs a few steps for |z| to clear the l1 threshold)
    assert ms[0]["pctr"] == pytest.approx(0.5, abs=1e-6)
    assert ms[-1]["pctr"] > ms[0]["pctr"]


def test_smoothed_trigger_suppresses_single_spike():
    v = ProgressiveValidator()
    trig = SmoothedThresholdTrigger(metric="logloss", threshold=1.0,
                                    window=5, min_points=5)
    rng = np.random.default_rng(0)
    y = (rng.random(64) < 0.5).astype(np.float32)
    good = np.clip(y * 0.8 + 0.1, 0.01, 0.99)
    for i in range(6):
        v.observe(float(i), i, y, good)
    assert not trig.check(v)
    # one bad batch — smoothed metric must NOT trigger
    v.observe(6.0, 6, y, 1.0 - good)
    assert not trig.check(v)
    # sustained collapse — must trigger
    for i in range(7, 13):
        v.observe(float(i), i, y, 1.0 - good)
    assert trig.check(v)


def test_domino_downgrade_restores_serving_quality():
    """Corrupt the master post-checkpoint; the downgrade hot-switches the
    slaves back to the stable version (with queue offsets from the ckpt)."""
    cl = WeiPSCluster(LR_FTRL, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=1, num_partitions=4,
        downgrade_threshold=1.0, downgrade_window=4))
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields,
                         seed=3)
    now = 0.0
    for i in range(15):
        ids, y = stream.batch(64)
        cl.train_on_batch(ids, y, now=now)
        cl.sync_tick(now)
        now += 0.5
    v_good = cl.checkpoint(now)
    ids_eval, y_eval = stream.batch(256)
    p_good = cl.predict(ids_eval)

    # poison the master state (simulates a corrupted update burst)
    for m in cl.masters:
        t = m.tables["w"]
        all_ids = t.all_ids()
        if len(all_ids):
            w, slots = t.gather(all_ids)
            slots["z"] = slots["z"] + 100.0
            t.scatter(all_ids, w, slots)
            m.collector.record("w", all_ids, "upsert")
    cl.sync_tick(now + 1)
    p_bad = cl.predict(ids_eval)
    assert np.abs(p_bad - p_good).max() > 0.1     # serving visibly degraded

    v = cl.downgrader.execute(now + 2, version=v_good)
    assert v == v_good
    p_restored = cl.predict(ids_eval)
    np.testing.assert_allclose(p_restored, p_good, atol=5e-3)


def test_auto_downgrade_on_metric_collapse():
    import dataclasses
    cfg = dataclasses.replace(LR_FTRL, ftrl_l1=0.01, ftrl_alpha=0.3)
    cl = WeiPSCluster(cfg, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
        downgrade_metric="logloss", downgrade_threshold=0.72,
        downgrade_window=3))
    stream = ClickStream(feature_space=1 << 8, fields=cfg.fields,
                         signal_scale=1.0)
    now = 0.0
    for i in range(30):
        ids, y = stream.batch(128)
        cl.train_on_batch(ids, y, now=now)
        cl.sync_tick(now)
        now += 0.5
    cl.checkpoint(now)
    assert cl.downgrade_check(now) is None        # healthy: no downgrade
    stream.corrupt(scale=2.0)                     # adversarial sign flip
    for i in range(8):
        ids, y = stream.batch(128)
        cl.train_on_batch(ids, y, now=now)
        now += 0.5
    assert cl.downgrade_check(now) is not None    # trigger fired
    assert len(cl.downgrader.downgrades) == 1
