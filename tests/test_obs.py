"""Unit tests for the observability subsystem (`repro.obs`): tracer
ring semantics, Perfetto round-trip, metrics registry, and the
in-process causal chain through the streaming update path
(push -> queue -> apply -> cache-invalidate)."""

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import perfetto
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


@pytest.fixture(autouse=True)
def _isolated_tracer():
    """Every test starts and ends with the module tracer disabled —
    the global is process-wide state."""
    obs_trace.disable()
    yield
    obs_trace.disable()


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------
class TestTracer:
    def test_disabled_is_noop(self):
        tr = Tracer(enabled=False, capacity=4)
        sp = tr.begin("x", foo=1)
        assert sp is obs_trace._NULL_SPAN
        with sp:
            pass
        assert tr.record("y", t0=0.0, t1=1.0) == 0
        assert tr.instant("z") == 0
        assert tr.export() == []

    def test_nesting_and_parenting(self):
        clk = FakeClock()
        tr = Tracer(clock=clk, process="p0")
        root = tr.begin("outer", trace=tr.new_trace())
        clk.advance(1.0)
        with tr.span("inner", k=2) as inner:
            assert inner.trace == root.trace
            assert inner.parent == root.id
            clk.advance(0.5)
        tr.end(root)
        spans = tr.export()
        assert [s["name"] for s in spans] == ["inner", "outer"]
        inner_d, outer_d = spans
        assert inner_d["parent"] == outer_d["span"]
        assert inner_d["trace"] == outer_d["trace"]
        assert inner_d["args"] == {"k": 2}
        assert outer_d["t1"] - outer_d["t0"] == pytest.approx(1.5)
        assert all(s["proc"] == "p0" for s in spans)

    def test_ids_are_pid_salted_and_unique(self):
        import os
        tr = Tracer()
        ids = {tr.new_trace() for _ in range(100)}
        assert len(ids) == 100
        assert all(i >> 32 == (os.getpid() & 0xFFFF) for i in ids)

    def test_ring_wrap_drops_oldest(self):
        clk = FakeClock()
        tr = Tracer(capacity=4, clock=clk)
        for i in range(7):
            tr.record(f"s{i}", t0=float(i), t1=float(i) + 0.5)
        assert tr.dropped == 3
        assert [s["name"] for s in tr.export()] == \
            ["s3", "s4", "s5", "s6"]

    def test_record_and_instant(self):
        tr = Tracer(clock=FakeClock(5.0))
        sid = tr.record("q", t0=1.0, t1=2.0, trace=9, parent=3, n=4)
        spans = tr.export()
        assert spans[0] == {"name": "q", "proc": "main", "trace": 9,
                            "span": sid, "parent": 3, "t0": 1.0,
                            "t1": 2.0, "args": {"n": 4}}
        tr.instant("mark", kind="kill")
        inst = tr.export()[-1]
        assert inst["t1"] is None and inst["t0"] == 5.0

    def test_end_pops_only_own_frame(self):
        tr = Tracer(clock=FakeClock())
        a = tr.begin("a", trace=tr.new_trace())
        b = tr.begin("b")
        tr.end(a)              # out-of-order: must not pop b's frame
        assert tr.current()[1] == b.id
        tr.end(b)
        assert tr.current() == (0, 0)

    def test_export_includes_open_spans(self):
        clk = FakeClock()
        tr = Tracer(clock=clk, process="m0")
        root = tr.begin("sync.push", trace=tr.new_trace(), groups=2)
        clk.advance(0.25)
        # export mid-span (what the pre-kill dump hook sees): the open
        # span appears, clipped at now and flagged partial, so children
        # already carrying its id don't orphan
        spans = tr.export()
        assert [s["name"] for s in spans] == ["sync.push"]
        d = spans[0]
        assert d["span"] == root.id and d["trace"] == root.trace
        assert d["t1"] == pytest.approx(d["t0"] + 0.25)
        assert d["args"] == {"groups": 2, "partial": True}
        # once ended normally it exports from the ring, unflagged
        tr.end(root)
        spans = tr.export()
        assert [s["name"] for s in spans] == ["sync.push"]
        assert spans[0]["args"] == {"groups": 2}
        tr.clear()
        assert tr.export() == []

    def test_configure_disable_roundtrip(self):
        assert not obs_trace.get_tracer().enabled
        tr = obs_trace.configure(enabled=True, capacity=8, process="w")
        assert obs_trace.get_tracer() is tr and tr.enabled
        assert tr.capacity == 8
        obs_trace.disable()
        assert not obs_trace.get_tracer().enabled


# ---------------------------------------------------------------------
# perfetto
# ---------------------------------------------------------------------
class TestPerfetto:
    def _spans(self):
        clk = FakeClock(100.0)
        tr = Tracer(clock=clk, process="master-0")
        t = tr.new_trace()
        with tr.span("sync.push", trace=t, groups=1):
            clk.advance(0.010)
        tr.instant("fault.kill", trace=t, point="mid_flush")
        return tr.export()

    def test_chrome_structure(self):
        doc = perfetto.to_chrome(self._spans())
        phs = [e["ph"] for e in doc["traceEvents"]]
        assert phs.count("M") == 1        # one process track
        assert phs.count("X") == 1 and phs.count("i") == 1
        assert phs.count("s") == 1 and phs.count("t") == 1  # flow
        x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
        assert x["dur"] == pytest.approx(10_000.0)  # 10ms in us
        assert x["args"]["groups"] == 1

    def test_write_load_roundtrip(self, tmp_path):
        spans = self._spans()
        path = str(tmp_path / "t.json")
        n = perfetto.write_trace(path, spans)
        assert n == 2
        back = perfetto.load_spans(path)
        assert len(back) == len(spans)
        for a, b in zip(sorted(back, key=lambda s: s["span"]),
                        sorted(spans, key=lambda s: s["span"])):
            assert a["name"] == b["name"]
            assert a["proc"] == b["proc"]
            assert (a["trace"], a["span"], a["parent"]) == \
                (b["trace"], b["span"], b["parent"])
            assert a["t0"] == pytest.approx(b["t0"], abs=1e-6)
            assert (a["t1"] is None) == (b["t1"] is None)

    def test_merge_dedups_and_sorts(self):
        spans = self._spans()
        merged = perfetto.merge_spans(spans, spans, None, [])
        assert len(merged) == len(spans)
        assert merged == sorted(merged, key=lambda s: s["t0"])

    def test_viewer_summary(self, tmp_path, capsys):
        path = str(tmp_path / "t.json")
        perfetto.write_trace(path, self._spans())
        assert obs_trace.main([path]) == 0
        out = capsys.readouterr().out
        assert "sync.push" in out and "fault.kill" in out


# ---------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------
class TestMetrics:
    def test_primitives(self):
        reg = MetricsRegistry()
        c = reg.counter("a.count")
        c.inc()
        c.inc(2)
        g = reg.gauge("a.depth")
        g.set(7.0)
        h = reg.histogram("a.lat", window=8)
        for v in (1.0, 2.0, 3.0, 4.0):
            h.record(v)
        t = reg.tree()
        assert t["a"]["count"] == 3
        assert t["a"]["depth"] == 7.0
        assert t["a"]["lat"]["count"] == 4
        assert t["a"]["lat"]["p50"] == pytest.approx(2.5)

    def test_providers_arity(self):
        reg = MetricsRegistry()
        reg.register("x", lambda: {"a": 1})
        reg.register("y", lambda now: now * 2)
        t = reg.tree(3.0)
        assert t == {"x": {"a": 1}, "y": 6.0}

    def test_collect_flattens(self):
        reg = MetricsRegistry()
        reg.register("s.l", lambda: {"p50": 0.1, "p99": 0.9})
        assert reg.collect() == {"s.l.p50": 0.1, "s.l.p99": 0.9}
        assert reg.names() == ["s.l.p50", "s.l.p99"]

    def test_duplicate_name_raises(self):
        reg = MetricsRegistry()
        reg.counter("dup")
        with pytest.raises(ValueError):
            reg.register("dup", lambda: 1)

    def test_provider_merge_at_shared_prefix(self):
        reg = MetricsRegistry()
        reg.register("s.a", lambda: 1)
        reg.register("s", lambda: {"b": 2})
        assert reg.tree() == {"s": {"a": 1, "b": 2}}

    def test_join(self):
        assert obs_metrics.join("", "x") == "x"
        assert obs_metrics.join("a", "x") == "a.x"


# ---------------------------------------------------------------------
# in-process causal chain through the streaming update path
# ---------------------------------------------------------------------
class TestStreamingTraceChain:
    def _cluster(self):
        from repro.configs.weips_ctr import FM_FTRL
        from repro.core import ClusterConfig, WeiPSCluster
        return WeiPSCluster(FM_FTRL, ClusterConfig(
            num_master=1, num_slave=2, num_replicas=1,
            num_partitions=2))

    @staticmethod
    def _push_records():
        from repro.core.ps import MasterShard
        from repro.core.queue import Consumer, PartitionedQueue
        from repro.core.routing import RoutingPlan
        from repro.core.streaming import Pusher
        from repro.core.transform import make_transform
        from repro.optim import get_optimizer
        opt = get_optimizer("ftrl")
        master = MasterShard(0, {"w": 4}, opt)
        ids = np.arange(256, dtype=np.int64)
        master.apply_batch("w", ids, np.ones((256, 4), np.float32))
        q = PartitionedQueue(2)
        Pusher(master, q, RoutingPlan(1, 1, 2),
               make_transform("identity", opt)).push(
            {("w", "upsert"): ids}, now=0.0)
        return list(Consumer(q, (0, 1)).poll())

    def test_disabled_records_carry_no_trace_meta(self):
        recs = self._push_records()
        assert recs
        for r in recs:
            assert "trace" not in r.meta and "span" not in r.meta
        assert obs_trace.get_tracer().export() == []

    def test_enabled_records_stamp_trace_meta(self):
        obs_trace.configure(enabled=True, process="test")
        recs = self._push_records()
        assert recs
        tids = {r.meta["trace"] for r in recs}
        assert len(tids) == 1 and 0 not in tids
        for r in recs:
            assert r.meta["span"] and "t_push" in r.meta

    def test_enabled_chain_push_queue_apply_invalidate(self):
        obs_trace.configure(enabled=True, process="test")
        cl = self._cluster()
        ids = np.arange(64, dtype=np.int64).reshape(8, 8)
        cl.train_on_batch(ids, np.zeros(8, np.float32), now=0.0)
        cl.sync_tick(0.0)
        cl.predict(ids)                   # warm the serve cache
        cl.train_on_batch(ids, np.ones(8, np.float32), now=1.0)
        cl.sync_tick(1.0)                 # invalidates warm rows
        spans = obs_trace.get_tracer().export()
        names = {s["name"] for s in spans}
        assert {"sync.push", "sync.queue", "sync.apply",
                "cache.invalidate"} <= names

        # one causal tree: queue's parent is the push span, apply's
        # parent is the queue span, invalidate nests under apply
        pushes = {s["span"]: s for s in spans
                  if s["name"] == "sync.push"}
        queues = [s for s in spans if s["name"] == "sync.queue"]
        applies = {s["span"]: s for s in spans
                   if s["name"] == "sync.apply"}
        assert queues
        for q in queues:
            assert q["parent"] in pushes
            assert q["trace"] == pushes[q["parent"]]["trace"]
        for a in applies.values():
            parent_q = next(q for q in queues if q["span"] == a["parent"])
            assert parent_q["trace"] == a["trace"]
        invs = [s for s in spans if s["name"] == "cache.invalidate"]
        assert invs
        for inv in invs:
            assert inv["parent"] in applies
            assert inv["trace"] == applies[inv["parent"]]["trace"]

        # no orphans: every non-zero parent resolves to an exported span
        all_ids = {s["span"] for s in spans}
        for s in spans:
            assert s["parent"] == 0 or s["parent"] in all_ids

    def test_queue_span_measures_dwell(self):
        obs_trace.configure(enabled=True, process="test")
        cl = self._cluster()
        ids = np.arange(32, dtype=np.int64).reshape(4, 8)
        cl.train_on_batch(ids, np.zeros(4, np.float32), now=0.0)
        cl.sync_tick(0.0)
        queues = [s for s in obs_trace.get_tracer().export()
                  if s["name"] == "sync.queue"]
        assert queues
        for q in queues:
            assert q["t1"] >= q["t0"]     # push stamp precedes poll
