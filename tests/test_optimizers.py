"""Optimizer correctness: descent, slot semantics, serve-weight derivation,
adafactor memory factorization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (FTRL, Adafactor, Adagrad, Adam, Momentum, SGD,
                         get_optimizer)

ALL = ["sgd", "momentum", "adagrad", "adam", "ftrl", "adafactor"]


@pytest.mark.parametrize("name", ALL)
def test_descent_on_quadratic(name):
    """Every optimizer reduces f(w) = ||w - w*||^2 over 200 steps."""
    opt = get_optimizer(name, lr=0.05) if name != "ftrl" else \
        get_optimizer("ftrl", alpha=0.5, l1=0.0, l2=0.0)
    w_star = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                         jnp.float32)
    w = jnp.zeros((4, 8), jnp.float32)
    slots = opt.init_slots(w)
    f0 = float(jnp.sum((w - w_star) ** 2))
    for t in range(200):
        g = 2 * (w - w_star)
        w, slots = opt.update(w, slots, g, t)
    assert float(jnp.sum((w - w_star) ** 2)) < 0.1 * f0


def test_ftrl_l1_sparsity():
    """FTRL with strong l1 zeroes small-signal coordinates exactly."""
    opt = FTRL(alpha=0.1, l1=5.0, l2=1.0)
    w = jnp.zeros((1, 4))
    slots = opt.init_slots(w)
    rng = np.random.default_rng(1)
    for t in range(50):
        # coordinate 0 has strong signal; others pure noise
        g = jnp.asarray(np.concatenate([
            [[-4.0]], rng.normal(size=(1, 3)) * 0.1], axis=1), jnp.float32)
        w, slots = opt.update(w, slots, g, t)
    assert float(jnp.abs(w[0, 0])) > 0
    assert np.all(np.asarray(w[0, 1:]) == 0.0)


def test_ftrl_serve_weights_equal_param():
    """The stored param IS the derived w (consistency of the transform)."""
    opt = FTRL()
    w = jnp.zeros((2, 4))
    slots = opt.init_slots(w)
    for t in range(10):
        g = jnp.asarray(np.random.default_rng(t).normal(size=(2, 4)),
                        jnp.float32) * 3
        w, slots = opt.update(w, slots, g, t)
    np.testing.assert_allclose(np.asarray(opt.serve_weights(w, slots)),
                               np.asarray(w), rtol=1e-6)


def test_adam_bias_correction_first_step():
    opt = Adam(lr=1.0, b1=0.9, b2=0.999, eps=0.0)
    w = jnp.zeros((1,))
    slots = opt.init_slots(w)
    g = jnp.asarray([0.5])
    w2, _ = opt.update(w, slots, g, 0)
    # bias-corrected first step == -lr * sign(g)
    np.testing.assert_allclose(np.asarray(w2), [-1.0], rtol=1e-5)


def test_adafactor_slots_are_factored():
    opt = Adafactor()
    p = jnp.zeros((64, 128))
    slots = opt.init_slots(p)
    assert slots["vr"].shape == (64,)
    assert slots["vc"].shape == (128,)
    slot_bytes = sum(np.asarray(s).nbytes for s in slots.values())
    assert slot_bytes < 0.05 * p.size * 4       # >20x smaller than Adam


def test_momentum_updates_untouched_coordinates():
    """Documented momentum semantics the sync engine's 'cumulative' embed
    mode exists for: a coordinate with g=0 still moves while m != 0."""
    opt = Momentum(lr=0.1, momentum=0.9)
    w = jnp.zeros((2,))
    slots = opt.init_slots(w)
    w, slots = opt.update(w, slots, jnp.asarray([1.0, 0.0]), 0)
    w2, _ = opt.update(w, slots, jnp.asarray([0.0, 0.0]), 1)
    assert float(w2[0]) != float(w[0])          # keeps moving with g=0
    assert float(w2[1]) == 0.0
