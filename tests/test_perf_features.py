"""Correctness of the §Perf beyond-paper features: chunked CE, grouped MoE
dispatch, context-parallel attention flag, sort-based positions."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.models import moe as moe_lib
from repro.training.trainer import loss_fn


def test_chunked_ce_matches_monolithic_values_and_grads():
    cfg = reduced(get_config("qwen2-7b"))
    cfg_c = dataclasses.replace(cfg, loss_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 33),
                                          0, cfg.vocab_size)}
    l1, _ = loss_fn(params, cfg, batch)
    l2, _ = loss_fn(params, cfg_c, batch)
    assert abs(float(l1) - float(l2)) < 1e-4
    g1 = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(p, cfg_c, batch)[0])(params)
    errs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g1, g2)
    assert max(jax.tree.leaves(errs)) < 1e-5


def _moe_params(cfg, key):
    return {
        "router": jax.random.normal(key, (cfg.d_model, cfg.num_experts)),
        "w_gate": jax.random.normal(key, (cfg.num_experts, cfg.d_model,
                                          cfg.d_ff)) * 0.05,
        "w_up": jax.random.normal(key, (cfg.num_experts, cfg.d_model,
                                        cfg.d_ff)) * 0.05,
        "w_down": jax.random.normal(key, (cfg.num_experts, cfg.d_ff,
                                          cfg.d_model)) * 0.05,
    }


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_dispatch_matches_flat_with_ample_capacity(groups):
    cfg = dataclasses.replace(reduced(get_config("dbrx-132b")),
                              moe_capacity_factor=8.0)
    cfg_g = dataclasses.replace(cfg, moe_dispatch_groups=groups)
    key = jax.random.PRNGKey(0)
    p = _moe_params(cfg, key)
    x = jax.random.normal(key, (4, 16, cfg.d_model))
    o1, a1, c1 = moe_lib.moe_ffn(p, x, cfg)
    o2, a2, c2 = moe_lib.moe_ffn(p, x, cfg_g)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_sorted_positions_first_come_first_served():
    """Stable-sort positions preserve arrival order within each expert —
    the capacity drop semantics of the cumsum formulation."""
    flat_e = jnp.array([3, 1, 3, 3, 0, 1, 3], jnp.int32)
    pos = moe_lib._slot_positions(flat_e, 4)
    np.testing.assert_array_equal(np.asarray(pos), [0, 0, 1, 2, 0, 1, 3])


def test_context_parallel_flag_is_noop_without_mesh():
    """cp-attention adds constraints only; math unchanged (no mesh here,
    UNCONSTRAINED specs are inert on a single device)."""
    cfg = reduced(get_config("qwen1.5-4b"))
    cfg_cp = dataclasses.replace(cfg, context_parallel_attn=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    l1, _ = loss_fn(params, cfg, batch)
    l2, _ = loss_fn(params, cfg_cp, batch)
    assert abs(float(l1) - float(l2)) < 1e-6


def test_cumulative_expert_tracking_under_adam():
    """Momentum keeps updating experts routed-to in earlier windows; the
    engine's cumulative mode keeps the replica exact."""
    from repro.core.sync_engine import ModelSyncEngine, SyncConfig
    from repro.training import init_train_state, make_train_step

    cfg = dataclasses.replace(reduced(get_config("granite-moe-3b-a800m")),
                              num_experts=16, experts_per_token=2, d_ff=64)
    assert cfg.optimizer == "adam"
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, donate=False)
    engine = ModelSyncEngine(cfg, state.params, SyncConfig(
        gather_mode="period", period=1.0, codec="identity"))
    assert engine._embed_mode == "cumulative"
    rng = np.random.default_rng(0)
    for t in range(8):
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)),
                             jnp.int32)
        state, m = step(state, {"tokens": tokens})
        engine.collect_step(np.asarray(tokens), {
            "expert_counts_per_layer": jax.tree.map(
                np.asarray, m["expert_counts_per_layer"])})
        engine.tick(state.params, now=t * 0.5)
    engine.tick(state.params, now=1e9)
    assert engine.replicas[0].staleness(state.params) < 1e-5


def test_int8_kv_cache_decode_close_to_forward():
    """Quantized serving cache: decode matches full forward to the int8
    quantization tolerance (the fit-enabler for 90B decode — §Perf iter 5)."""
    from repro.models import decode_step, forward, init_cache, init_params

    cfg = reduced(get_config("qwen2-7b"))
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    full, _ = forward(params, cfg, tokens)
    cache = init_cache(cfg, B, S, dtype=jnp.float32, kv_quant=True)
    step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    worst = 0.0
    for t in range(S):
        lg, cache = step(cache, tokens[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        worst = max(worst, float(jnp.abs(
            lg[:, :cfg.vocab_size] - full[:, t, :cfg.vocab_size]).max()))
    assert worst < 0.3              # logit error bounded by int8 scales
    assert cache["segments"][0]["pos0"]["k"].dtype == jnp.int8
