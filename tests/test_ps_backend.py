"""PS backend equivalence: the ``pallas`` row engine (interpret mode on
CPU, Mosaic on TPU) must match the ``numpy`` reference path through the
real PS layer — SlaveShard serve lookups via the ``embedding_lookup``
kernel and MasterShard FTRL pushes via the fused ``ftrl_row_update``
kernel. This is the acceptance gate that the shipped kernels are actually
exercised by the parameter server, not just by kernel unit tests."""

import numpy as np
import pytest

from repro.core.ps import MasterShard, SlaveShard, SparseTable
from repro.optim import get_optimizer

DIM = 128       # lane-width-aligned rows (TPU idiom; interpret mode on CPU)


def _rand_ids(rng, n, space=10_000):
    return rng.integers(0, space, size=n).astype(np.int64)


def test_sparse_table_gather_backends_match(rng):
    tables = {b: SparseTable(DIM, init_capacity=32, backend=b)
              for b in ("numpy", "pallas")}
    ids = _rand_ids(rng, 12, space=40)
    w = rng.normal(size=(len(ids), DIM)).astype(np.float32)
    for t in tables.values():
        t.scatter(ids, w)
    probe = np.concatenate([ids[:5], _rand_ids(rng, 5, space=40) + 100])
    got_np, _ = tables["numpy"].gather(probe)
    got_pl, _ = tables["pallas"].gather(probe)
    np.testing.assert_array_equal(got_np, got_pl)
    # missing ids (the +100 block) are zeros on both paths
    assert (got_np[5:] == 0).all()


def test_slave_lookup_pallas_matches_numpy(rng):
    groups = {"w": DIM}
    slaves = {b: SlaveShard(0, groups, backend=b)
              for b in ("numpy", "pallas")}
    ids = _rand_ids(rng, 16, space=60)
    vals = rng.normal(size=(len(ids), DIM)).astype(np.float32)
    for s in slaves.values():
        s.tables["w"].scatter(ids, vals)
    probe = np.concatenate([ids, _rand_ids(rng, 4, space=60) + 1000])
    np.testing.assert_array_equal(slaves["numpy"].lookup("w", probe),
                                  slaves["pallas"].lookup("w", probe))


@pytest.mark.parametrize("steps", [1, 4])
def test_master_ftrl_pallas_matches_numpy(rng, steps):
    """apply_batch: hash → gather → fused FTRL kernel → scatter, against
    the vectorized NumPy reference, over several steps (state carries)."""
    opt = get_optimizer("ftrl", alpha=0.1, beta=1.0, l1=0.5, l2=0.2)
    masters = {b: MasterShard(0, {"w": DIM}, opt, backend=b)
               for b in ("numpy", "pallas")}
    for step in range(steps):
        ids = _rand_ids(rng, 8, space=20)
        grads = np.random.default_rng(step).normal(
            size=(len(ids), DIM)).astype(np.float32)
        for m in masters.values():
            m.apply_batch("w", ids, grads, step=step)
    ids_all = masters["numpy"].tables["w"].all_ids()
    w_np, s_np = masters["numpy"].tables["w"].gather(np.sort(ids_all))
    w_pl, s_pl = masters["pallas"].tables["w"].gather(np.sort(ids_all))
    np.testing.assert_allclose(w_np, w_pl, rtol=1e-5, atol=1e-6)
    for k in ("z", "n"):
        np.testing.assert_allclose(s_np[k], s_pl[k], rtol=1e-5, atol=1e-6)


def test_apply_batch_dedups_and_sums_duplicate_ids():
    """Duplicate ids in one minibatch act as summed gradients on one row
    (sparse-grad semantics), and each unique row updates exactly once."""
    opt = get_optimizer("ftrl")
    m_dup = MasterShard(0, {"w": 4}, opt)
    m_sum = MasterShard(0, {"w": 4}, opt)
    ids = np.array([7, 7, 9], np.int64)
    g = np.array([[1.0] * 4, [2.0] * 4, [5.0] * 4], np.float32)
    m_dup.apply_batch("w", ids, g, step=0)
    m_sum.apply_batch("w", np.array([7, 9], np.int64),
                      np.array([[3.0] * 4, [5.0] * 4], np.float32), step=0)
    for m in (m_dup, m_sum):
        assert m.tables["w"].touch_count[
            m.tables["w"].lookup(np.array([7]))[0]] == 1
    w_dup, s_dup = m_dup.tables["w"].gather(np.array([7, 9]))
    w_sum, s_sum = m_sum.tables["w"].gather(np.array([7, 9]))
    np.testing.assert_allclose(w_dup, w_sum, rtol=1e-6)
    np.testing.assert_allclose(s_dup["z"], s_sum["z"], rtol=1e-6)


def test_apply_batch_unsorted_unique_ids():
    """Regression: slots resolve in sorted-unique order, so grad rows must
    be permuted to match even when ids are unique but unsorted."""
    opt = get_optimizer("sgd", lr=1.0)
    m = MasterShard(0, {"w": 2}, opt)
    m.apply_batch("w", np.array([5, 2], np.int64),
                  np.array([[1.0, 1.0], [10.0, 10.0]], np.float32), step=0)
    w, _ = m.tables["w"].gather(np.array([5, 2], np.int64))
    np.testing.assert_allclose(w, [[-1.0, -1.0], [-10.0, -10.0]])


def test_update_rows_matches_update_for_all_optimizers(rng):
    """The batched row path must agree with the elementwise ``update``
    contract every other PS consumer (dense bank, transform) relies on."""
    import jax.numpy as jnp
    for name in ("sgd", "adagrad", "adam", "momentum", "ftrl"):
        opt = get_optimizer(name)
        w = rng.normal(size=(6, 8)).astype(np.float32)
        slots = {k: np.asarray(v) for k, v in
                 opt.init_slots(jnp.asarray(w)).items()}
        g = rng.normal(size=(6, 8)).astype(np.float32)
        new_w, new_s = opt.update_rows(w, slots, g, 3)
        ref_w, ref_s = opt.update(jnp.asarray(w),
                                  {k: jnp.asarray(v)
                                   for k, v in slots.items()},
                                  jnp.asarray(g), 3)
        np.testing.assert_allclose(new_w, np.asarray(ref_w), rtol=1e-5,
                                   atol=1e-6)
        for k in new_s:
            np.testing.assert_allclose(new_s[k], np.asarray(ref_s[k]),
                                       rtol=1e-5, atol=1e-6)


def test_cold_pull_end_to_end_pallas_matches_numpy(rng):
    """Acceptance gate for the fused serve path: a fully cold serve_rows
    through a ``pallas`` cluster (device-mirror probe + fused
    probe→gather lookups) is bit-equal to the ``numpy`` staged path —
    router, replica reads, cache fill and all — and stays bit-equal warm
    (cache hits) and after a second training sync."""
    import dataclasses

    from repro.configs.weips_ctr import FM_FTRL
    from repro.core.cluster import ClusterConfig, WeiPSCluster

    cfg = dataclasses.replace(FM_FTRL, fields=4)
    pool = np.unique(_rand_ids(rng, 96, space=1 << 40))
    req = pool[rng.integers(0, len(pool), size=(6, cfg.fields))]
    served = {}
    for backend in ("numpy", "pallas"):
        cl = WeiPSCluster(cfg, ClusterConfig(
            num_master=1, num_slave=2, num_replicas=1, num_partitions=2,
            ps_backend=backend))
        prng = np.random.default_rng(11)          # same rows per backend
        for mid, mids in cl.plan.split_by_master(pool).items():
            for g, dim in cl.groups.items():
                cl.masters[mid].apply_batch(
                    g, mids,
                    prng.normal(size=(len(mids), dim)).astype(np.float32))
        cl.sync_tick(0.0)
        cold = cl.serve_rows(req)                 # cache starts empty
        warm = cl.serve_rows(req)
        served[backend] = (cold, warm)
    for i in range(2):
        for g in served["numpy"][i]:
            np.testing.assert_array_equal(served["numpy"][i][g],
                                          served["pallas"][i][g])


def test_cluster_forced_hbm_placement_matches_numpy(rng):
    """Every table in a pallas cluster pinned to the HBM windowed-DMA
    probe (`device_placement="hbm"`) — training pushes, replica reads,
    cache fills and warm serves all run through the DMA kernel and stay
    bit-equal to the numpy cluster; the aggregated mirror metrics confirm
    the placement actually took."""
    import dataclasses

    from repro.configs.weips_ctr import FM_FTRL
    from repro.core.cluster import ClusterConfig, WeiPSCluster

    cfg = dataclasses.replace(FM_FTRL, fields=4)
    pool = np.unique(_rand_ids(rng, 96, space=1 << 40))
    req = pool[rng.integers(0, len(pool), size=(6, cfg.fields))]
    served = {}
    for backend in ("numpy", "pallas"):
        cl = WeiPSCluster(cfg, ClusterConfig(
            num_master=1, num_slave=2, num_replicas=1, num_partitions=2,
            ps_backend=backend))
        if backend == "pallas":
            for shard in (list(cl.masters)
                          + [r for rs in cl.replica_sets
                             for r in rs.replicas]):
                for t in shard.tables.values():
                    t.device_placement = "hbm"
            for scn in cl.serving.registry:
                scn.cache.table.device_placement = "hbm"
        prng = np.random.default_rng(23)
        for mid, mids in cl.plan.split_by_master(pool).items():
            for g, dim in cl.groups.items():
                cl.masters[mid].apply_batch(
                    g, mids,
                    prng.normal(size=(len(mids), dim)).astype(np.float32))
        cl.sync_tick(0.0)
        served[backend] = (cl.serve_rows(req), cl.serve_rows(req))
        if backend == "pallas":
            assert cl.serving.device_blocks > 0
            mm = cl.sync_metrics(0.0)["device_mirror"]
            assert mm["tables"] > 0 and mm["key_bytes_uploaded"] > 0
            scn = cl.serving.scenario()
            assert scn.cache.table._dev.placement == "hbm"
    for i in range(2):
        for g in served["numpy"][i]:
            np.testing.assert_array_equal(served["numpy"][i][g],
                                          served["pallas"][i][g])


def test_cold_pull_large_map_pallas_matches_numpy(rng):
    """End-to-end cold→warm serve through a >2M-slot serving map: the
    scenario cache arena is rebuilt at 2^22 slots, so auto placement
    flips to the HBM windowed-DMA probe for every warm cache hit — and
    the served rows stay bit-equal to the numpy backend throughout."""
    import dataclasses

    from repro.configs.weips_ctr import FM_FTRL
    from repro.core.cluster import ClusterConfig, WeiPSCluster
    from repro.core.ps import SparseTable
    from repro.kernels.hashmap_probe import VMEM_SLOT_BOUND

    cfg = dataclasses.replace(FM_FTRL, fields=4)
    pool = np.unique(_rand_ids(rng, 80, space=1 << 40))
    req = pool[rng.integers(0, len(pool), size=(5, cfg.fields))]
    served = {}
    for backend in ("numpy", "pallas"):
        cl = WeiPSCluster(cfg, ClusterConfig(
            num_master=1, num_slave=2, num_replicas=1, num_partitions=2,
            ps_backend=backend))
        scn = cl.serving.scenario()
        scn.cache.table = SparseTable(scn.cache.width, backend=backend,
                                      init_capacity=1 << 22)
        assert scn.cache.table._map.capacity > VMEM_SLOT_BOUND
        prng = np.random.default_rng(31)
        for mid, mids in cl.plan.split_by_master(pool).items():
            for g, dim in cl.groups.items():
                cl.masters[mid].apply_batch(
                    g, mids,
                    prng.normal(size=(len(mids), dim)).astype(np.float32))
        cl.sync_tick(0.0)
        served[backend] = (cl.serve_rows(req), cl.serve_rows(req))
        if backend == "pallas":
            assert scn.cache.table._dev.placement == "hbm"
            assert cl.serving.device_blocks > 0
    for i in range(2):
        for g in served["numpy"][i]:
            np.testing.assert_array_equal(served["numpy"][i][g],
                                          served["pallas"][i][g])


def test_mirror_incremental_key_sync_counters(rng):
    """The dirty-slot journal keeps mirror key syncs incremental: after
    the first full upload, inserting a few ids re-uploads only their
    slots (bytes counted per slot, not per table), visible per-table and
    aggregated through ``cluster.sync_metrics``."""
    from repro.core.ps import SparseTable

    st = SparseTable(4, ("n", "z"), backend="pallas",
                     init_capacity=1 << 12)
    ids = np.unique(_rand_ids(rng, 256, space=1 << 40))
    st.ensure(ids)
    st._gather_device(ids[:32])                  # first sync: full upload
    m0 = st.mirror_metrics()
    assert m0["key_full_uploads"] == 1
    assert m0["key_incremental_uploads"] == 0
    full_bytes = m0["key_bytes_uploaded"]
    assert full_bytes > 0
    fresh = np.unique(_rand_ids(rng, 8, space=1 << 40) + (1 << 41))
    st.ensure(fresh)
    st._gather_device(fresh)                     # second sync: journal path
    m1 = st.mirror_metrics()
    assert m1["key_full_uploads"] == 1           # no re-upload of the table
    assert m1["key_incremental_uploads"] == 1
    delta = m1["key_bytes_uploaded"] - full_bytes
    assert 0 < delta <= len(fresh) * 2 * 20      # per-slot, not per-table
    # evict → tombstones flow through the same journal
    st.evict(ids[:4])
    rows, found, _ = st.lookup_device(ids[:8])
    assert not found[:4].any() and found[4:].all()
    m2 = st.mirror_metrics()
    assert m2["key_full_uploads"] == 1
    assert m2["key_incremental_uploads"] == 2
